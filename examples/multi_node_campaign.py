"""Multi-node optimization campaign over real HTTP (paper sec. 4).

Reproduces the MARCONI-100 campaign shape on one machine: a HOPAAS
service (4 stateless API workers behind the event-loop HTTP frontend,
shared durable storage — snapshots + segmented WAL with group-commit
fsync) and 20 concurrent *unreliable* worker "nodes" that join with
staggered start times (elasticity), occasionally crash without
reporting (opportunistic resources), and whose orphaned trials the
service requeues via lease expiry.  The 20 node threads share one
``PooledHttpTransport`` — a bounded pool of keep-alive sockets checked
out per request — instead of opening a connection per node.  Ends with
a crash-restart: recovery loads the newest snapshot, replays only the
WAL tail, and is digest-verified identical to the pre-crash state.

  PYTHONPATH=src python examples/multi_node_campaign.py
"""
import tempfile
import time

from repro.core.auth import TokenManager
from repro.core.campaign import run_campaign
from repro.core.client import suggestions
from repro.core.durable import DurableStorage
from repro.core.server import HopaasServer
from repro.core.transport import HttpServiceRunner, PooledHttpTransport


def objective(params, report):
    """Rastrigin-flavored surface with intermediate reports."""
    import math
    x, y = params["x"], params["y"]
    val = (20 + x * x - 10 * math.cos(2 * math.pi * x)
           + y * y - 10 * math.cos(2 * math.pi * y))
    for step in range(6):
        if report(step, val + (6 - step)):
            break
    time.sleep(0.002)          # simulated training time
    return val


def main():
    root = tempfile.mkdtemp(prefix="hopaas-engine-")
    storage = DurableStorage(root, fsync="group", segment_bytes=64 * 1024)
    tokens = TokenManager()
    backends = [HopaasServer(storage=storage, tokens=tokens,
                             lease_seconds=1.0, worker_name=f"api-{i}")
                for i in range(4)]
    runner = HttpServiceRunner(backends).start()
    token = tokens.issue("campaign-user")
    print(f"service: {runner.url}  (4 API workers, "
          f"frontend={runner.backend}, storage engine at {root})")

    # one transport for all 20 node threads: an 8-socket keep-alive pool
    pool = PooledHttpTransport(runner.host, runner.port, pool_size=8)

    res = run_campaign(
        objective,
        study_spec={
            "name": "marconi-style",
            "properties": {"x": suggestions.uniform(-5.12, 5.12),
                           "y": suggestions.uniform(-5.12, 5.12)},
            "direction": "minimize",
            "sampler": {"name": "tpe"},
            "pruner": {"name": "median", "n_warmup_steps": 2},
        },
        transport_factory=lambda: pool,
        token=token,
        n_workers=20, n_trials=120,
        failure_rate=0.10,          # 10% of nodes die mid-trial
        stagger_seconds=0.02,       # elastic join
        seed=11)

    # lease sweep happens on ask; give orphans one explicit pass
    time.sleep(1.2)
    requeued = backends[0].sweep_expired()

    print(f"\ncampaign: {res.n_trials} trials on 20 nodes in "
          f"{res.wall_seconds:.1f}s")
    print(f"  completed={res.n_completed} pruned={res.n_pruned} "
          f"failed={res.n_failed} (+{requeued} swept after the fact)")
    print(f"  best: {res.best_value:.4f} at {res.best_params}")
    print(f"  trials per node: {sorted(res.trials_per_worker.values())}")
    stats = storage.storage_stats()
    print(f"  WAL: {stats['wal_records']} records over "
          f"{stats['rotations'] + 1} segment(s), fsync={stats['fsync']} "
          f"({stats['fsyncs']} fsyncs), {stats['compactions']} compaction(s)")

    # --- crash-restart: load newest snapshot + replay only the tail ----
    digest = storage.state_digest()
    runner.stop()                       # flushes the shared storage
    storage.close()
    restarted = DurableStorage(root, fsync="group")
    rec = restarted.last_recovery
    assert restarted.state_digest() == digest, "recovered state diverged"
    restored = restarted.studies()
    print(f"\ncrash-restart: snapshot covers segment "
          f"{rec['snapshot_covers']}, replayed {rec['records_replayed']} "
          f"tail records in {rec['seconds'] * 1e3:.1f}ms; state digest "
          f"verified identical ({len(restored)} stud(ies), "
          f"{sum(len(s.trials) for s in restored)} trials)")
    restarted.close()


if __name__ == "__main__":
    main()
