"""HOPAAS quickstart — the paper's README-level story in one file.

Starts an in-process HOPAAS service, runs a TPE study with median pruning
over a noisy objective through the exact ask/tell/should_prune protocol,
and prints the study report (what the web UI would show).

  PYTHONPATH=src python examples/quickstart.py
"""
import math
import random

from repro.core.auth import TokenManager
from repro.core.client import Client, Study, suggestions
from repro.core.report import convergence_trace, format_report
from repro.core.server import HopaasServer
from repro.core.transport import DirectTransport


def objective(trial) -> float:
    """Noisy 2-D bowl with a log-scaled axis (lr-like)."""
    rnd = random.Random(trial.id)
    base = (math.log10(trial.lr) + 3.0) ** 2 + (trial.momentum - 0.9) ** 2
    # report intermediate values; the server may prune us
    for step in range(10):
        value = base + 2.0 * math.exp(-0.5 * step) + rnd.gauss(0, 0.01)
        if trial.should_prune(step, value):
            return value
    return base + rnd.gauss(0, 0.01)


def main():
    server = HopaasServer(tokens=TokenManager())
    token = server.tokens.issue("quickstart", ttl_seconds=3600)
    client = Client(DirectTransport(server), token)
    print("HOPAAS version:", client.version())

    study = Study(
        name="quickstart",
        properties={"lr": suggestions.loguniform(1e-5, 1e-1),
                    "momentum": suggestions.uniform(0.5, 0.99)},
        direction="minimize",
        sampler={"name": "tpe"},
        pruner={"name": "median", "n_warmup_steps": 3},
        client=client)

    for _ in range(30):
        with study.trial() as trial:
            trial.loss = objective(trial)

    stored = server.storage.get_study(study.study_key)
    print(format_report(stored))
    trace = convergence_trace(stored)
    print("best-so-far trace:",
          " -> ".join(f"{v:.3f}" for v in trace[:: max(1, len(trace) // 8)]))


if __name__ == "__main__":
    main()
