"""End-to-end training driver: a ~100M-parameter LM for a few hundred
steps with the full substrate — deterministic data pipeline, microbatched
AdamW train step, checkpoint/restart, and loss reporting.

Defaults are sized so the loss visibly drops on CPU in a few minutes; on
real hardware raise --steps/--batch/--seq (the step is the same jitted
function the dry-run lowers to 512 chips).

  PYTHONPATH=src python examples/train_e2e.py [--steps 300] [--tiny]
"""
import argparse

from repro.data import DataConfig
from repro.models import registry
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, cosine_warmup
from repro.train import Trainer, TrainerConfig


def model_100m() -> ModelConfig:
    """A ~100M llama-style config (deepseek family, reduced)."""
    return registry.get_config("deepseek-7b").replace(
        name="deepseek-100m", n_layers=10, d_model=640, n_heads=10,
        n_kv_heads=10, head_dim=64, d_ff=1920, vocab_size=32768)


def model_tiny() -> ModelConfig:
    return registry.get_config("deepseek-7b", smoke=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-sized model (seconds, for CI)")
    ap.add_argument("--checkpoint-dir", default=None)
    args = ap.parse_args()

    mcfg = model_tiny() if args.tiny else model_100m()
    n = mcfg.n_params()
    print(f"model: {mcfg.name}  {n/1e6:.1f}M params")

    opt = AdamWConfig(lr=cosine_warmup(args.lr, warmup=20,
                                       total=args.steps))
    dcfg = DataConfig(global_batch=args.batch, seq_len=args.seq, seed=0)
    tcfg = TrainerConfig(total_steps=args.steps,
                         microbatches=args.microbatches,
                         checkpoint_dir=args.checkpoint_dir,
                         checkpoint_every=100 if args.checkpoint_dir else 0,
                         log_every=max(args.steps // 20, 1))
    res = Trainer(mcfg, opt, dcfg, tcfg).run()
    toks = res.steps_run * args.batch * args.seq
    print(f"\n{res.steps_run} steps / {toks/1e6:.2f}M tokens in "
          f"{res.wall_seconds:.0f}s "
          f"({toks/max(res.wall_seconds, 1e-9):.0f} tok/s)")
    print(f"loss: {res.losses[0]:.4f} -> {res.final_loss:.4f}")
    assert res.final_loss < res.losses[0], "loss did not improve"


if __name__ == "__main__":
    main()
