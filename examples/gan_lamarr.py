"""GAN hyperparameter campaign — the paper's sec. 4 workload class.

Lamarr parameterizes the LHCb detector response with GANs; "adversarial
models are particularly sensitive to the choice of the hyperparameter
configuration".  This example trains a real (small) JAX GAN on a
synthetic multi-modal "detector response" distribution and lets HOPAAS
steer (lr_g, lr_d, latent, width) with TPE + median pruning on an
intermediate two-sample metric.

  PYTHONPATH=src python examples/gan_lamarr.py [--trials 6] [--steps 300]
"""
import argparse
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.auth import TokenManager
from repro.core.client import Client, Study, suggestions
from repro.core.report import format_report
from repro.core.server import HopaasServer
from repro.core.transport import DirectTransport
from repro.optim import AdamWConfig, adamw_init, adamw_update


# ------------------------------------------------------------------ #
# the "detector": a 2-D, 8-mode ring mixture (stand-in for the high-level
# response distributions Lamarr parameterizes)
# ------------------------------------------------------------------ #
def sample_real(key, n):
    k1, k2 = jax.random.split(key)
    mode = jax.random.randint(k1, (n,), 0, 8)
    ang = 2 * math.pi * mode.astype(jnp.float32) / 8
    centers = jnp.stack([2 * jnp.cos(ang), 2 * jnp.sin(ang)], -1)
    return centers + 0.15 * jax.random.normal(k2, (n, 2))


def mlp_init(key, sizes):
    params = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        key, sub = jax.random.split(key)
        params.append({"w": jax.random.normal(sub, (a, b)) / jnp.sqrt(a),
                       "b": jnp.zeros((b,))})
    return params


def mlp_apply(params, x):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i + 1 < len(params):
            x = jax.nn.leaky_relu(x, 0.2)
    return x


def mmd(x, y, sigma=1.0):
    """Gaussian-kernel MMD^2 — the pruning/objective metric."""
    def k(a, b):
        d = jnp.sum((a[:, None] - b[None]) ** 2, -1)
        return jnp.exp(-d / (2 * sigma ** 2))
    return k(x, x).mean() + k(y, y).mean() - 2 * k(x, y).mean()


def train_gan(params_hp, report, steps, seed=0):
    latent = int(params_hp["latent"])
    width = int(params_hp["width"])
    key = jax.random.key(seed)
    kg, kd, key = jax.random.split(key, 3)
    G = mlp_init(kg, [latent, width, width, 2])
    D = mlp_init(kd, [2, width, width, 1])
    og = AdamWConfig(lr=params_hp["lr_g"], b1=0.5, b2=0.9, weight_decay=0.0,
                     grad_clip=0.0)
    od = AdamWConfig(lr=params_hp["lr_d"], b1=0.5, b2=0.9, weight_decay=0.0,
                     grad_clip=0.0)
    sg, sd = adamw_init(G, og), adamw_init(D, od)
    B = 128

    @jax.jit
    def step(G, D, sg, sd, key):
        kz, kr, kz2 = jax.random.split(key, 3)
        z = jax.random.normal(kz, (B, latent))
        real = sample_real(kr, B)

        def d_loss(D):
            fake = mlp_apply(G, z)
            lr_ = jax.nn.sigmoid(mlp_apply(D, real))
            lf = jax.nn.sigmoid(mlp_apply(D, fake))
            return -jnp.mean(jnp.log(lr_ + 1e-6) + jnp.log(1 - lf + 1e-6))

        gd = jax.grad(d_loss)(D)
        D2, sd2, _ = adamw_update(gd, sd, D, od)

        def g_loss(G):
            fake = mlp_apply(G, jax.random.normal(kz2, (B, latent)))
            return -jnp.mean(jnp.log(jax.nn.sigmoid(mlp_apply(D2, fake))
                                     + 1e-6))

        gg = jax.grad(g_loss)(G)
        G2, sg2, _ = adamw_update(gg, sg, G, og)
        return G2, D2, sg2, sd2

    eval_every = max(steps // 6, 1)
    metric = float("inf")
    for t in range(steps):
        key, sub = jax.random.split(key)
        G, D, sg, sd = step(G, D, sg, sd, sub)
        if (t + 1) % eval_every == 0:
            ke, kz = jax.random.split(jax.random.key(t))
            fake = mlp_apply(G, jax.random.normal(kz, (512, latent)))
            metric = float(mmd(sample_real(ke, 512), fake))
            if report((t + 1) // eval_every, metric):
                return metric          # pruned
    return metric


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=6)
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    server = HopaasServer(tokens=TokenManager(), seed=1)
    client = Client(DirectTransport(server), server.tokens.issue("gan"))
    study = Study(
        name="lamarr-gan",
        properties={"lr_g": suggestions.loguniform(1e-5, 1e-2),
                    "lr_d": suggestions.loguniform(1e-5, 1e-2),
                    "latent": suggestions.int(4, 64),
                    "width": suggestions.categorical([64, 128, 256])},
        direction="minimize", sampler={"name": "tpe"},
        pruner={"name": "median", "n_warmup_steps": 2}, client=client)

    for i in range(args.trials):
        trial = study.ask()
        print(f"trial {trial.id}: lr_g={trial.lr_g:.2e} lr_d={trial.lr_d:.2e} "
              f"latent={trial.latent} width={trial.width}", flush=True)
        value = train_gan(trial.params, trial.should_prune, args.steps,
                          seed=i)
        study.tell(trial, value=value,
                   state="pruned" if trial.pruned else None)
        print(f"  -> MMD^2 {value:.4f}" + (" (pruned)" if trial.pruned
                                           else ""))

    print()
    print(format_report(server.storage.get_study(study.study_key)))


if __name__ == "__main__":
    main()
