"""Multi-objective HPO — the paper's sec. 5 future work, implemented.

Fast-simulation models (the paper's Lamarr workload) trade fidelity
against inference cost.  This example drives a real bi-objective study —
minimize [validation loss, parameter count] of a small LM — with the
NSGA-II sampler, and prints the resulting Pareto front from the service
API (what the web UI's front plot would show).

  PYTHONPATH=src python examples/multiobjective.py [--trials 10]
"""
import argparse

from repro.core.auth import TokenManager
from repro.core.client import Client, Study, suggestions
from repro.core.server import HopaasServer
from repro.core.transport import DirectTransport
from repro.data import DataConfig
from repro.models import registry
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig


def objective(params) -> tuple[float, float]:
    width = int(params["width"])
    layers = int(params["layers"])
    mcfg = registry.get_config("deepseek-7b", smoke=True).replace(
        n_layers=layers, d_model=width, d_ff=width * 3,
        n_heads=4, n_kv_heads=4, head_dim=width // 4, vocab_size=512)
    n_params = mcfg.n_params()
    res = Trainer(mcfg,
                  AdamWConfig(lr=float(params["lr"]), weight_decay=0.0),
                  DataConfig(global_batch=8, seq_len=32, seed=0),
                  TrainerConfig(total_steps=40)).run()
    return res.final_loss, float(n_params)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=10)
    args = ap.parse_args()

    server = HopaasServer(tokens=TokenManager(), seed=7)
    token = server.tokens.issue("mo-user")
    client = Client(DirectTransport(server), token)
    study = Study(
        name="loss-vs-size",
        properties={"width": suggestions.categorical([32, 64, 128]),
                    "layers": suggestions.int(1, 4),
                    "lr": suggestions.loguniform(1e-4, 1e-2)},
        directions=["minimize", "minimize"],
        sampler={"name": "nsga2", "population": 4},
        client=client)

    for _ in range(args.trials):
        t = study.ask()
        loss, size = objective(t.params)
        study.tell(t, value=[loss, size])
        print(f"trial {t.id}: width={t.width} layers={t.layers} "
              f"lr={t.lr:.1e} -> loss {loss:.3f}, {size/1e3:.0f}K params")

    _, payload = server.handle("GET", f"/api/studies/{token}")
    rec = [s for s in payload["studies"]
           if s["key"] == study.study_key][0]
    print("\nPareto front (loss, params):")
    for p in sorted(rec["pareto_front"], key=lambda r: r["values"][1]):
        print(f"  {p['values'][0]:.3f} @ {p['values'][1]/1e3:.0f}K  "
              f"{p['params']}")


if __name__ == "__main__":
    main()
