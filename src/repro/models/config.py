"""Model configuration — one dataclass covers all 10 assigned families."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                  # per-expert FFN width
    n_shared: int = 0              # always-on shared experts (qwen2-moe)
    capacity_factor: float = 1.25
    router_norm_topk: bool = True  # renormalize gates over the chosen top-k
    dense_dispatch: bool = False   # tiny smoke configs: run all experts
    group_size: int = 1024        # GShard-style dispatch group (tokens);
    #                               capacity is per-group — global capacity
    #                               makes the one-hot dispatch tensors
    #                               O(T^2/E) (verified: 1.4 TB/device at 32k
    #                               prefill)
    scan_groups: int = 1          # >1: lax.scan over group blocks, bounding
    #                               live dispatch buffers to 1/scan_groups
    #                               (long-sequence prefill)


@dataclasses.dataclass(frozen=True)
class SSMConfig:                   # Mamba2 / SSD
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 64                # SSD chunk length


@dataclasses.dataclass(frozen=True)
class RWKVConfig:                  # RWKV6 "Finch"
    head_dim: int = 64
    decay_lora: int = 64           # rank of the data-dependent decay LoRA
    gate_lora: int = 32


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | hybrid | vlm | moe | ssm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    # attention flavor
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: int | None = None
    rope_theta: float = 10_000.0
    # block pattern
    block: str = "attn"            # attn | mamba2 | rwkv6 | zamba2
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    rwkv: RWKVConfig | None = None
    shared_attn_period: int = 6    # zamba2: shared attn block every N mamba
    # structure
    encoder_only: bool = False     # hubert: no causal mask, no decode
    frontend: str | None = None    # audio | vision (stub embeddings)
    frontend_dim: int = 0          # raw feature dim entering the stub
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    act: str = "silu"              # mlp nonlinearity (hubert uses gelu)
    glu: bool = True               # SwiGLU-style gated MLP (False -> plain)
    # numerics / implementation
    dtype: Any = jnp.bfloat16      # activation/compute dtype
    param_dtype: Any = jnp.float32
    attn_impl: str = "ref"         # ref | flash (pallas) | blocked (jnp online-softmax)
    ssm_impl: str = "ref"          # ref | pallas
    kv_quant: bool = False         # int8 KV cache (serving)
    attn_sp: bool = False          # sequence-parallel attention (q seq
    #                                sharded over the context mesh axis;
    #                                for archs whose head counts cannot
    #                                shard over the model axis)
    remat: bool = True             # checkpoint each layer in train_step
    remat_policy: str = "nothing"  # nothing | dots (save projection/mlp dot
    #                                outputs: skips recomputing ~95% of layer
    #                                FLOPs in backward for ~L x 40MB HBM)
    scan_layers: bool = True       # lax.scan over the layer stack

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % self.n_kv_heads == 0, "GQA group size must divide"

    @property
    def is_attention_free(self) -> bool:
        return self.block in ("mamba2", "rwkv6")

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic path exists: SSM/linear blocks, hybrids, or SWA."""
        return self.block in ("mamba2", "rwkv6", "zamba2") or (
            self.sliding_window is not None)

    @property
    def supports_decode(self) -> bool:
        return not self.encoder_only

    def n_params(self) -> int:
        """Analytic parameter count (used for 6·N·D roofline bookkeeping)."""
        from . import registry
        return registry.count_params(self)

    def replace(self, **kw: Any) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
