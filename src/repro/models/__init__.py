from .config import ModelConfig, MoEConfig, RWKVConfig, SSMConfig
from .registry import (count_active_params, count_params, get_config,
                       list_archs, register)
from . import transformer

__all__ = ["ModelConfig", "MoEConfig", "RWKVConfig", "SSMConfig",
           "count_active_params", "count_params", "get_config", "list_archs",
           "register", "transformer"]
