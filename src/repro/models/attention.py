"""Grouped-query attention with the flavor flags of the assigned archs:
QKV bias (qwen1.5), qk-norm (qwen3), sliding window (mixtral), GQA (all),
encoder mode (hubert).  ``attn_impl='flash'`` routes the sequence path
through the Pallas kernel; ``'ref'`` is the pure-jnp path (used by the
dry-run so HLO cost analysis sees the true FLOPs).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import Leaf, apply_rope, mk, rmsnorm


def init_attention(ks, cfg: ModelConfig, stacked: int | None = None) -> dict:
    L = () if stacked is None else (stacked,)
    A = () if stacked is None else ("layers",)
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cfg.param_dtype
    p = {
        "wq": mk(next(ks), (*L, d, h, hd), (*A, "embed", "heads", "head_dim"), dt),
        "wk": mk(next(ks), (*L, d, kv, hd), (*A, "embed", "kv_heads", "head_dim"), dt),
        "wv": mk(next(ks), (*L, d, kv, hd), (*A, "embed", "kv_heads", "head_dim"), dt),
        "wo": mk(next(ks), (*L, h, hd, d), (*A, "heads", "head_dim", "embed"), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = mk(next(ks), (*L, h, hd), (*A, "heads", "head_dim"), dt, init="zeros")
        p["bk"] = mk(next(ks), (*L, kv, hd), (*A, "kv_heads", "head_dim"), dt, init="zeros")
        p["bv"] = mk(next(ks), (*L, kv, hd), (*A, "kv_heads", "head_dim"), dt, init="zeros")
    if cfg.qk_norm:
        p["q_norm"] = mk(next(ks), (*L, hd), (*A, "head_dim"), dt, init="ones")
        p["k_norm"] = mk(next(ks), (*L, hd), (*A, "head_dim"), dt, init="ones")
    return p


def _project_qkv(p: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
    pet = dict(preferred_element_type=cfg.dtype)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cfg.dtype), **pet)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cfg.dtype), **pet)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cfg.dtype), **pet)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cfg.dtype)
        k = k + p["bk"].astype(cfg.dtype)
        v = v + p["bv"].astype(cfg.dtype)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if not cfg.encoder_only:           # hubert uses learned conv pos (stubbed)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _ref_core(cfg: ModelConfig, q: jax.Array, k: jax.Array, v: jax.Array,
              q_positions: jax.Array, kv_positions: jax.Array,
              kv_len: jax.Array | None = None,
              k_scale: jax.Array | None = None,
              v_scale: jax.Array | None = None) -> jax.Array:
    """Reference GQA attention.  q: (B,S,Hq,hd); k,v: (B,T,Hkv,hd).
    Masking from absolute positions; ``kv_len`` bounds valid cache entries.
    ``k_scale``/``v_scale`` (B,T): int8-quantized KV — the scale is folded
    into scores/probs so no dequantized cache copy materializes."""
    B, S, Hq, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    qg = q.reshape(B, S, Hkv, g, hd)
    kc = k.astype(cfg.dtype) if k.dtype == jnp.int8 else k
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, kc).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    if k_scale is not None:
        scores = scores * k_scale.astype(jnp.float32)[:, None, None, None, :]

    qpos = q_positions[..., :, None]            # (S,1) or (B,S,1)
    kpos = kv_positions[..., None, :]           # (1,T) or (B,1,T)
    mask = jnp.ones((S, T), dtype=bool) if cfg.encoder_only else (kpos <= qpos)
    if cfg.sliding_window is not None:
        mask = mask & (kpos > qpos - cfg.sliding_window)
    mask = mask & (kpos >= 0)                   # ring slots not yet written
    if kv_len is not None:
        mask = mask & (kv_positions < kv_len)[..., None, :]
    scores = jnp.where(mask[..., None, None, :, :] if mask.ndim == 2
                       else mask[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    if v_scale is not None:
        probs = probs * v_scale.astype(jnp.float32)[:, None, None, None, :]
    probs = probs.astype(cfg.dtype)
    vc = v.astype(cfg.dtype) if v.dtype == jnp.int8 else v
    out = jnp.einsum("bkgst,btkh->bskgh", probs, vc)
    return out.reshape(B, S, Hq, hd)


def _blocked_core(cfg: ModelConfig, q: jax.Array, k: jax.Array,
                  v: jax.Array, block_k: int = 512, q_chunks: int = 4
                  ) -> jax.Array:
    """Memory-bounded attention: online softmax streamed over kv blocks
    with ``lax.scan`` (never materializes the S x T score matrix — the
    pure-XLA analogue of the Pallas flash kernel, used where the kernel
    cannot lower: CPU dry-runs and the 32k-prefill cells).  For causal
    attention the q dim is split into ``q_chunks`` static chunks so kv
    blocks entirely above the diagonal are not computed (FLOP overcount
    vs a perfect diagonal skip: 1 + 1/(2*q_chunks))."""
    B, S, Hq, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    causal = not cfg.encoder_only
    window = cfg.sliding_window

    def run_chunk(qc: jax.Array, q0: int, kv_lo: int, kv_hi: int
                  ) -> jax.Array:
        """qc: (B, Sc, Hq, hd) starting at absolute position q0; attends
        kv[kv_lo:kv_hi] (static bounds — the causal/SWA block skip).

        Flat-head form: kv blocks are repeated to Hq heads *per block*
        (cheap — one kv block) instead of reshaping q to (Hkv, g, hd).
        The grouped reshape splits a sharded Hq dim into dims the mesh
        cannot divide, which GSPMD resolves by replicating q AND the
        weights that produce it (verified: +4.3 GB/device on mixtral)."""
        Sc = qc.shape[1]
        span = kv_hi - kv_lo
        bk = min(block_k, span)
        nb = span // bk
        rem = span - nb * bk                # trailing partial block
        qf = qc.astype(jnp.float32) * scale
        qpos = q0 + jnp.arange(Sc, dtype=jnp.int32)

        def attend(carry, kblk, vblk, kpos):
            m, l, acc = carry
            if g > 1:                       # expand kv heads per block
                kblk = jnp.repeat(kblk, g, axis=2)
                vblk = jnp.repeat(vblk, g, axis=2)
            s = jnp.einsum("bshd,bthd->bsht", qf,
                           kblk.astype(jnp.float32))
            msk = jnp.ones((Sc, kblk.shape[1]), bool)
            if causal:
                msk = msk & (kpos[None, :] <= qpos[:, None])
            if window is not None:
                msk = msk & (kpos[None, :] > qpos[:, None] - window)
            s = jnp.where(msk[None, :, None, :], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(msk[None, :, None, :], p, 0.0)
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bsht,bthd->bshd", p, vblk.astype(jnp.float32))
            return m_new, l_new, acc_new

        m0 = jnp.full((B, Sc, Hq), -1e30, jnp.float32)
        l0 = jnp.zeros((B, Sc, Hq), jnp.float32)
        a0 = jnp.zeros((B, Sc, Hq, hd), jnp.float32)

        kb = k[:, kv_lo: kv_lo + nb * bk].reshape(B, nb, bk, Hkv, hd)
        vb = v[:, kv_lo: kv_lo + nb * bk].reshape(B, nb, bk, Hkv, hd)
        pb = kv_lo + jnp.arange(nb * bk, dtype=jnp.int32).reshape(nb, bk)

        def body(carry, inp):
            kblk, vblk, kpos = inp
            return attend(carry, kblk, vblk, kpos), None

        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0),
            (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), pb))
        if rem:
            m, l, acc = attend((m, l, acc), k[:, kv_lo + nb * bk: kv_hi],
                               v[:, kv_lo + nb * bk: kv_hi],
                               jnp.arange(kv_lo + nb * bk, kv_hi,
                                          dtype=jnp.int32))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.reshape(B, Sc, Hq, hd).astype(cfg.dtype)

    if not causal:
        return run_chunk(q, 0, 0, T)
    nq = q_chunks if S % q_chunks == 0 and S >= q_chunks else 1
    Sc = S // nq
    outs = []
    qq = q
    for i in range(nq):
        lo = 0 if window is None else max(0, i * Sc - window)
        out = run_chunk(qq[:, i * Sc: (i + 1) * Sc], i * Sc,
                        lo, min(T, (i + 1) * Sc))
        if i + 1 < nq:
            # scheduling edge: chunk i+1 starts only after chunk i, so XLA
            # reuses one chunk's accumulator buffers instead of keeping
            # all nq alive (verified: 4x peak-temp reduction at 32k)
            out, qq = jax.lax.optimization_barrier((out, qq))
        outs.append(out)
    return jnp.concatenate(outs, axis=1) if nq > 1 else outs[0]


def attention(p: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array
              ) -> jax.Array:
    """Full-sequence attention (train / prefill)."""
    q, k, v = _project_qkv(p, cfg, x, positions)
    if cfg.attn_sp:
        # sequence-parallel attention (context-provided axis): q seq
        # sharded, kv replicated on that axis -> scores stay local
        from repro.dist.context import constrain_attn_seq
        q, k, v, _ = constrain_attn_seq(q, k, v)
    if cfg.attn_impl == "flash" and not cfg.encoder_only:
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(q, k, v, causal=True,
                                     window=cfg.sliding_window)
    elif cfg.attn_impl == "blocked":
        out = _blocked_core(cfg, q, k, v)
    else:
        out = _ref_core(cfg, q, k, v, positions, positions)
    if cfg.attn_sp:
        from repro.dist.context import constrain_batch, constrain_seq
        out = constrain_seq(out)
        # leave the seq-parallel region at the block boundary: without
        # this the seq-sharding propagates into the MLP, which then
        # replicates (fully gathers) its TP weights
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cfg.dtype),
                       preferred_element_type=cfg.dtype)
        return constrain_batch(y, exact=True)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cfg.dtype),
                      preferred_element_type=cfg.dtype)


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  abstract: bool = False, stacked: int | None = None) -> dict:
    """``cfg.kv_quant`` stores K/V int8 with a per-(batch, slot) bf16 scale
    (shared over heads and head_dim) — 2x HBM saving on serving caches;
    scores contract against int8 directly (MXU int8 path) with the scale
    folded in afterwards, so no dequantized copy ever materializes."""
    L = () if stacked is None else (stacked,)
    A = () if stacked is None else ("layers",)
    shape = (*L, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    axes = (*A, "batch", None, "kv_heads", "head_dim")
    kv_dtype = jnp.int8 if cfg.kv_quant else cfg.dtype
    if abstract:
        arr = jax.ShapeDtypeStruct(shape, kv_dtype)
        out = {"k": Leaf(arr, axes), "v": Leaf(arr, axes)}
    else:
        z = jnp.zeros(shape, kv_dtype)
        out = {"k": Leaf(z, axes), "v": Leaf(z, axes)}
    if cfg.kv_quant:
        s_shape = (*L, batch, max_len)
        s_axes = (*A, "batch", None)
        if abstract:
            s = jax.ShapeDtypeStruct(s_shape, jnp.bfloat16)
            out["k_scale"], out["v_scale"] = Leaf(s, s_axes), Leaf(s, s_axes)
        else:
            zs = jnp.zeros(s_shape, jnp.bfloat16)
            out["k_scale"] = Leaf(zs, s_axes)
            out["v_scale"] = Leaf(jnp.array(zs), s_axes)
    return out


def _quantize_token(t: jax.Array) -> tuple[jax.Array, jax.Array]:
    """t: (B, 1, Hkv, hd) -> (int8, scale (B, 1) bf16)."""
    tf = t.astype(jnp.float32)
    scale = jnp.max(jnp.abs(tf), axis=(1, 2, 3), keepdims=False)[:, None] / 127.0
    scale = jnp.maximum(scale, 1e-8)                    # (B, 1)
    q = jnp.clip(jnp.round(tf / scale[:, :, None, None]), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def decode_attention(p: dict, cfg: ModelConfig, x: jax.Array, kv: dict,
                     cache_len: jax.Array) -> tuple[jax.Array, dict]:
    """One-token decode.  x: (B,1,d); kv: {"k","v"[,"k_scale","v_scale"]}
    with k/v (B,Smax,Hkv,hd); cache_len: scalar int32 — tokens already in
    the cache.  Returns (out (B,1,d), new kv dict).

    SWA archs use a *ring* cache: ``Smax`` may be just the window, slot
    ``t % Smax`` holds token ``t``, and slot positions are reconstructed
    from ``cache_len`` — this is what makes mixtral's ``long_500k`` cell
    O(window) HBM instead of O(seq).  ``cfg.kv_quant`` stores int8 + per
    (batch, slot) scales."""
    B, _, _ = x.shape
    Smax = kv["k"].shape[1]
    positions = jnp.full((1,), cache_len, dtype=jnp.int32)
    q, k, v = _project_qkv(p, cfg, x, positions)
    ring = cfg.sliding_window is not None and Smax <= cfg.sliding_window
    if ring:
        slot = cache_len % Smax
        idx = jnp.arange(Smax, dtype=jnp.int32)
        # slot i holds the largest position p <= cache_len with p % Smax == i
        kv_positions = cache_len - ((cache_len - idx) % Smax)
        kv_len = None            # every slot's position is already <= qpos
    else:
        slot = cache_len
        kv_positions = jnp.arange(Smax, dtype=jnp.int32)
        kv_len = cache_len + 1
    new = dict(kv)
    if cfg.kv_quant:
        kq, ks = _quantize_token(k)
        vq, vs = _quantize_token(v)
        new["k"] = jax.lax.dynamic_update_slice(kv["k"], kq, (0, slot, 0, 0))
        new["v"] = jax.lax.dynamic_update_slice(kv["v"], vq, (0, slot, 0, 0))
        new["k_scale"] = jax.lax.dynamic_update_slice(
            kv["k_scale"], ks.astype(kv["k_scale"].dtype), (0, slot))
        new["v_scale"] = jax.lax.dynamic_update_slice(
            kv["v_scale"], vs.astype(kv["v_scale"].dtype), (0, slot))
        out = _ref_core(cfg, q, new["k"], new["v"],
                        q_positions=positions, kv_positions=kv_positions,
                        kv_len=kv_len, k_scale=new["k_scale"],
                        v_scale=new["v_scale"])
    else:
        new["k"] = jax.lax.dynamic_update_slice(kv["k"], k, (0, slot, 0, 0))
        new["v"] = jax.lax.dynamic_update_slice(kv["v"], v, (0, slot, 0, 0))
        out = _ref_core(cfg, q, new["k"], new["v"],
                        q_positions=positions, kv_positions=kv_positions,
                        kv_len=kv_len)
    return (jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cfg.dtype),
                       preferred_element_type=cfg.dtype), new)
