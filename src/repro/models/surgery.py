"""Checkpoint surgery for deployment: TP head padding.

40 attention heads cannot shard over a 16-way model axis; padding q/k/v
to the next multiple with zero heads is function-preserving (zero heads
contribute nothing through the zero rows of w_o) and is what production
TP serving stacks do (vLLM pads heads for exactly this reason).  Costs
(new_h/old_h - 1) extra attention FLOPs; buys collective-free attention.
"""
from __future__ import annotations

import jax.numpy as jnp

from .config import ModelConfig


def padded_heads(n: int, divisor: int) -> int:
    return ((n + divisor - 1) // divisor) * divisor


def pad_heads_config(cfg: ModelConfig, divisor: int) -> ModelConfig:
    """Config with q/kv heads padded up to a multiple of ``divisor``."""
    return cfg.replace(n_heads=padded_heads(cfg.n_heads, divisor),
                       n_kv_heads=padded_heads(cfg.n_kv_heads, divisor))


def pad_heads_params(params: dict, cfg: ModelConfig,
                     new_cfg: ModelConfig) -> dict:
    """Zero-pad a real checkpoint to the padded head counts.  Only the
    attention tensors change; everything else is shared by reference."""
    dh, dkv = (new_cfg.n_heads - cfg.n_heads,
               new_cfg.n_kv_heads - cfg.n_kv_heads)

    def pad(t, axis, extra):
        if extra == 0:
            return t
        widths = [(0, 0)] * t.ndim
        widths[axis] = (0, extra)
        return jnp.pad(t, widths)

    def fix_block(block: dict) -> dict:
        if "attn" not in block:
            return block
        a = dict(block["attn"])
        off = 1 if a["wq"].ndim == 4 else 0      # stacked layers dim
        a["wq"] = pad(a["wq"], off + 1, dh)
        a["wk"] = pad(a["wk"], off + 1, dkv)
        a["wv"] = pad(a["wv"], off + 1, dkv)
        a["wo"] = pad(a["wo"], off + 0, dh)
        for name, extra in (("bq", dh), ("bk", dkv), ("bv", dkv)):
            if name in a:
                a[name] = pad(a[name], off + 0, extra)
        return {**block, "attn": a}

    out = dict(params)
    if "blocks" in out and isinstance(out["blocks"], dict) \
            and "attn" in out["blocks"]:
        out["blocks"] = fix_block(out["blocks"])
    if "shared" in out:
        out["shared"] = fix_block(out["shared"])
    return out
