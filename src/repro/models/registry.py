"""Arch registry + analytic bookkeeping."""
from __future__ import annotations

import math
from typing import Callable

from .config import ModelConfig

_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}
_SMOKE: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str, full: Callable[[], ModelConfig],
             smoke: Callable[[], ModelConfig]) -> None:
    _REGISTRY[name] = full
    _SMOKE[name] = smoke


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    _ensure_loaded()
    table = _SMOKE if smoke else _REGISTRY
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(table)}")
    return table[name]()


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded() -> None:
    if not _REGISTRY:
        import repro.configs  # noqa: F401  (registers all archs on import)


def count_params(cfg: ModelConfig) -> int:
    """Exact parameter count via abstract init (no allocation)."""
    from . import transformer
    params, _ = transformer.init_params(cfg, None)
    import jax
    return sum(math.prod(p.shape) for p in jax.tree.leaves(params))


def count_active_params(cfg: ModelConfig) -> int:
    """Active-per-token params (MoE: top_k + shared experts only)."""
    total = count_params(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    per_expert = 3 * cfg.d_model * m.d_expert
    routed_total = cfg.n_layers * m.n_experts * per_expert
    routed_active = cfg.n_layers * m.top_k * per_expert
    return total - routed_total + routed_active
