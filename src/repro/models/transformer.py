"""The model stack: embedding -> N blocks (scan) -> norm -> LM head.

Covers every assigned family through ``cfg.block``:
  * ``attn``   — pre-norm attention + (MLP | MoE)        [dense, moe, vlm, audio]
  * ``rwkv6``  — time-mix + channel-mix                  [ssm: rwkv6-7b]
  * ``mamba2`` — pure SSD stack                          [ssm]
  * ``zamba2`` — SSD backbone + weight-tied shared attention block every
                 ``shared_attn_period`` layers           [hybrid]

Layers are stacked along a leading ``layers`` dim and traversed with
``jax.lax.scan`` (small HLO, fast 512-way GSPMD compile); each block body is
``jax.checkpoint``-ed when ``cfg.remat`` (activation memory ~ one block).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.context import constrain_batch

from . import attention as attn_mod
from . import frontends, mamba2, moe as moe_mod, rwkv6
from .config import ModelConfig
from .layers import (Leaf, cross_entropy, init_embedding, init_lm_head,
                     init_mlp, init_rmsnorm, keygen, mk, mlp, rmsnorm,
                     split_tree)

MOE_AUX_COEF = 0.01


# ===================================================================== #
# init
# ===================================================================== #
def _init_attn_block(ks, cfg: ModelConfig, stacked: int | None) -> dict:
    p = {"norm1": init_rmsnorm(ks, cfg.d_model, cfg.param_dtype, stacked),
         "attn": attn_mod.init_attention(ks, cfg, stacked),
         "norm2": init_rmsnorm(ks, cfg.d_model, cfg.param_dtype, stacked)}
    if cfg.moe is not None:
        p["moe"] = moe_mod.init_moe(ks, cfg, stacked)
    else:
        p["mlp"] = init_mlp(ks, cfg.d_model, cfg.d_ff, cfg.param_dtype,
                            cfg.glu, stacked)
    return p


def _init_rwkv_block(ks, cfg: ModelConfig, stacked: int | None) -> dict:
    return {"norm1": init_rmsnorm(ks, cfg.d_model, cfg.param_dtype, stacked),
            "tmix": rwkv6.init_rwkv6(ks, cfg, stacked),
            "norm2": init_rmsnorm(ks, cfg.d_model, cfg.param_dtype, stacked),
            "cmix": rwkv6.init_channel_mix(ks, cfg, stacked)}


def _init_mamba_block(ks, cfg: ModelConfig, stacked: int | None) -> dict:
    return {"norm": init_rmsnorm(ks, cfg.d_model, cfg.param_dtype, stacked),
            "mamba": mamba2.init_mamba2(ks, cfg, stacked)}


def _zamba_split(cfg: ModelConfig) -> tuple[int, int, int]:
    period = cfg.shared_attn_period
    n_groups = cfg.n_layers // period
    tail = cfg.n_layers - n_groups * period
    return n_groups, period, tail


def init(cfg: ModelConfig, key: jax.Array | None) -> dict:
    """Build the Leaf tree.  ``key=None`` -> abstract (ShapeDtypeStruct)."""
    ks = keygen(key)
    p: dict[str, Any] = {}
    if cfg.frontend == "audio":
        p["frontend"] = frontends.init_audio_frontend(ks, cfg)
    else:
        p["embed"] = init_embedding(ks, cfg.vocab_size, cfg.d_model,
                                    cfg.param_dtype)
    if cfg.frontend == "vision":
        p["adapter"] = frontends.init_vision_adapter(ks, cfg)

    if cfg.block == "attn":
        p["blocks"] = _init_attn_block(ks, cfg, cfg.n_layers)
    elif cfg.block == "rwkv6":
        p["blocks"] = _init_rwkv_block(ks, cfg, cfg.n_layers)
    elif cfg.block == "mamba2":
        p["blocks"] = _init_mamba_block(ks, cfg, cfg.n_layers)
    elif cfg.block == "zamba2":
        n_groups, period, tail = _zamba_split(cfg)
        p["mamba_groups"] = _init_mamba_block(ks, cfg, n_groups * period)
        if tail:
            p["mamba_tail"] = _init_mamba_block(ks, cfg, tail)
        p["shared"] = _init_attn_block(ks, cfg, None)      # weight-tied copy
    else:
        raise ValueError(cfg.block)

    p["final_norm"] = init_rmsnorm(ks, cfg.d_model, cfg.param_dtype)
    if not cfg.tie_embeddings and cfg.frontend != "audio":
        p["lm_head"] = init_lm_head(ks, cfg.d_model, cfg.vocab_size,
                                    cfg.param_dtype)
    elif cfg.frontend == "audio":
        p["lm_head"] = init_lm_head(ks, cfg.d_model, cfg.vocab_size,
                                    cfg.param_dtype)
    return p


def init_params(cfg: ModelConfig, key: jax.Array | None):
    """-> (params, logical_specs)."""
    return split_tree(init(cfg, key))


# ===================================================================== #
# block bodies (full sequence)
# ===================================================================== #
def _attn_block(p, cfg: ModelConfig, x, positions):
    x = constrain_batch(x)          # re-assert DP sharding at block entry
    x = x + attn_mod.attention(p["attn"], cfg, rmsnorm(x, p["norm1"], cfg.norm_eps),
                               positions)
    h = rmsnorm(x, p["norm2"], cfg.norm_eps)
    if cfg.moe is not None and "moe" in p:
        y, aux = moe_mod.moe_ffn(p["moe"], cfg, h)
    else:
        y, aux = mlp(p["mlp"], h, cfg.act), jnp.float32(0.0)
    return x + y, aux


def _rwkv_block(p, cfg: ModelConfig, x):
    x = constrain_batch(x)
    x = x + rwkv6.rwkv6_seq(p["tmix"], cfg, rmsnorm(x, p["norm1"], cfg.norm_eps))
    x = x + rwkv6.channel_mix(p["cmix"], cfg, rmsnorm(x, p["norm2"], cfg.norm_eps))
    return x


def _mamba_block(p, cfg: ModelConfig, x):
    x = constrain_batch(x)
    return x + mamba2.mamba2_seq(p["mamba"], cfg,
                                 rmsnorm(x, p["norm"], cfg.norm_eps))


def _maybe_remat(fn, cfg: ModelConfig):
    if not cfg.remat:
        return fn
    policy = {
        "nothing": jax.checkpoint_policies.nothing_saveable,
        # saves projection/MLP dot outputs (no-batch-dim dots); attention
        # score/pv dots (which have batch dims) are still rematerialized,
        # so the saved set is ~40MB/layer instead of the 268MB/layer scores
        "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    }[cfg.remat_policy]
    return jax.checkpoint(fn, policy=policy)


def _stack(cfg: ModelConfig, params: dict, x: jax.Array,
           positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Run all blocks.  Returns (x, moe_aux_sum)."""
    aux0 = jnp.float32(0.0)

    if cfg.block == "attn":
        def body(carry, p_i):
            h, aux = carry
            h, a = _maybe_remat(
                lambda pp, hh: _attn_block(pp, cfg, hh, positions), cfg)(p_i, h)
            return (h, aux + a), None
        if cfg.scan_layers:
            (x, aux), _ = jax.lax.scan(body, (x, aux0), params["blocks"])
        else:
            aux = aux0
            for i in range(cfg.n_layers):
                p_i = jax.tree.map(lambda t: t[i], params["blocks"])
                (x, aux), _ = body((x, aux), p_i)
        return x, aux

    if cfg.block in ("rwkv6", "mamba2"):
        fn = _rwkv_block if cfg.block == "rwkv6" else _mamba_block

        def body(h, p_i):
            return _maybe_remat(lambda pp, hh: fn(pp, cfg, hh), cfg)(p_i, h), None
        if cfg.scan_layers:
            x, _ = jax.lax.scan(body, x, params["blocks"])
        else:
            for i in range(cfg.n_layers):
                p_i = jax.tree.map(lambda t: t[i], params["blocks"])
                x, _ = body(x, p_i)
        return x, aux0

    if cfg.block == "zamba2":
        n_groups, period, tail = _zamba_split(cfg)
        shared = params["shared"]

        def mamba_body(h, p_i):
            return _maybe_remat(
                lambda pp, hh: _mamba_block(pp, cfg, hh), cfg)(p_i, h), None

        def group_body(h, pg):
            # pg: params of `period` mamba layers (leading dim = period)
            h, _ = jax.lax.scan(mamba_body, h, pg)
            h, _ = _maybe_remat(
                lambda pp, hh: _attn_block(pp, cfg, hh, positions), cfg)(shared, h)
            return h, None

        grouped = jax.tree.map(
            lambda t: t.reshape(n_groups, period, *t.shape[1:]),
            params["mamba_groups"])
        x, _ = jax.lax.scan(group_body, x, grouped)
        if tail:
            x, _ = jax.lax.scan(mamba_body, x, params["mamba_tail"])
        return x, aux0

    raise ValueError(cfg.block)


# ===================================================================== #
# forward / loss
# ===================================================================== #
def embed_inputs(params: dict, cfg: ModelConfig, batch: dict
                 ) -> tuple[jax.Array, jax.Array]:
    """-> (x (B,S,d), positions (S,))."""
    if cfg.frontend == "audio":
        x = frontends.audio_frontend(params["frontend"], cfg,
                                     batch["features"], batch.get("frame_mask"))
    else:
        emb = params["embed"].astype(cfg.dtype)
        x = emb[batch["tokens"]]
        if cfg.frontend == "vision":
            img = frontends.vision_adapter(params["adapter"], cfg,
                                           batch["patch_embeds"])
            x = jnp.concatenate([img, x], axis=1)
    S = x.shape[1]
    return constrain_batch(x), jnp.arange(S, dtype=jnp.int32)


def logits_fn(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,dv->bsv", x, head.astype(cfg.dtype))


def forward(params: dict, cfg: ModelConfig, batch: dict
            ) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward -> (logits, moe_aux)."""
    x, positions = embed_inputs(params, cfg, batch)
    x, aux = _stack(cfg, params, x, positions)
    return logits_fn(params, cfg, x), aux


def loss_fn(params: dict, cfg: ModelConfig, batch: dict
            ) -> tuple[jax.Array, dict]:
    logits, aux = forward(params, cfg, batch)
    labels = batch["labels"]
    if cfg.frontend == "vision":
        # score only the text positions (images occupy the prefix)
        n_img = batch["patch_embeds"].shape[1]
        logits = logits[:, n_img:]
    mask = batch.get("frame_mask") if cfg.frontend == "audio" else \
        batch.get("loss_mask")
    ce = cross_entropy(logits, labels, mask)
    loss = ce + MOE_AUX_COEF * aux
    return loss, {"ce": ce, "moe_aux": aux}


# ===================================================================== #
# decode (serve_step)
# ===================================================================== #
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               abstract: bool = False) -> dict:
    """Per-layer decode state, stacked along layers where applicable."""
    if cfg.block == "attn":
        return {"kv": attn_mod.init_kv_cache(cfg, batch, max_len, abstract,
                                             stacked=cfg.n_layers)}
    if cfg.block == "rwkv6":
        return {"rwkv": rwkv6.init_rwkv6_state(cfg, batch, abstract,
                                               stacked=cfg.n_layers)}
    if cfg.block == "mamba2":
        return {"ssm": mamba2.init_mamba2_state(cfg, batch, abstract,
                                                stacked=cfg.n_layers)}
    if cfg.block == "zamba2":
        n_groups, period, tail = _zamba_split(cfg)
        c = {"ssm": mamba2.init_mamba2_state(cfg, batch, abstract,
                                             stacked=n_groups * period),
             "shared_kv": attn_mod.init_kv_cache(cfg, batch, max_len, abstract,
                                                 stacked=n_groups)}
        if tail:
            c["ssm_tail"] = mamba2.init_mamba2_state(cfg, batch, abstract,
                                                     stacked=tail)
        return c
    raise ValueError(cfg.block)


def init_cache_arrays(cfg: ModelConfig, batch: int, max_len: int,
                      abstract: bool = False):
    return split_tree(init_cache(cfg, batch, max_len, abstract))


def _decode_attn_block(p, cfg, x, kv, cache_len):
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    o, kv = attn_mod.decode_attention(p["attn"], cfg, h, kv, cache_len)
    x = x + o
    h = rmsnorm(x, p["norm2"], cfg.norm_eps)
    if cfg.moe is not None and "moe" in p:
        y, _ = moe_mod.moe_ffn(p["moe"], cfg, h)
    else:
        y = mlp(p["mlp"], h, cfg.act)
    return x + y, kv


def decode_step(params: dict, cfg: ModelConfig, cache: dict,
                tokens: jax.Array, cache_len: jax.Array
                ) -> tuple[jax.Array, dict]:
    """One new token with existing state.  tokens: (B,1) int32.
    Returns (logits (B,1,V), new_cache)."""
    assert cfg.supports_decode, f"{cfg.name} is encoder-only"
    emb = params["embed"].astype(cfg.dtype)
    x = emb[tokens]
    new_cache = dict(cache)

    if cfg.block == "attn":
        # the KV cache rides in the scan CARRY and is updated in place
        # with dynamic-update-slice: XLA aliases carried buffers across
        # iterations, where a scan ys output would materialize a second
        # full-size cache (verified: 2x cache HBM on the 32k cells)
        def body(carry, xs):
            h, kv = carry
            p_i, i = xs
            kv_i = jax.tree.map(
                lambda t: jax.lax.dynamic_index_in_dim(t, i, 0,
                                                       keepdims=False), kv)
            h, kv_i = _decode_attn_block(p_i, cfg, h, kv_i, cache_len)
            kv = jax.tree.map(
                lambda full, new: jax.lax.dynamic_update_index_in_dim(
                    full, new.astype(full.dtype), i, 0), kv, kv_i)
            return (h, kv), None
        (x, kv_new), _ = jax.lax.scan(
            body, (x, cache["kv"]),
            (params["blocks"], jnp.arange(cfg.n_layers, dtype=jnp.int32)))
        new_cache["kv"] = kv_new

    elif cfg.block == "rwkv6":
        def body(h, xs):
            p_i, S, sh_t, sh_c = xs
            hn = rmsnorm(h, p_i["norm1"], cfg.norm_eps)
            o, st = rwkv6.rwkv6_decode(p_i["tmix"], cfg, hn,
                                       {"S": S, "shift": sh_t})
            h = h + o
            hn = rmsnorm(h, p_i["norm2"], cfg.norm_eps)
            o, new_shc = rwkv6.channel_mix_decode(p_i["cmix"], cfg, hn, sh_c)
            h = h + o
            return h, (st["S"], st["shift"], new_shc)
        st = cache["rwkv"]
        x, (S, sh_t, sh_c) = jax.lax.scan(
            body, x, (params["blocks"], st["S"], st["shift_t"], st["shift_c"]))
        new_cache["rwkv"] = {"S": S, "shift_t": sh_t, "shift_c": sh_c}

    elif cfg.block == "mamba2":
        def body(h, xs):
            p_i, hs, conv = xs
            hn = rmsnorm(h, p_i["norm"], cfg.norm_eps)
            o, st = mamba2.mamba2_decode(p_i["mamba"], cfg, hn,
                                         {"h": hs, "conv": conv})
            return h + o, (st["h"], st["conv"])
        st = cache["ssm"]
        x, (hs, conv) = jax.lax.scan(body, x, (params["blocks"],
                                               st["h"], st["conv"]))
        new_cache["ssm"] = {"h": hs, "conv": conv}

    elif cfg.block == "zamba2":
        n_groups, period, tail = _zamba_split(cfg)
        shared = params["shared"]

        def mamba_body(h, xs):
            p_i, hs, conv = xs
            hn = rmsnorm(h, p_i["norm"], cfg.norm_eps)
            o, st = mamba2.mamba2_decode(p_i["mamba"], cfg, hn,
                                         {"h": hs, "conv": conv})
            return h + o, (st["h"], st["conv"])

        def group_body(carry, xs):
            h, kv = carry
            pg, hs, conv, i = xs
            h, (hs, conv) = jax.lax.scan(mamba_body, h, (pg, hs, conv))
            kv_i = jax.tree.map(
                lambda t: jax.lax.dynamic_index_in_dim(t, i, 0,
                                                       keepdims=False), kv)
            h, kv_i = _decode_attn_block(shared, cfg, h, kv_i, cache_len)
            kv = jax.tree.map(
                lambda full, new: jax.lax.dynamic_update_index_in_dim(
                    full, new.astype(full.dtype), i, 0), kv, kv_i)
            return (h, kv), (hs, conv)

        grouped = jax.tree.map(
            lambda t: t.reshape(n_groups, period, *t.shape[1:]),
            params["mamba_groups"])
        st = cache["ssm"]
        hs = st["h"].reshape(n_groups, period, *st["h"].shape[1:])
        conv = st["conv"].reshape(n_groups, period, *st["conv"].shape[1:])
        (x, kv_new), (hs, conv) = jax.lax.scan(
            group_body, (x, cache["shared_kv"]),
            (grouped, hs, conv, jnp.arange(n_groups, dtype=jnp.int32)))
        new_cache["ssm"] = {"h": hs.reshape(-1, *hs.shape[2:]),
                            "conv": conv.reshape(-1, *conv.shape[2:])}
        new_cache["shared_kv"] = kv_new
        if tail:
            stt = cache["ssm_tail"]
            x, (hs2, conv2) = jax.lax.scan(
                mamba_body, x, (params["mamba_tail"], stt["h"], stt["conv"]))
            new_cache["ssm_tail"] = {"h": hs2, "conv": conv2}
    else:
        raise ValueError(cfg.block)

    return logits_fn(params, cfg, x), new_cache
