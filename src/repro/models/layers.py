"""Shared neural building blocks (pure functional JAX, no flax).

Parameter trees are built from ``Leaf`` objects that carry both the array
and its *logical sharding axes* (e.g. ``("embed", "mlp")``); ``split_tree``
separates them into a params pytree and a parallel spec pytree that
``repro.dist.sharding`` maps onto the device mesh.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------- #
# param-tree plumbing
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class Leaf:
    value: jax.Array | jax.ShapeDtypeStruct
    axes: tuple[str | None, ...]


def _is_leaf(x: Any) -> bool:
    return isinstance(x, Leaf)


def split_tree(tree: Any) -> tuple[Any, Any]:
    params = jax.tree.map(lambda l: l.value, tree, is_leaf=_is_leaf)
    specs = jax.tree.map(lambda l: l.axes, tree, is_leaf=_is_leaf)
    return params, specs


def mk(key: jax.Array | None, shape: tuple[int, ...], axes: tuple[str | None, ...],
       dtype: Any, scale: float | None = None, init: str = "normal") -> Leaf:
    """Create one parameter.  ``key=None`` -> ShapeDtypeStruct (abstract init
    for the dry-run: no host allocation for 67B-param models)."""
    assert len(shape) == len(axes), (shape, axes)
    if key is None:
        return Leaf(jax.ShapeDtypeStruct(shape, dtype), axes)
    if init == "zeros":
        return Leaf(jnp.zeros(shape, dtype), axes)
    if init == "ones":
        return Leaf(jnp.ones(shape, dtype), axes)
    if scale is None:
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        scale = 1.0 / math.sqrt(max(fan_in, 1))
    return Leaf((jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype), axes)


def keygen(key: jax.Array | None):
    """Infinite stream of subkeys; yields None forever in abstract mode."""
    while True:
        if key is None:
            yield None
        else:
            key, sub = jax.random.split(key)
            yield sub


# --------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------- #
def init_rmsnorm(ks, d: int, dtype: Any, stacked: int | None = None) -> Leaf:
    shape, axes = (d,), ("embed",)
    if stacked is not None:
        shape, axes = (stacked, d), ("layers", "embed")
    if next(ks) is None:          # abstract mode
        return Leaf(jax.ShapeDtypeStruct(shape, dtype), axes)
    return Leaf(jnp.ones(shape, dtype), axes)


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------- #
# MLP (SwiGLU / plain)
# --------------------------------------------------------------------- #
def init_mlp(ks, d_model: int, d_ff: int, dtype: Any, glu: bool,
             stacked: int | None = None) -> dict:
    L = () if stacked is None else (stacked,)
    A = () if stacked is None else ("layers",)
    p = {"up": mk(next(ks), (*L, d_model, d_ff), (*A, "embed", "mlp"), dtype),
         "down": mk(next(ks), (*L, d_ff, d_model), (*A, "mlp", "embed"), dtype)}
    if glu:
        p["gate"] = mk(next(ks), (*L, d_model, d_ff), (*A, "embed", "mlp"), dtype)
    return p


def mlp(p: dict, x: jax.Array, act: str = "silu") -> jax.Array:
    fn = getattr(jax.nn, act)
    dt = x.dtype
    # preferred_element_type pins the dot output dtype to the activation
    # dtype, so the TP partial-sum all-reduce of the down-projection moves
    # bf16 — without it XLA may all-reduce the f32 accumulator (2x bytes)
    h = jnp.einsum("...d,df->...f", x, p["up"].astype(dt),
                   preferred_element_type=dt)
    if "gate" in p:
        h = h * fn(jnp.einsum("...d,df->...f", x, p["gate"].astype(dt),
                              preferred_element_type=dt))
    else:
        h = fn(h)
    return jnp.einsum("...f,fd->...d", h, p["down"].astype(dt),
                      preferred_element_type=dt)


# --------------------------------------------------------------------- #
# rotary position embeddings
# --------------------------------------------------------------------- #
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                        # (hd/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., : hd // 2], x[..., hd // 2:]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1).astype(dt)


# --------------------------------------------------------------------- #
# embeddings / LM head / losses
# --------------------------------------------------------------------- #
def init_embedding(ks, vocab: int, d_model: int, dtype: Any) -> Leaf:
    return mk(next(ks), (vocab, d_model), ("vocab", "embed"), dtype, scale=0.02)


def init_lm_head(ks, d_model: int, vocab: int, dtype: Any) -> Leaf:
    return mk(next(ks), (d_model, vocab), ("embed", "vocab"), dtype)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None) -> jax.Array:
    """Mean token CE in fp32.  logits (..., V); labels (...) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
