"""Mixture-of-Experts FFN: top-k token-choice routing with capacity-based
einsum dispatch (the TPU-native formulation — dense one-hot dispatch
matrices feed the MXU instead of GPU-style scatter/gather), plus always-on
shared experts (qwen2-moe) and an auxiliary load-balancing loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.context import constrain_batch

from .config import ModelConfig
from .layers import mk


def init_moe(ks, cfg: ModelConfig, stacked: int | None = None) -> dict:
    m = cfg.moe
    L = () if stacked is None else (stacked,)
    A = () if stacked is None else ("layers",)
    d, e, f = cfg.d_model, m.n_experts, m.d_expert
    dt = cfg.param_dtype
    p = {
        "router": mk(next(ks), (*L, d, e), (*A, "embed", None), dt, scale=0.02),
        "up": mk(next(ks), (*L, e, d, f), (*A, "experts", "embed", "mlp"), dt),
        "gate": mk(next(ks), (*L, e, d, f), (*A, "experts", "embed", "mlp"), dt),
        "down": mk(next(ks), (*L, e, f, d), (*A, "experts", "mlp", "embed"), dt),
    }
    if m.n_shared:
        p["shared_up"] = mk(next(ks), (*L, d, f * m.n_shared), (*A, "embed", "mlp"), dt)
        p["shared_gate"] = mk(next(ks), (*L, d, f * m.n_shared), (*A, "embed", "mlp"), dt)
        p["shared_down"] = mk(next(ks), (*L, f * m.n_shared, d), (*A, "mlp", "embed"), dt)
        p["shared_router"] = mk(next(ks), (*L, d, 1), (*A, "embed", None), dt, scale=0.02)
    return p


def moe_ffn(p: dict, cfg: ModelConfig, x: jax.Array
            ) -> tuple[jax.Array, jax.Array]:
    """x: (B,S,d) -> (out, aux_loss)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    logits = jnp.einsum("td,de->te", xt, p["router"].astype(cfg.dtype)
                        ).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                       # (T,E)
    gate_vals, expert_idx = jax.lax.top_k(probs, m.top_k)         # (T,K)
    if m.router_norm_topk:
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux load-balancing loss (Switch-style): E * sum_e f_e * p_e
    assign = jax.nn.one_hot(expert_idx, m.n_experts, dtype=jnp.float32)  # (T,K,E)
    frac_tokens = assign.sum(1).mean(0)                           # (E,)
    frac_probs = probs.mean(0)
    aux = m.n_experts * jnp.sum(frac_tokens * frac_probs)

    if m.dense_dispatch:
        # tiny configs / smoke tests: run every expert on every token
        h = jnp.einsum("td,edf->tef", xt, p["up"].astype(cfg.dtype))
        h = h * jax.nn.silu(jnp.einsum("td,edf->tef", xt, p["gate"].astype(cfg.dtype)))
        y_all = jnp.einsum("tef,efd->ted", h, p["down"].astype(cfg.dtype))
        combine = (assign * gate_vals[..., None]).sum(1)          # (T,E)
        y = jnp.einsum("te,ted->td", combine.astype(cfg.dtype), y_all)
    else:
        # GShard-style grouped capacity dispatch: tokens are split into
        # groups of ~group_size and capacity applies per group, keeping the
        # one-hot dispatch/combine tensors O(T * E * C_g) with C_g fixed.
        # Groups align with the DP sharding (row-major split of the sharded
        # token dim), so dispatch never crosses devices.
        Tg = min(m.group_size, T)
        while T % Tg:
            Tg -= 1
        G = T // Tg
        cap = int(m.capacity_factor * m.top_k * Tg / m.n_experts)
        cap = max(cap, m.top_k)
        # groups inherit the DP sharding of the token dim; asserting it
        # here stops GSPMD sharding the *within-group* token dim over the
        # model axis (verified: that choice all-reduces the full (E,C,d)
        # dispatch output per layer)
        xg = constrain_batch(xt.reshape(G, Tg, d), exact=True)
        assign_g = assign.reshape(G, Tg, m.top_k, m.n_experts)
        gates_g = gate_vals.reshape(G, Tg, m.top_k)

        def run_groups(xg, assign_g, gates_g):
            G_ = xg.shape[0]
            # position of each (token, k) in its expert's per-group buffer
            flat = assign_g.reshape(G_, Tg * m.top_k, m.n_experts)
            pos = (jnp.cumsum(flat, axis=1) - 1.0).reshape(
                G_, Tg, m.top_k, m.n_experts)
            keep = (pos < cap) & (assign_g > 0)                  # (G,Tg,K,E)
            pos_oh = jax.nn.one_hot(pos, cap, dtype=cfg.dtype) \
                * keep[..., None]
            dispatch = pos_oh.sum(2)                             # (G,Tg,E,C)
            combine = (pos_oh * gates_g.astype(cfg.dtype)[..., None, None]
                       ).sum(2)                                  # (G,Tg,E,C)
            xe = jnp.einsum("gtd,gtec->gecd", xg, dispatch)      # (G,E,C,d)
            h = jnp.einsum("gecd,edf->gecf", xe, p["up"].astype(cfg.dtype))
            h = h * jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe,
                                           p["gate"].astype(cfg.dtype)))
            ye = jnp.einsum("gecf,efd->gecd", h, p["down"].astype(cfg.dtype))
            return jnp.einsum("gtec,gecd->gtd", combine, ye)     # (G,Tg,d)

        ns = m.scan_groups
        if ns > 1 and G % ns == 0 and G // ns >= 1:
            # bound live dispatch buffers: strided split keeps each scan
            # step's group block sharded over the DP axis
            def resplit(t):
                return t.reshape(G // ns, ns, *t.shape[1:]).swapaxes(0, 1)

            def body(_, blk):
                xg_b, as_b, gt_b = blk
                return None, run_groups(constrain_batch(xg_b, exact=True),
                                        as_b, gt_b)

            _, y_blocks = jax.lax.scan(
                body, None, (resplit(xg), resplit(assign_g),
                             resplit(gates_g)))
            # y_blocks: (ns, G/ns, Tg, d) -> undo the strided split
            y = y_blocks.swapaxes(0, 1).reshape(T, d)
        else:
            y = run_groups(xg, assign_g, gates_g).reshape(T, d)

    if m.n_shared:
        sg = jax.nn.sigmoid(jnp.einsum(
            "td,do->to", xt, p["shared_router"].astype(cfg.dtype)).astype(jnp.float32))
        hs = jnp.einsum("td,df->tf", xt, p["shared_up"].astype(cfg.dtype))
        hs = hs * jax.nn.silu(jnp.einsum("td,df->tf", xt, p["shared_gate"].astype(cfg.dtype)))
        ys = jnp.einsum("tf,fd->td", hs, p["shared_down"].astype(cfg.dtype))
        y = y + ys * sg.astype(cfg.dtype)

    return y.reshape(B, S, d), aux
