"""RWKV6 "Finch" block (Peng et al. 2024, arXiv:2404.05892).

Linear attention with *data-dependent per-channel decay*:
    S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t
    o_t = r_t · (diag(u) k_t ⊗ v_t + S_{t-1})
Sequence path uses a chunked closed form (attention-like intra-chunk
matmuls + short scan over chunk states) mirroring the SSD layout, so the
same Pallas kernel skeleton applies (``repro.kernels.rwkv6_scan``).

Includes token-shift for the time-mix and the RWKV channel-mix FFN is the
standard MLP of the stack (d_ff given by the assigned config).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import mk, rmsnorm


def _dims(cfg: ModelConfig) -> tuple[int, int]:
    hd = cfg.rwkv.head_dim
    nh = cfg.d_model // hd
    return nh, hd


def init_rwkv6(ks, cfg: ModelConfig, stacked: int | None = None) -> dict:
    nh, hd = _dims(cfg)
    L = () if stacked is None else (stacked,)
    A = () if stacked is None else ("layers",)
    d, dt = cfg.d_model, cfg.param_dtype
    r = cfg.rwkv.decay_lora
    return {
        "mix_r": mk(next(ks), (*L, d), (*A, "embed"), dt, init="zeros"),
        "mix_k": mk(next(ks), (*L, d), (*A, "embed"), dt, init="zeros"),
        "mix_v": mk(next(ks), (*L, d), (*A, "embed"), dt, init="zeros"),
        "mix_w": mk(next(ks), (*L, d), (*A, "embed"), dt, init="zeros"),
        "mix_g": mk(next(ks), (*L, d), (*A, "embed"), dt, init="zeros"),
        "wr": mk(next(ks), (*L, d, nh, hd), (*A, "embed", "heads", "head_dim"), dt),
        "wk": mk(next(ks), (*L, d, nh, hd), (*A, "embed", "heads", "head_dim"), dt),
        "wv": mk(next(ks), (*L, d, nh, hd), (*A, "embed", "heads", "head_dim"), dt),
        "wg": mk(next(ks), (*L, d, d), (*A, "embed", "embed"), dt),
        # data-dependent decay: w_t = exp(-exp(w0 + (x W_a) W_b))
        "w0": mk(next(ks), (*L, nh, hd), (*A, "heads", "head_dim"), dt, init="zeros"),
        "wa": mk(next(ks), (*L, d, r), (*A, "embed", None), dt, scale=0.02),
        "wb": mk(next(ks), (*L, r, nh, hd), (*A, None, "heads", "head_dim"), dt,
                 scale=0.02),
        "u": mk(next(ks), (*L, nh, hd), (*A, "heads", "head_dim"), dt, init="zeros"),
        "ln_x": mk(next(ks), (*L, d), (*A, "embed"), dt, init="ones"),
        "out": mk(next(ks), (*L, d, d), (*A, "embed", "embed"), dt),
    }


def _token_shift(x: jax.Array, prev: jax.Array | None = None) -> jax.Array:
    """x_{t-1} stream.  prev: (B,1,d) carry for decode; zeros at t=0."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def wkv6_chunked(r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
                 u: jax.Array, chunk: int, S0: jax.Array | None = None
                 ) -> tuple[jax.Array, jax.Array]:
    """Chunked WKV6.

    r,k,v: (b,S,nh,hd); logw: (b,S,nh,hd) (negative log-decays);
    u: (nh,hd).  Returns (o (b,S,nh,hd), S_final (b,nh,hd,hd)).

    Closed form: o_t = Σ_{s<t} (r_t ⊙ exp(W_{t-1}-W_s)) · k_s  v_s
                      + (r_t ⊙ u) · k_t  v_t
    with W the inclusive cumsum of logw along time.
    """
    b, S, nh, hd = r.shape
    Q = min(chunk, S)
    nchunk = S // Q
    assert S % Q == 0

    def rs(t):
        return t.reshape(b, nchunk, Q, nh, hd)

    rc, kc, vc = rs(r), rs(k), rs(v)
    lw = rs(logw.astype(jnp.float32))
    cum = jnp.cumsum(lw, axis=2)                              # (b,n,Q,nh,hd)

    # intra-chunk: pairs (t, s) with s < t ; decay exp(W_{t-1} - W_s)
    dec_t = cum - lw                                          # W_{t-1} (exclusive)
    expo = dec_t[:, :, :, None] - cum[:, :, None, :, :]       # (b,n,t,s,nh,hd)
    strict = jnp.tril(jnp.ones((Q, Q), bool), k=-1)[None, None, :, :, None, None]
    rdec = rc.astype(jnp.float32)[:, :, :, None] * jnp.exp(
        jnp.where(strict, expo, -jnp.inf))                    # (b,n,t,s,nh,hd)
    scores = jnp.einsum("bntshd,bnshd->bnths", rdec,
                        kc.astype(jnp.float32))               # (b,n,t,nh,s)
    y_intra = jnp.einsum("bnths,bnshd->bnthd", scores.astype(r.dtype), vc)
    # diagonal bonus term
    diag = jnp.einsum("bnthd,bnthd->bnth", rc * u.astype(r.dtype), kc)
    y_intra = y_intra + diag[..., None] * vc

    # chunk summaries: S_i = Σ_s exp(W_Q - W_s) k_s ⊗ v_s ; carry scan
    tail = cum[:, :, -1:] - cum                               # (b,n,Q,nh,hd)
    Sc = jnp.einsum("bnshd,bnshe->bnhde",
                    kc.astype(jnp.float32) * jnp.exp(tail), vc.astype(jnp.float32))
    gamma = jnp.exp(cum[:, :, -1])                            # (b,n,nh,hd)

    S_init = jnp.zeros((b, nh, hd, hd), jnp.float32) if S0 is None \
        else S0.astype(jnp.float32)

    def step(Sst, inp):
        S_i, g_i = inp
        return Sst * g_i[..., None] + S_i, Sst                # emit entering state

    S_fin, S_enter = jax.lax.scan(
        step, S_init, (jnp.moveaxis(Sc, 1, 0), jnp.moveaxis(gamma, 1, 0)))
    S_enter = jnp.moveaxis(S_enter, 0, 1)                     # (b,n,nh,hd,hd)

    # inter-chunk: o_t += (r_t ⊙ exp(W_{t-1})) · S_enter
    y_inter = jnp.einsum("bnthd,bnhde->bnthe",
                         rc.astype(jnp.float32) * jnp.exp(dec_t), S_enter)
    y = (y_intra + y_inter.astype(r.dtype)).reshape(b, S, nh, hd)
    return y, S_fin


def wkv6_step(r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
              u: jax.Array, S: jax.Array) -> tuple[jax.Array, jax.Array]:
    """One token.  r,k,v,logw: (b,nh,hd); S: (b,nh,hd,hd)."""
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    kv = jnp.einsum("bhd,bhe->bhde", kf, vf)
    o = jnp.einsum("bhd,bhde->bhe", rf, S + u.astype(jnp.float32)[None, :, :, None] * kv)
    S = S * jnp.exp(logw.astype(jnp.float32))[..., None] + kv
    return o.astype(r.dtype), S


def _mix(x: jax.Array, xs: jax.Array, mu: jax.Array) -> jax.Array:
    return x + (xs - x) * mu


def rwkv6_seq(p: dict, cfg: ModelConfig, x: jax.Array,
              shift_prev: jax.Array | None = None,
              S0: jax.Array | None = None, return_state: bool = False):
    """Full-sequence RWKV6 time-mix.  x: (B,S,d)."""
    nh, hd = _dims(cfg)
    xs = _token_shift(x, shift_prev)
    xr = _mix(x, xs, p["mix_r"].astype(cfg.dtype))
    xk = _mix(x, xs, p["mix_k"].astype(cfg.dtype))
    xv = _mix(x, xs, p["mix_v"].astype(cfg.dtype))
    xw = _mix(x, xs, p["mix_w"].astype(cfg.dtype))
    xg = _mix(x, xs, p["mix_g"].astype(cfg.dtype))

    r = jnp.einsum("bsd,dhk->bshk", xr, p["wr"].astype(cfg.dtype))
    k = jnp.einsum("bsd,dhk->bshk", xk, p["wk"].astype(cfg.dtype))
    v = jnp.einsum("bsd,dhk->bshk", xv, p["wv"].astype(cfg.dtype))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"].astype(cfg.dtype)))

    # data-dependent decay (negative log)
    lora = jnp.einsum("bsd,dr->bsr", jnp.tanh(xw), p["wa"].astype(cfg.dtype))
    wraw = p["w0"].astype(jnp.float32) + jnp.einsum(
        "bsr,rhk->bshk", lora, p["wb"].astype(cfg.dtype)).astype(jnp.float32)
    logw = -jnp.exp(-0.5 + wraw)            # in (-inf, 0)

    if cfg.ssm_impl == "pallas":
        from repro.kernels.rwkv6_scan import ops as wkv_ops
        o, S_fin = wkv_ops.wkv6(r, k, v, logw.astype(cfg.dtype),
                                p["u"].astype(cfg.dtype),
                                chunk=cfg.ssm.chunk if cfg.ssm else 64, S0=S0)
    else:
        o, S_fin = wkv6_chunked(r, k, v, logw, p["u"],
                                chunk=cfg.ssm.chunk if cfg.ssm else 64, S0=S0)
    o = o.reshape(*x.shape[:2], cfg.d_model)
    o = rmsnorm(o, p["ln_x"], cfg.norm_eps) * g
    out = jnp.einsum("bsd,de->bse", o, p["out"].astype(cfg.dtype))
    if return_state:
        return out, (x[:, -1:], S_fin)
    return out


def init_channel_mix(ks, cfg: ModelConfig, stacked: int | None = None) -> dict:
    """RWKV channel-mix (the FFN of the RWKV stack):
    out = sigmoid(x_r W_r) * (relu(x_k W_k)^2 W_v)."""
    L = () if stacked is None else (stacked,)
    A = () if stacked is None else ("layers",)
    d, f, dt = cfg.d_model, cfg.d_ff, cfg.param_dtype
    return {
        "mix_k": mk(next(ks), (*L, d), (*A, "embed"), dt, init="zeros"),
        "mix_r": mk(next(ks), (*L, d), (*A, "embed"), dt, init="zeros"),
        "wk": mk(next(ks), (*L, d, f), (*A, "embed", "mlp"), dt),
        "wv": mk(next(ks), (*L, f, d), (*A, "mlp", "embed"), dt),
        "wr": mk(next(ks), (*L, d, d), (*A, "embed", "embed"), dt),
    }


def channel_mix(p: dict, cfg: ModelConfig, x: jax.Array,
                shift_prev: jax.Array | None = None) -> jax.Array:
    xs = _token_shift(x, shift_prev)
    xk = _mix(x, xs, p["mix_k"].astype(cfg.dtype))
    xr = _mix(x, xs, p["mix_r"].astype(cfg.dtype))
    k = jnp.einsum("bsd,df->bsf", xk, p["wk"].astype(cfg.dtype))
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("bsf,fd->bsd", k, p["wv"].astype(cfg.dtype))
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"].astype(cfg.dtype)))
    return r * kv


def channel_mix_decode(p: dict, cfg: ModelConfig, x: jax.Array,
                       shift_prev: jax.Array) -> tuple[jax.Array, jax.Array]:
    out = channel_mix(p, cfg, x, shift_prev)
    return out, x                   # new shift carry


def init_rwkv6_state(cfg: ModelConfig, batch: int, abstract: bool = False,
                     stacked: int | None = None) -> dict:
    from .layers import Leaf
    nh, hd = _dims(cfg)
    L = () if stacked is None else (stacked,)
    A = () if stacked is None else ("layers",)
    sh_S = (*L, batch, nh, hd, hd)
    ax_S = (*A, "batch", "heads", None, None)
    sh_x = (*L, batch, 1, cfg.d_model)
    ax_x = (*A, "batch", None, "embed")
    if abstract:
        x = jax.ShapeDtypeStruct(sh_x, cfg.dtype)
        return {"S": Leaf(jax.ShapeDtypeStruct(sh_S, jnp.float32), ax_S),
                "shift_t": Leaf(x, ax_x), "shift_c": Leaf(x, ax_x)}
    return {"S": Leaf(jnp.zeros(sh_S, jnp.float32), ax_S),
            "shift_t": Leaf(jnp.zeros(sh_x, cfg.dtype), ax_x),
            "shift_c": Leaf(jnp.zeros(sh_x, cfg.dtype), ax_x)}


def rwkv6_decode(p: dict, cfg: ModelConfig, x: jax.Array, state: dict
                 ) -> tuple[jax.Array, dict]:
    """One-token decode.  x: (B,1,d); state: {"S","shift"}."""
    nh, hd = _dims(cfg)
    xs = state["shift"]
    xr = _mix(x, xs, p["mix_r"].astype(cfg.dtype))
    xk = _mix(x, xs, p["mix_k"].astype(cfg.dtype))
    xv = _mix(x, xs, p["mix_v"].astype(cfg.dtype))
    xw = _mix(x, xs, p["mix_w"].astype(cfg.dtype))
    xg = _mix(x, xs, p["mix_g"].astype(cfg.dtype))

    r = jnp.einsum("bsd,dhk->bshk", xr, p["wr"].astype(cfg.dtype))[:, 0]
    k = jnp.einsum("bsd,dhk->bshk", xk, p["wk"].astype(cfg.dtype))[:, 0]
    v = jnp.einsum("bsd,dhk->bshk", xv, p["wv"].astype(cfg.dtype))[:, 0]
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"].astype(cfg.dtype)))

    lora = jnp.einsum("bsd,dr->bsr", jnp.tanh(xw), p["wa"].astype(cfg.dtype))
    wraw = p["w0"].astype(jnp.float32) + jnp.einsum(
        "bsr,rhk->bshk", lora, p["wb"].astype(cfg.dtype)).astype(jnp.float32)
    logw = -jnp.exp(-0.5 + wraw)[:, 0]

    o, S = wkv6_step(r, k, v, logw, p["u"], state["S"])
    o = o.reshape(x.shape[0], 1, cfg.d_model)
    o = rmsnorm(o, p["ln_x"], cfg.norm_eps) * g
    return (jnp.einsum("bsd,de->bse", o, p["out"].astype(cfg.dtype)),
            {"S": S, "shift": x})
