"""Mamba2 / SSD block (Dao & Gu 2024), TPU-adapted.

The sequence path uses the *chunked SSD algorithm* — intra-chunk work is
attention-like matmuls (MXU-friendly), inter-chunk state flows through a
short ``lax.scan`` over chunks.  This is both the faithful algorithm and
what we kernelize in Pallas (``repro.kernels.mamba2_ssd``).

Decode keeps O(1) state per layer: the SSM state (B,nh,hd,d_state) plus a
(d_conv-1)-deep causal-conv tail — this is why the hybrid/ssm archs run the
``long_500k`` shape that dense attention cannot.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import mk, rmsnorm


def _dims(cfg: ModelConfig) -> tuple[int, int, int, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads, s.head_dim, s.d_state


def init_mamba2(ks, cfg: ModelConfig, stacked: int | None = None) -> dict:
    s = cfg.ssm
    d_inner, nh, hd, ds = _dims(cfg)
    d_xbc = d_inner + 2 * ds                     # conv runs over [x, B, C]
    L = () if stacked is None else (stacked,)
    A = () if stacked is None else ("layers",)
    d, dt = cfg.d_model, cfg.param_dtype
    return {
        # projections: z (gate), x, B, C, dt
        "in_z": mk(next(ks), (*L, d, d_inner), (*A, "embed", "mlp"), dt),
        "in_x": mk(next(ks), (*L, d, d_inner), (*A, "embed", "mlp"), dt),
        "in_b": mk(next(ks), (*L, d, ds), (*A, "embed", None), dt),
        "in_c": mk(next(ks), (*L, d, ds), (*A, "embed", None), dt),
        "in_dt": mk(next(ks), (*L, d, nh), (*A, "embed", "heads"), dt),
        "dt_bias": mk(next(ks), (*L, nh), (*A, "heads"), dt, init="zeros"),
        "conv_w": mk(next(ks), (*L, s.d_conv, d_xbc), (*A, None, "mlp"), dt,
                     scale=0.5),
        "conv_b": mk(next(ks), (*L, d_xbc), (*A, "mlp"), dt, init="zeros"),
        "a_log": mk(next(ks), (*L, nh), (*A, "heads"), dt, init="zeros"),
        "d_skip": mk(next(ks), (*L, nh), (*A, "heads"), dt, init="ones"),
        "norm": mk(next(ks), (*L, d_inner), (*A, "mlp"), dt, init="ones"),
        "out": mk(next(ks), (*L, d_inner, d), (*A, "mlp", "embed"), dt),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 tail: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv.  xbc: (B,S,D); w: (K,D); tail: (B,K-1,D)."""
    K = w.shape[0]
    pad = jnp.zeros((xbc.shape[0], K - 1, xbc.shape[2]), xbc.dtype) \
        if tail is None else tail
    xp = jnp.concatenate([pad, xbc], axis=1)                 # (B, S+K-1, D)
    out = sum(xp[:, i: i + xbc.shape[1]] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def ssd_chunked(x: jax.Array, dt: jax.Array, a_log: jax.Array,
                B: jax.Array, C: jax.Array, chunk: int,
                h0: jax.Array | None = None
                ) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x: (b,S,nh,hd); dt: (b,S,nh); a_log: (nh,); B,C: (b,S,ds).
    Returns (y (b,S,nh,hd), h_final (b,nh,hd,ds)).
    """
    b, S, nh, hd = x.shape
    ds = B.shape[-1]
    Q = min(chunk, S)
    nchunk = S // Q
    assert S % Q == 0, f"seq {S} not divisible by chunk {Q}"

    A = -jnp.exp(a_log.astype(jnp.float32))                   # (nh,) negative
    dtf = dt.astype(jnp.float32)
    lax_ = dtf * A                                            # (b,S,nh) log-decay
    xw = (x * dt[..., None]).astype(x.dtype)                  # dt-weighted input

    def rs(t, *shape):
        return t.reshape(b, nchunk, Q, *shape)

    xc, lc = rs(xw, nh, hd), rs(lax_, nh)
    Bc, Cc = rs(B, ds), rs(C, ds)
    cum = jnp.cumsum(lc, axis=2)                              # (b,n,Q,nh)

    # --- intra-chunk (attention-like, causal) --------------------------
    # M[t,s] = (C_t . B_s) * exp(cum_t - cum_s) for s <= t
    scores = jnp.einsum("bnts,bnqs->bntq", Cc, Bc)            # (b,n,Q,Q) t,q=src
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # (b,n,Q,Q,nh)
    causal = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    # mask inside the exp argument: exp of the dead (t<s) branch would be
    # +inf and poison gradients through jnp.where
    M = jnp.exp(jnp.where(causal, decay, -jnp.inf)) * scores[..., None]
    y_intra = jnp.einsum("bntqh,bnqhd->bnthd", M.astype(x.dtype), xc)

    # --- chunk summaries -> inter-chunk scan ---------------------------
    tail = cum[:, :, -1:, :] - cum                            # exp to chunk end
    Sc = jnp.einsum("bnqs,bnqhd->bnhds", Bc.astype(jnp.float32),
                    xc.astype(jnp.float32) * jnp.exp(tail)[..., None])
    gamma = jnp.exp(cum[:, :, -1, :])                         # (b,n,nh)

    h_init = jnp.zeros((b, nh, hd, ds), jnp.float32) if h0 is None \
        else h0.astype(jnp.float32)

    def step(h, inp):
        S_i, g_i = inp                                        # (b,nh,hd,ds),(b,nh)
        h_new = h * g_i[:, :, None, None] + S_i
        return h_new, h                                       # emit state *entering* chunk

    Sc_t = jnp.moveaxis(Sc, 1, 0)                             # (n,b,nh,hd,ds)
    g_t = jnp.moveaxis(gamma, 1, 0)                           # (n,b,nh)
    h_fin, h_enter = jax.lax.scan(step, h_init, (Sc_t, g_t))
    h_enter = jnp.moveaxis(h_enter, 0, 1)                     # (b,n,nh,hd,ds)

    # --- inter-chunk contribution --------------------------------------
    y_inter = jnp.einsum("bnts,bnhds,bnth->bnthd",
                         Cc.astype(jnp.float32), h_enter,
                         jnp.exp(cum)).astype(x.dtype)

    y = (y_intra + y_inter).reshape(b, S, nh, hd)
    return y, h_fin


def ssd_step(x: jax.Array, dt: jax.Array, a_log: jax.Array,
             B: jax.Array, C: jax.Array, h: jax.Array
             ) -> tuple[jax.Array, jax.Array]:
    """Single-token recurrence.  x: (b,nh,hd); dt: (b,nh); B,C: (b,ds);
    h: (b,nh,hd,ds)."""
    A = -jnp.exp(a_log.astype(jnp.float32))
    g = jnp.exp(dt.astype(jnp.float32) * A)                   # (b,nh)
    upd = jnp.einsum("bhd,bs->bhds", (x * dt[..., None]).astype(jnp.float32),
                     B.astype(jnp.float32))
    h = h * g[:, :, None, None] + upd
    y = jnp.einsum("bhds,bs->bhd", h, C.astype(jnp.float32))
    return y.astype(x.dtype), h


def mamba2_seq(p: dict, cfg: ModelConfig, u: jax.Array) -> jax.Array:
    """Full-sequence Mamba2 block.  u: (B,S,d_model)."""
    s = cfg.ssm
    d_inner, nh, hd, ds = _dims(cfg)
    z = jnp.einsum("bsd,de->bse", u, p["in_z"].astype(cfg.dtype))
    xb = jnp.einsum("bsd,de->bse", u, p["in_x"].astype(cfg.dtype))
    Bv = jnp.einsum("bsd,de->bse", u, p["in_b"].astype(cfg.dtype))
    Cv = jnp.einsum("bsd,de->bse", u, p["in_c"].astype(cfg.dtype))
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", u, p["in_dt"].astype(cfg.dtype)
                   ).astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

    xbc = jnp.concatenate([xb, Bv, Cv], axis=-1)
    xbc = _causal_conv(xbc, p["conv_w"].astype(cfg.dtype),
                       p["conv_b"].astype(cfg.dtype))
    xb, Bv, Cv = jnp.split(xbc, [d_inner, d_inner + ds], axis=-1)

    xh = xb.reshape(*xb.shape[:2], nh, hd)
    if cfg.ssm_impl == "pallas":
        from repro.kernels.mamba2_ssd import ops as ssd_ops
        y, _ = ssd_ops.ssd(xh, dt.astype(cfg.dtype), p["a_log"], Bv, Cv,
                           chunk=s.chunk)
    else:
        y, _ = ssd_chunked(xh, dt.astype(cfg.dtype), p["a_log"], Bv, Cv,
                           chunk=s.chunk)
    y = y + xh * p["d_skip"].astype(cfg.dtype)[:, None]
    y = y.reshape(*u.shape[:2], d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, p["out"].astype(cfg.dtype))


def init_mamba2_state(cfg: ModelConfig, batch: int, abstract: bool = False,
                      stacked: int | None = None) -> dict:
    from .layers import Leaf
    s = cfg.ssm
    d_inner, nh, hd, ds = _dims(cfg)
    d_xbc = d_inner + 2 * ds
    L = () if stacked is None else (stacked,)
    A = () if stacked is None else ("layers",)
    sh_h = (*L, batch, nh, hd, ds)
    ax_h = (*A, "batch", "heads", None, None)
    sh_c = (*L, batch, s.d_conv - 1, d_xbc)
    ax_c = (*A, "batch", None, "mlp")
    if abstract:
        return {"h": Leaf(jax.ShapeDtypeStruct(sh_h, jnp.float32), ax_h),
                "conv": Leaf(jax.ShapeDtypeStruct(sh_c, cfg.dtype), ax_c)}
    return {"h": Leaf(jnp.zeros(sh_h, jnp.float32), ax_h),
            "conv": Leaf(jnp.zeros(sh_c, cfg.dtype), ax_c)}


def mamba2_decode(p: dict, cfg: ModelConfig, u: jax.Array,
                  state: dict) -> tuple[jax.Array, dict]:
    """One-token decode.  u: (B,1,d_model); state: {"h","conv"}."""
    d_inner, nh, hd, ds = _dims(cfg)
    z = jnp.einsum("bsd,de->bse", u, p["in_z"].astype(cfg.dtype))
    xb = jnp.einsum("bsd,de->bse", u, p["in_x"].astype(cfg.dtype))
    Bv = jnp.einsum("bsd,de->bse", u, p["in_b"].astype(cfg.dtype))
    Cv = jnp.einsum("bsd,de->bse", u, p["in_c"].astype(cfg.dtype))
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", u, p["in_dt"].astype(cfg.dtype)
                   ).astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

    xbc = jnp.concatenate([xb, Bv, Cv], axis=-1)              # (B,1,d_xbc)
    conv_in = jnp.concatenate([state["conv"], xbc], axis=1)   # (B,K,d_xbc)
    w, b = p["conv_w"].astype(cfg.dtype), p["conv_b"].astype(cfg.dtype)
    out = jax.nn.silu((conv_in * w[None]).sum(1) + b)[:, None]  # (B,1,d_xbc)
    new_conv = conv_in[:, 1:]
    xb, Bv, Cv = jnp.split(out, [d_inner, d_inner + ds], axis=-1)

    xh = xb[:, 0].reshape(-1, nh, hd)
    y, h = ssd_step(xh, dt[:, 0].astype(cfg.dtype), p["a_log"],
                    Bv[:, 0], Cv[:, 0], state["h"])
    y = y + xh * p["d_skip"].astype(cfg.dtype)[:, None]
    y = y.reshape(u.shape[0], 1, d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return (jnp.einsum("bse,ed->bsd", y, p["out"].astype(cfg.dtype)),
            {"h": h, "conv": new_conv})
