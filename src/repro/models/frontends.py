"""Modality frontends — STUBS by assignment.

The [audio]/[vlm] archs specify the transformer BACKBONE only; the modality
frontend provides precomputed frame/patch embeddings via ``input_specs()``.
Here we keep only the thin trainable adapters that map precomputed features
into the backbone width (HuBERT's conv feature extractor and Pixtral's ViT
run upstream and are not part of the assigned configs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import mk


def init_audio_frontend(ks, cfg: ModelConfig) -> dict:
    """HuBERT-style: precomputed conv features (B,S,frontend_dim) -> d_model,
    plus the learned [MASK] frame embedding for masked prediction."""
    dt = cfg.param_dtype
    return {
        "proj": mk(next(ks), (cfg.frontend_dim, cfg.d_model), (None, "embed"), dt),
        "proj_b": mk(next(ks), (cfg.d_model,), ("embed",), dt, init="zeros"),
        "mask_emb": mk(next(ks), (cfg.d_model,), ("embed",), dt, scale=0.02),
    }


def audio_frontend(p: dict, cfg: ModelConfig, features: jax.Array,
                   mask: jax.Array | None) -> jax.Array:
    """features: (B,S,frontend_dim); mask: (B,S) bool — True = masked frame."""
    x = jnp.einsum("bsf,fd->bsd", features.astype(cfg.dtype),
                   p["proj"].astype(cfg.dtype)) + p["proj_b"].astype(cfg.dtype)
    if mask is not None:
        x = jnp.where(mask[..., None], p["mask_emb"].astype(cfg.dtype), x)
    return x


def init_vision_adapter(ks, cfg: ModelConfig) -> dict:
    """Pixtral-style: precomputed patch embeddings -> backbone width."""
    dt = cfg.param_dtype
    return {
        "proj": mk(next(ks), (cfg.frontend_dim, cfg.d_model), (None, "embed"), dt),
        "proj_b": mk(next(ks), (cfg.d_model,), ("embed",), dt, init="zeros"),
    }


def vision_adapter(p: dict, cfg: ModelConfig, patches: jax.Array) -> jax.Array:
    return jnp.einsum("bsf,fd->bsd", patches.astype(cfg.dtype),
                      p["proj"].astype(cfg.dtype)) + p["proj_b"].astype(cfg.dtype)
