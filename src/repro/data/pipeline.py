"""Deterministic synthetic LM data pipeline.

Design goals mirrored from production input pipelines:
  * **host-sharded**: each host materializes only its slice of the global
    batch (``host_index / host_count``), sized for its addressable devices;
  * **deterministic & resumable**: batch ``i`` is a pure function of
    ``(seed, i)`` — restart at step ``k`` reproduces the exact stream, so a
    checkpoint restore replays no data and skips none;
  * **model-aware**: emits token, audio-frame, or vision-patch batches per
    the arch's ``input_specs`` contract.

The synthetic distribution is a Zipf-like unigram mix with a Markov blend,
enough structure that a ~100M model shows a cleanly decreasing loss (used
by ``examples/train_e2e.py`` and the HOPAAS study objective).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.host_count == 0
        return self.global_batch // self.host_count


class SyntheticLMDataset:
    """Stateless batch factory: ``batch = ds[i]``."""

    def __init__(self, cfg: DataConfig, model_cfg: ModelConfig):
        self.cfg = cfg
        self.mcfg = model_cfg
        v = model_cfg.vocab_size
        rng = np.random.default_rng(cfg.seed)
        # fixed unigram (Zipf) + per-token Markov shift, shared across hosts
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self._unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._shift = rng.integers(1, v, size=257)           # Markov jumps

    def __getitem__(self, index: int) -> dict:
        c, m = self.cfg, self.mcfg
        rng = np.random.default_rng(
            (c.seed * 1_000_003 + index) * 1_000_033 + c.host_index)
        B, S, V = c.host_batch, c.seq_len, m.vocab_size

        if m.frontend == "audio":
            feats = rng.standard_normal((B, S, m.frontend_dim),
                                        dtype=np.float32)
            mask = rng.random((B, S)) < 0.3
            labels = rng.integers(0, V, size=(B, S), dtype=np.int32)
            return {"features": feats, "frame_mask": mask, "labels": labels}

        toks = rng.choice(V, size=(B, S + 1), p=self._unigram).astype(np.int32)
        # Markov blend: half the tokens continue deterministically
        cont = rng.random((B, S)) < 0.5
        nxt = (toks[:, :-1] + self._shift[toks[:, :-1] % 257]) % V
        toks[:, 1:] = np.where(cont, nxt, toks[:, 1:])
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if m.frontend == "vision":
            from repro.configs.pixtral_12b import N_PATCHES
            batch["patch_embeds"] = rng.standard_normal(
                (B, N_PATCHES, m.frontend_dim)).astype(np.float32)
        return batch

    def iter_from(self, start: int):
        i = start
        while True:
            yield i, self[i]
            i += 1


def make_batch_specs(model_cfg: ModelConfig, global_batch: int, seq_len: int,
                     dtype=jnp.int32) -> dict:
    """ShapeDtypeStruct stand-ins for one *global* batch (dry-run input)."""
    m = model_cfg
    if m.frontend == "audio":
        return {
            "features": jax.ShapeDtypeStruct(
                (global_batch, seq_len, m.frontend_dim), jnp.float32),
            "frame_mask": jax.ShapeDtypeStruct((global_batch, seq_len), bool),
            "labels": jax.ShapeDtypeStruct((global_batch, seq_len), dtype),
        }
    specs = {"tokens": jax.ShapeDtypeStruct((global_batch, seq_len), dtype),
             "labels": jax.ShapeDtypeStruct((global_batch, seq_len), dtype)}
    if m.frontend == "vision":
        from repro.configs.pixtral_12b import N_PATCHES
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (global_batch, N_PATCHES, m.frontend_dim), jnp.float32)
    return specs
