"""The distributed train step.

One jitted function per (arch, mesh): microbatched gradient accumulation
via ``lax.scan`` (activation working set = one microbatch x one layer,
thanks to per-layer remat inside the model), AdamW update fused in.  All
distribution is GSPMD: the batch enters sharded over the DP axes, params
enter FSDP+TP-sharded, and XLA inserts the reduce-scatters/all-gathers.
Gradient accumulation happens in the *sharded* parameter layout, so the
accumulator costs 1/|data| of the fp32 gradient per device.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.dist import sharding as shd
from repro.optim import AdamWConfig, adamw_init, adamw_update, opt_state_specs


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any

    def tree(self) -> dict:
        return {"params": self.params, "opt_state": self.opt_state}


def init_train_state(cfg: ModelConfig, opt_cfg: AdamWConfig,
                     key: jax.Array | None) -> tuple[TrainState, Any]:
    """``key=None`` -> abstract state (dry-run).  Returns (state, specs)."""
    params, pspecs = transformer.init_params(cfg, key)
    opt = adamw_init(params, opt_cfg, abstract=key is None)
    return (TrainState(params, opt),
            {"params": pspecs, "opt_state": opt_state_specs(pspecs)})


def train_state_shardings(specs: Any, state_tree: Any, mesh, rules):
    return shd.tree_shardings(specs, state_tree, mesh, rules)


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    n_microbatches: int = 1,
                    batch_axis: Any = None,
                    grad_shardings: Any = None) -> Callable:
    """-> train_step(state_tree, batch) -> (state_tree, metrics).

    ``batch_axis``: mesh axis (or tuple) the batch dim is sharded over —
    re-asserted on every microbatch inside the accumulation loop, since
    the strided reshape feeding ``lax.scan`` otherwise lets GSPMD drop
    the DP sharding and replicate activations (verified: 16x activation
    blow-up without the constraint).

    ``grad_shardings``: per-param shardings asserted on each microbatch's
    gradients — turns the cross-replica gradient reduction into
    reduce-scatters landing directly in the FSDP/TP shards instead of
    full all-reduces followed by slicing (half the bytes)."""

    def constrain_grads(g):
        if grad_shardings is None:
            return g
        return jax.tree.map(
            lambda t, s: jax.lax.with_sharding_constraint(t, s), g,
            grad_shardings)

    def constrain_mb(mb):
        if batch_axis is None:
            return mb
        from jax.sharding import PartitionSpec as P
        return jax.tree.map(
            lambda t: jax.lax.with_sharding_constraint(
                t, P(batch_axis, *(None,) * (t.ndim - 1))), mb)

    def loss_of(params, batch):
        loss, parts = transformer.loss_fn(params, cfg, batch)
        return loss, parts

    def cast_weights(params):
        """f32 masters -> one sharded bf16 copy per step, BEFORE the FSDP
        all-gathers: the gathers then move 2x fewer bytes and the
        per-layer-per-microbatch convert disappears (XLA-CPU otherwise
        gathers f32 and converts after — verified 2x collective bytes).
        Matmul weights only; norms/scalars stay f32."""
        return jax.tree.map(
            lambda p: p.astype(cfg.dtype)
            if (p.dtype == jnp.float32 and p.ndim >= 2) else p, params)

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        params, opt_state = state["params"], state["opt_state"]
        grad_fn = jax.value_and_grad(
            lambda pc, mb: loss_of(pc, mb), has_aux=True)

        params_c = cast_weights(params)
        if n_microbatches == 1:
            (loss, parts), grads = grad_fn(params_c, batch)
            grads = constrain_grads(grads)
        else:
            def resplit(x):          # (B, ...) -> (n_micro, B/n_micro, ...)
                # strided split: microbatch j takes rows {j, n+j, 2n+j, ...}
                # so the *inner* batch dim keeps the DP sharding (a plain
                # leading reshape would give each microbatch to one device)
                B = x.shape[0]
                assert B % n_microbatches == 0, (B, n_microbatches)
                return x.reshape(B // n_microbatches, n_microbatches,
                                 *x.shape[1:]).swapaxes(0, 1)
            micro = jax.tree.map(resplit, batch)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, mb):
                g_acc, loss_acc = carry
                (l, _), g = grad_fn(params_c, constrain_mb(mb))
                g = constrain_grads(g)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, loss_acc + l), None

            (grads, loss_sum), _ = jax.lax.scan(
                body, (g0, jnp.float32(0.0)), micro)
            inv = 1.0 / n_microbatches
            grads = jax.tree.map(lambda g: g * inv, grads)
            loss = loss_sum * inv
            parts = {"ce": loss, "moe_aux": jnp.float32(0.0)}

        new_params, new_opt, opt_metrics = adamw_update(
            grads, opt_state, params, opt_cfg)
        metrics = {"loss": loss.astype(jnp.float32), **opt_metrics,
                   **{k: v.astype(jnp.float32) for k, v in parts.items()}}
        return {"params": new_params, "opt_state": new_opt}, metrics

    return train_step
