from .step import TrainState, make_train_step, train_state_shardings
from .trainer import Trainer, TrainerConfig

__all__ = ["TrainState", "make_train_step", "train_state_shardings",
           "Trainer", "TrainerConfig"]
