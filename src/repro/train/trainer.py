"""The trainer: the HOPAAS *client workload* (paper sec. 4).

Wires together model init, the jitted train step, the deterministic data
pipeline, checkpoint/restart, and — the paper's integration point — the
HOPAAS ``should_prune`` hook: the trainer reports its loss every
``report_every`` steps and aborts when the service says so.  This is
exactly the "thinnest possible layer in the model training application"
the paper argues for: one callback.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLMDataset
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig
from repro.train.step import init_train_state, make_train_step

# report(step, loss) -> True means "prune me" (wired to Trial.should_prune)
ReportFn = Callable[[int, float], bool]


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    microbatches: int = 1
    report_every: int = 10
    checkpoint_every: int = 0           # 0 = disabled
    checkpoint_dir: str | None = None
    keep_checkpoints: int = 3
    seed: int = 0
    log_every: int = 0


@dataclasses.dataclass
class TrainResult:
    final_loss: float
    losses: list
    steps_run: int
    pruned: bool
    restored_from: int | None
    wall_seconds: float


class Trainer:
    def __init__(self, model_cfg: ModelConfig, opt_cfg: AdamWConfig,
                 data_cfg: DataConfig, tcfg: TrainerConfig):
        self.model_cfg = model_cfg
        self.opt_cfg = opt_cfg
        self.data_cfg = data_cfg
        self.tcfg = tcfg
        self.dataset = SyntheticLMDataset(data_cfg, model_cfg)
        self._step_fn = jax.jit(
            make_train_step(model_cfg, opt_cfg, tcfg.microbatches),
            donate_argnums=(0,))
        self.ckpt = (CheckpointManager(tcfg.checkpoint_dir,
                                       tcfg.keep_checkpoints)
                     if tcfg.checkpoint_dir else None)

    def run(self, report: ReportFn | None = None) -> TrainResult:
        t0 = time.time()
        tc = self.tcfg
        state, _ = init_train_state(self.model_cfg, self.opt_cfg,
                                    jax.random.key(tc.seed))
        state = state.tree()
        start_step, restored_from = 0, None
        if self.ckpt is not None:
            got = self.ckpt.restore_latest(state)
            if got is not None:
                state, meta = got
                start_step = int(meta["step"])
                restored_from = start_step

        losses, pruned, executed = [], False, 0
        for step, batch in self.dataset.iter_from(start_step):
            if step >= tc.total_steps:
                break
            state, metrics = self._step_fn(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            executed += 1
            if not np.isfinite(loss):
                raise FloatingPointError(f"loss diverged at step {step}: {loss}")
            if tc.log_every and step % tc.log_every == 0:
                print(f"  step {step:5d}  loss {loss:.4f}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}")
            if self.ckpt is not None and tc.checkpoint_every and \
                    (step + 1) % tc.checkpoint_every == 0:
                self.ckpt.save(step + 1, state)
            if report is not None and (step + 1) % tc.report_every == 0:
                if report(step + 1, loss):
                    pruned = True
                    break
        if self.ckpt is not None:
            self.ckpt.wait()
        return TrainResult(
            final_loss=losses[-1] if losses else float("nan"),
            losses=losses, steps_run=executed, pruned=pruned,
            restored_from=restored_from, wall_seconds=time.time() - t0)


def hopaas_objective(model_cfg: ModelConfig, *, total_steps: int = 60,
                     global_batch: int = 8, seq_len: int = 64,
                     report_every: int = 10) -> Callable[[dict, ReportFn], float]:
    """Build an objective(trial_params, report) for repro.core.campaign:
    trains ``model_cfg`` with trial-suggested optimizer hyperparameters."""
    def objective(params: dict[str, Any], report: ReportFn) -> float:
        opt = AdamWConfig(
            lr=float(params.get("lr", 3e-4)),
            b1=float(params.get("b1", 0.9)),
            b2=float(params.get("b2", 0.95)),
            weight_decay=float(params.get("weight_decay", 0.1)),
            grad_clip=float(params.get("grad_clip", 1.0)))
        dcfg = DataConfig(global_batch=global_batch, seq_len=seq_len,
                          seed=int(params.get("data_seed", 0)))
        tcfg = TrainerConfig(total_steps=total_steps,
                             report_every=report_every,
                             seed=int(params.get("seed", 0)))
        res = Trainer(model_cfg, opt, dcfg, tcfg).run(report=report)
        return res.final_loss
    return objective
