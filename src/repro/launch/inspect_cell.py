"""Dry-run profiler: per-opcode / per-shape cost breakdown of one cell.

The hypothesis-loop microscope: shows where the bytes, flops and
collective traffic of a compiled cell actually go (loop-weighted), plus
the biggest live buffers.

  PYTHONPATH=src python -m repro.launch.inspect_cell --arch deepseek-67b \
      --shape train_4k [--multi-pod] [--top 15]
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse            # noqa: E402
import re                  # noqa: E402
from collections import defaultdict  # noqa: E402

import jax                 # noqa: E402

from repro.dist.context import activation_batch_axis  # noqa: E402
from repro.launch import dryrun, hlo_cost              # noqa: E402
from repro.launch.mesh import make_production_mesh     # noqa: E402


def compile_cell(arch: str, shape: str, multi_pod: bool = False):
    mesh = make_production_mesh(multi_pod=multi_pod)
    fn, args, in_sh, out_sh, donate, cfg = dryrun.build_cell(
        arch, shape, mesh)
    bax, ext = dryrun.cell_batch_axis(arch, shape, mesh)
    with mesh, activation_batch_axis(bax, ext):
        compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                           donate_argnums=donate).lower(*args).compile()
    return compiled, mesh


def breakdown(compiled, n_dev: int, top: int = 15) -> str:
    comps, entry = hlo_cost.parse_module(compiled.as_text())
    rows = []                 # (bytes, flops, coll, op, shape, ctx)

    def walk(name, fused, mult, ctx):
        symtab = {i.name: i.shape for i in comps.get(name, [])}
        for ins in comps.get(name, []):
            ob = hlo_cost._shape_bytes(ins.shape)
            byt = fl = co = 0.0
            if ins.opcode in hlo_cost.COLLECTIVES:
                g = hlo_cost._group_size(ins.attrs, n_dev)
                co = hlo_cost._TRAFFIC[ins.opcode](ob, max(g, 1)) * mult
            if ins.opcode == "dot" and ins.operands:
                lhs = symtab.get(ins.operands[0], "")
                m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
                contract = 1
                if m and lhs:
                    dm = hlo_cost._SHAPE_RE.search(lhs)
                    if dm and dm.group(2):
                        ld = [int(x) for x in dm.group(2).split(",")]
                        for ci in (m.group(1).split(",") if m.group(1)
                                   else []):
                            contract *= ld[int(ci)]
                fl = 2.0 * hlo_cost._shape_numel(ins.shape) * contract * mult
            if not fused and ins.opcode not in hlo_cost._FREE_OPS \
                    and ins.opcode not in ("while", "conditional", "call"):
                if ins.opcode == "fusion":
                    called = re.search(r"calls=(%[\w\.\-]+)", ins.attrs)
                    reads = hlo_cost._fusion_read_bytes(
                        comps.get(called.group(1), []) if called else [],
                        [symtab.get(o, "") for o in ins.operands])
                    byt = (ob + reads) * mult
                else:
                    byt = (ob + sum(hlo_cost._shape_bytes(symtab.get(o, ""))
                                    for o in ins.operands)) * mult
            if byt or fl or co:
                rows.append((byt, fl, co, ins.opcode, ins.shape[:58], ctx))
            if ins.opcode == "while":
                body = re.search(r"body=(%[\w\.\-]+)", ins.attrs)
                trip = hlo_cost._trip_count(ins.attrs) or 1
                if body:
                    walk(body.group(1), fused, mult * trip,
                         ctx + f">x{trip}")
            elif ins.opcode == "fusion":
                called = re.search(r"calls=(%[\w\.\-]+)", ins.attrs)
                if called:
                    walk(called.group(1), True, mult, ctx)

    walk(entry, False, 1.0, "E")
    out = []
    for title, key in (("BYTES", 0), ("FLOPS", 1), ("COLLECTIVE", 2)):
        agg = defaultdict(float)
        for r in rows:
            agg[(r[3], r[4], r[5])] += r[key]
        out.append(f"--- top {title} ---")
        for (op, sh, ctx), v in sorted(agg.items(), key=lambda kv: -kv[1])[:top]:
            if v <= 0:
                continue
            unit = v / 1e9
            out.append(f"  {unit:10.2f}G {op:18s} {ctx:10s} {sh}")
    return "\n".join(out)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()
    compiled, mesh = compile_cell(args.arch, args.shape, args.multi_pod)
    print(breakdown(compiled, mesh.size, args.top))
    mem = compiled.memory_analysis()
    print(f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB "
          f"args={mem.argument_size_in_bytes/2**30:.2f}GiB")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
