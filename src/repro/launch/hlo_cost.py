"""HLO-text cost analyzer with loop trip-count accounting.

``compiled.cost_analysis()`` counts every computation ONCE — a model that
``lax.scan``s over 95 layers reports 1/95th of its real FLOPs (verified).
This module parses ``compiled.as_text()`` (the post-SPMD, per-device
optimized HLO), walks the call graph, and multiplies ``while`` bodies by
their ``known_trip_count`` — yielding *executed* per-device totals:

  * flops           — dot/convolution MACs x2 (contraction size from the
                      operand symbol table)
  * bytes           — HBM traffic proxy: operand + result bytes of every
                      top-level instruction (fusion-internal ops excluded:
                      they never round-trip HBM)
  * collectives     — per-op kind and bytes, with ring-algorithm traffic
                      factors applied per participating-group size

Collective traffic convention (per device, ring algorithms):
  all-gather: out x (g-1)/g       all-reduce: 2 x out x (g-1)/g
  reduce-scatter: out x (g-1)     all-to-all: out x (g-1)/g
  collective-permute: out
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {"pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
                "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
                "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4,
                "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _is_score_shaped(shape_str: str) -> bool:
    """(..., S, S) with S >= 2048 and >= 4 dims — an attention score/prob
    tensor (weight matrices have unequal trailing dims)."""
    m = _SHAPE_RE.search(shape_str)
    if not m or not m.group(2):
        return False
    dims = [int(d) for d in m.group(2).split(",")]
    return (len(dims) >= 4 and dims[-1] == dims[-2] and dims[-1] >= 2048)
_FREE_OPS = {"bitcast", "get-tuple-element", "tuple", "parameter",
             "constant", "after-all", "iota", "reshape", "broadcast",
             "partition-id", "replica-id"}
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(shape_str: str) -> float:
    """Total bytes of a (possibly tuple) shape string."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_numel(shape_str: str) -> int:
    n_total = 0
    for _, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        n_total += n
    return n_total


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    operands: list[str]
    attrs: str
    args_text: str = ""          # raw text inside the opcode parens


_INSTR_RE = re.compile(
    r"^\s*(ROOT\s+)?(%[\w\.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?(%[\w\.\-]+)[\s(].*\{\s*$")


def parse_module(txt: str) -> tuple[dict[str, list[Instr]], str]:
    """-> ({computation: [Instr]}, entry_name)."""
    comps: dict[str, list[Instr]] = {}
    entry = ""
    cur: list[Instr] | None = None
    for line in txt.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m:
                name = m.group(2)
                comps[name] = []
                cur = comps[name]
                if m.group(1):
                    entry = name
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        _, name, shape, opcode, rest = m.groups()
        # operands: %refs inside the first balanced paren group
        depth, i = 1, 0
        while i < len(rest) and depth:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        operands = re.findall(r"%[\w\.\-]+", rest[: i])
        cur.append(Instr(name, shape, opcode, operands, rest[i:],
                         rest[: max(i - 1, 0)]))
    return comps, entry


def _group_size(attrs: str, total_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    return total_devices


def _trip_count(attrs: str) -> int | None:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', attrs)
    return int(m.group(1)) if m else None


_TRAFFIC = {
    "all-gather": lambda out, g: out * (g - 1) / g,
    "all-reduce": lambda out, g: 2 * out * (g - 1) / g,
    "reduce-scatter": lambda out, g: out * (g - 1),
    "all-to-all": lambda out, g: out * (g - 1) / g,
    "collective-permute": lambda out, g: out,
}


def _fusion_read_bytes(body: list["Instr"], operand_shapes: list[str]
                       ) -> float:
    """Actual HBM reads of a fusion: a fused (dynamic-)slice of a big
    operand (e.g. one layer out of the stacked scan weights) reads only
    the slice, and a fused dynamic-update-slice writes only the update
    region (the destination aliases in place)."""
    params: dict[int, str] = {}
    symtab: dict[str, str] = {}
    consumers: dict[str, list[Instr]] = defaultdict(list)
    for ins in body:
        symtab[ins.name] = ins.shape
        if ins.opcode == "parameter":
            m = re.match(r"\s*(\d+)", ins.args_text)
            if m:
                params[int(m.group(1))] = ins.name
        for o in ins.operands:
            consumers[o].append(ins)

    def effective_consumers(name: str, depth: int = 0) -> list[Instr]:
        """Consumers, seen through convert/bitcast chains (XLA-CPU wraps
        bf16 stacks in f32 converts that would not exist on TPU)."""
        out = []
        for c in consumers.get(name, []):
            if c.opcode in ("convert", "bitcast", "copy") and depth < 4:
                out.extend(effective_consumers(c.name, depth + 1))
            else:
                out.append(c)
        return out

    total = 0.0
    for idx, pname in params.items():
        full = _shape_bytes(operand_shapes[idx]) \
            if idx < len(operand_shapes) else 0.0
        cons = effective_consumers(pname)
        if not cons:
            continue
        touched = 0.0
        sliced = True
        for c in cons:
            if c.opcode in ("dynamic-slice", "slice", "gather"):
                touched += _shape_bytes(c.shape)
            elif c.opcode == "dynamic-update-slice":
                upd = _shape_bytes(symtab.get(c.operands[1], "")) \
                    if len(c.operands) > 1 else 0.0
                touched += upd
            else:
                sliced = False
                break
        total += min(full, touched) if sliced else full
    return total


def _fusion_write_bytes(body: list["Instr"], out_bytes: float) -> float:
    """Actual HBM writes of a fusion: when the root is a dynamic-update-
    slice (XLA aliases the destination in place), only the update region
    is written — a scan saving one layer's activations into its (L, ...)
    stack writes layer-sized, not stack-sized, bytes."""
    instrs = {i.name: i for i in body}
    symtab = {i.name: i.shape for i in body}
    consumed = {o for i in body for o in i.operands}
    roots = [i for i in body if i.name not in consumed] or body[-1:]

    def resolve(i: "Instr | None", depth: int = 0) -> "Instr | None":
        """See through convert/bitcast/copy wrappers around the root."""
        while i is not None and depth < 4 and \
                i.opcode in ("convert", "bitcast", "copy") and i.operands:
            i = instrs.get(i.operands[0])
            depth += 1
        return i

    def write_of(i: Instr) -> float:
        r = resolve(i)
        if r is not None and r.opcode == "dynamic-update-slice" \
                and len(r.operands) > 1:
            return _shape_bytes(symtab.get(r.operands[1], ""))
        return _shape_bytes(i.shape)

    def is_dus(i: "Instr | None") -> bool:
        r = resolve(i)
        return r is not None and r.opcode == "dynamic-update-slice"

    total = 0.0
    saw_dus = False
    for r in roots:
        if r.opcode == "tuple":
            for o in r.operands:
                elem = instrs.get(o)
                total += write_of(elem) if elem else 0.0
                saw_dus |= is_dus(elem)
        else:
            total += write_of(r)
            saw_dus |= is_dus(r)
    return min(total, out_bytes) if saw_dus else out_bytes


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_bytes_bf16: float = 0.0    # f32 collectives halved (TPU est.)
    # CPU-backend artifact accounting: XLA-CPU converts bf16 dot operands
    # to f32 (hoisted, materialized); TPU MXUs consume bf16 natively, so
    # these copies would not exist on the target.  ``convert_f32_bytes``
    # is loop-weighted (traffic); ``convert_f32_buffer_bytes`` counts each
    # convert once (a loop-resident buffer is reused across iterations).
    convert_f32_bytes: float = 0.0
    convert_f32_buffer_bytes: float = 0.0
    # f32 dot outputs (CPU emits f32 and converts back; TPU MXU emits bf16
    # when the consumer is bf16) — excess is half the f32 size
    dot_f32_out_bytes: float = 0.0        # buffer, unweighted
    dot_f32_traffic: float = 0.0          # loop-weighted
    # attention-score-shaped traffic (trailing dims equal and >=2048,
    # ndim>=4): what a fused flash-attention kernel keeps in VMEM —
    # reported so the roofline can state a with-kernel memory estimate
    score_traffic: float = 0.0            # loop-weighted
    by_collective: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    collective_calls: dict = dataclasses.field(
        default_factory=lambda: defaultdict(int))
    unknown_loops: int = 0

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        self.collective_bytes_bf16 += other.collective_bytes_bf16 * mult
        self.convert_f32_bytes += other.convert_f32_bytes * mult
        self.convert_f32_buffer_bytes += other.convert_f32_buffer_bytes
        self.dot_f32_out_bytes += other.dot_f32_out_bytes
        self.dot_f32_traffic += other.dot_f32_traffic * mult
        self.score_traffic += other.score_traffic * mult
        for k, v in other.by_collective.items():
            self.by_collective[k] += v * mult
        for k, v in other.collective_calls.items():
            self.collective_calls[k] += int(v * mult)
        self.unknown_loops += other.unknown_loops


def analyze(txt: str, total_devices: int) -> Cost:
    comps, entry = parse_module(txt)
    memo: dict[tuple[str, bool], Cost] = {}

    def comp_cost(name: str, fused_ctx: bool) -> Cost:
        key = (name, fused_ctx)
        if key in memo:
            return memo[key]
        cost = Cost()
        memo[key] = cost          # cycle guard (HLO is acyclic anyway)
        symtab = {i.name: i.shape for i in comps.get(name, [])}

        for ins in comps.get(name, []):
            out_bytes = _shape_bytes(ins.shape)

            # ---- flops
            if ins.opcode == "dot" and ins.operands:
                lhs_shape = symtab.get(ins.operands[0], "")
                m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
                contract = 1
                if m and lhs_shape:
                    dims_m = _SHAPE_RE.search(lhs_shape)
                    if dims_m and dims_m.group(2):
                        ldims = [int(d) for d in dims_m.group(2).split(",")]
                        for ci in (m.group(1).split(",") if m.group(1) else []):
                            contract *= ldims[int(ci)]
                cost.flops += 2.0 * _shape_numel(ins.shape) * contract
            elif ins.opcode == "convolution":
                # rough: 2 * out_numel * (in_features * window) — parse the
                # rhs (kernel) size instead: 2 * out * kernel_numel / out_feats
                rhs_shape = symtab.get(ins.operands[1], "") \
                    if len(ins.operands) > 1 else ""
                cost.flops += 2.0 * _shape_numel(ins.shape) * max(
                    1, _shape_numel(rhs_shape) // max(
                        1, _shape_numel(ins.shape) or 1))

            # ---- collectives
            if ins.opcode in COLLECTIVES:
                g = _group_size(ins.attrs, total_devices)
                traffic = _TRAFFIC[ins.opcode](out_bytes, max(g, 1))
                cost.collective_bytes += traffic
                cost.by_collective[ins.opcode] += traffic
                cost.collective_calls[ins.opcode] += 1
                # bf16-model adjustment: XLA-CPU canonicalizes bf16 dots to
                # f32 (+converts), which drags the adjacent partial-sum /
                # gradient collectives to f32.  TPU emits bf16 dots, so f32
                # collectives would move half the bytes there.
                cost.collective_bytes_bf16 += (
                    traffic / 2 if ins.shape.lstrip("(").startswith("f32")
                    else traffic)

            # ---- bytes (top-level only)
            if not fused_ctx and ins.opcode not in _FREE_OPS:
                contrib = 0.0
                if ins.opcode in ("while", "conditional", "call"):
                    pass           # carried tuple is aliased in place;
                                   # body traffic counted via recursion
                elif ins.opcode in ("dynamic-slice", "slice", "gather"):
                    contrib = 2 * out_bytes              # read + write slice
                elif ins.opcode == "dynamic-update-slice":
                    upd = _shape_bytes(symtab.get(ins.operands[1], "")) \
                        if len(ins.operands) > 1 else out_bytes
                    contrib = 2 * upd       # in-place: touch the slice only
                elif ins.opcode == "fusion":
                    called = re.search(r"calls=(%[\w\.\-]+)", ins.attrs)
                    body = comps.get(called.group(1), []) if called else []
                    reads = _fusion_read_bytes(
                        body, [symtab.get(o, "") for o in ins.operands])
                    contrib = _fusion_write_bytes(body, out_bytes) + reads
                else:
                    contrib = out_bytes + sum(
                        _shape_bytes(symtab.get(o, ""))
                        for o in ins.operands)
                cost.bytes += contrib
                if contrib and (_is_score_shaped(ins.shape) or any(
                        _is_score_shaped(symtab.get(o, ""))
                        for o in ins.operands)):
                    cost.score_traffic += contrib

            # ---- CPU bf16->f32 dot-operand conversion artifact
            if not fused_ctx and ins.shape.startswith("f32"):
                body_is_convert = False
                if ins.opcode == "convert":
                    src = symtab.get(ins.operands[0], "") if ins.operands \
                        else ""
                    body_is_convert = src.startswith(("bf16", "s8", "u8"))
                elif ins.opcode == "fusion":
                    called = re.search(r"calls=(%[\w\.\-]+)", ins.attrs)
                    body = comps.get(called.group(1), []) if called else []
                    real = [b for b in body if b.opcode != "parameter"]
                    body_is_convert = (
                        len(real) == 1 and real[0].opcode == "convert"
                        and any(b.shape.startswith(("bf16", "s8", "u8"))
                                for b in body))
                if body_is_convert and out_bytes > 64e6:
                    cost.convert_f32_bytes += out_bytes
                    cost.convert_f32_buffer_bytes += out_bytes
                if ins.opcode == "dot" and out_bytes > 64e6:
                    lhs = symtab.get(ins.operands[0], "") \
                        if ins.operands else ""
                    if lhs.startswith("f32"):
                        cost.dot_f32_out_bytes += out_bytes
                        cost.dot_f32_traffic += out_bytes

            # ---- called computations
            if ins.opcode == "while":
                body = re.search(r"body=(%[\w\.\-]+)", ins.attrs)
                trip = _trip_count(ins.attrs)
                if trip is None:
                    trip = 1
                    cost.unknown_loops += 1
                if body:
                    cost.add(comp_cost(body.group(1), fused_ctx), trip)
            elif ins.opcode == "fusion":
                called = re.search(r"calls=(%[\w\.\-]+)", ins.attrs)
                if called:
                    cost.add(comp_cost(called.group(1), True), 1.0)
            elif ins.opcode in ("call", "conditional", "async-start"):
                for target in re.findall(
                        r"(?:to_apply|called_computations?)=\{?(%[\w\.\-]+)",
                        ins.attrs):
                    cost.add(comp_cost(target, fused_ctx), 1.0)
        return cost

    return comp_cost(entry, False)
