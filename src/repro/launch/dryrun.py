"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, prove memory fit, and extract roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
# The dry-run (and ONLY the dry-run) needs 512 placeholder devices; jax
# locks the device count at first init, so this precedes EVERY import.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.dist import sharding as shd                     # noqa: E402
from repro.dist.context import (activation_batch_axis,     # noqa: E402
                                attention_seq_axis)
from repro.launch import hlo_cost                          # noqa: E402
from repro.launch.mesh import (HBM_BW, ICI_BW,             # noqa: E402
                               PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.launch import shapes as shp                     # noqa: E402
from repro.models import registry, transformer             # noqa: E402
from repro.optim import AdamWConfig                        # noqa: E402
from repro.train.step import init_train_state, make_train_step  # noqa: E402

HBM_PER_CHIP = 16 * 2 ** 30    # v5e: 16 GiB HBM2 (memory is binary-sized)


def _batch_shardings(mesh, batch_specs: dict, global_batch: int,
                     rules) -> dict:
    bax = shd.batch_axis(mesh, global_batch, rules)
    return {k: NamedSharding(mesh, P(bax, *(None,) * (v.ndim - 1)))
            for k, v in batch_specs.items()}


def cell_batch_axis(arch: str, shape_name: str, mesh):
    """-> (axis, extent) the activation batch dim is sharded over."""
    shape = shp.SHAPES[shape_name]
    if shape.kind == "train":
        micro = shp.microbatches_for(arch)
        ax = shd.batch_axis(mesh, shape.global_batch // micro,
                            shd.RULES_TRAIN)
    else:
        ax = shd.batch_axis(mesh, shape.global_batch, shd.RULES_DECODE)
    return ax, shd._mesh_extent(mesh, ax)


def build_cell(arch: str, shape_name: str, mesh):
    """-> (fn, example_args, in_shardings, out_shardings, donate, cfg)."""
    shape = shp.SHAPES[shape_name]
    cfg = shp.configure_for_cell(registry.get_config(arch), shape)

    if shape.kind == "train":
        opt = AdamWConfig()
        state, specs = init_train_state(cfg, opt, key=None)
        state_tree = state.tree()
        train_rules = shd.RULES_TRAIN
        if shp.no_tp(arch):
            # small model: no feature-TP — weights FSDP over data only,
            # the model axis carries sequence parallelism (attn_sp)
            train_rules = train_rules.replace(
                mlp=(None,), heads=(None,), kv_heads=(None,),
                head_dim=(None,), vocab=(None,))
        st_sh = shd.tree_shardings(
            {"params": specs["params"], "opt_state": specs["opt_state"]},
            state_tree, mesh, train_rules)
        batch = shp.input_specs(arch, shape_name)["batch"]
        b_sh = _batch_shardings(mesh, batch, shape.global_batch,
                                shd.RULES_TRAIN)
        micro = shp.microbatches_for(arch)
        mb_axis = shd.batch_axis(mesh, shape.global_batch // micro,
                                 shd.RULES_TRAIN)
        step = make_train_step(cfg, opt, micro, batch_axis=mb_axis,
                               grad_shardings=st_sh["params"])
        return (step, (state_tree, batch), (st_sh, b_sh), (st_sh, None),
                (0,), cfg)

    rules = shd.RULES_DECODE
    if shp.no_tp(arch):
        rules = rules.replace(mlp=(None,), heads=(None,), kv_heads=(None,),
                              head_dim=(None,), vocab=(None,),
                              embed=("data", None))
    model_size = mesh.shape.get("model", 1)
    if cfg.block in ("attn", "zamba2") and cfg.n_kv_heads % model_size:
        # GQA with kv_heads % model != 0: k/v fall back to head_dim TP, so
        # q must match — heads-sharded q against hd-sharded kv makes SPMD
        # fully rematerialize the KV cache per layer (verified: +12 GB temp
        # and 4 GB/step of involuntary all-gathers on mixtral decode).
        rules = rules.replace(heads=(None,), head_dim=("model", None))
    if shape.kind == "prefill" and cfg.block in ("attn", "zamba2") \
            and cfg.n_kv_heads % model_size:
        # Prefill wants q/k/v layouts matched *without* sharding the huge
        # score tensors' contraction dim.  kv heads are few and cache-free
        # here, so replicate them and shard q heads (disaggregated
        # prefill/decode layouts — industry practice).  When q heads don't
        # divide either (qwen1.5's 40), all of q/k/v fall through to
        # head_dim sharding — matched, at the cost of score all-reduces
        # (the baseline for that cell; see EXPERIMENTS.md sec. Perf).
        if cfg.n_heads % model_size == 0:
            rules = rules.replace(heads=("model", None),
                                  kv_heads=(None,), head_dim=(None,))
        else:
            rules = rules.replace(heads=(None,), kv_heads=(None,),
                                  head_dim=("model", None))
    params, pspecs = transformer.init_params(cfg, None)
    p_sh = shd.tree_shardings(pspecs, params, mesh, rules)

    if shape.kind == "prefill":
        batch = shp.input_specs(arch, shape_name)["batch"]
        b_sh = _batch_shardings(mesh, batch, shape.global_batch,
                                shd.RULES_DECODE)

        def prefill(p, b):
            logits, _ = transformer.forward(p, cfg, b)
            if cfg.encoder_only:
                return logits          # encoder output IS the product
            return logits[:, -1:]      # serving emits next-token logits
        return prefill, (params, batch), (p_sh, b_sh), None, (), cfg

    # decode
    specs = shp.input_specs(arch, shape_name)
    cache, cache_logical = specs["cache"], specs["cache_logical"]
    c_sh = shd.tree_shardings(cache_logical, cache, mesh, rules)
    tok_sh = NamedSharding(
        mesh, P(shd.batch_axis(mesh, shape.global_batch, shd.RULES_DECODE),
                None))

    def decode(p, c, toks, n):
        return transformer.decode_step(p, cfg, c, toks, n)

    args = (params, cache, specs["tokens"], specs["cache_len"])
    return (decode, args, (p_sh, c_sh, tok_sh, None), (None, c_sh), (1,),
            cfg)


def _ideal_bytes(cfg, shape: shp.Shape, args, n_dev: int) -> float:
    """Per-device lower bound on HBM traffic: every weight byte + (decode)
    every cache byte read once.  The bytes-efficiency denominator for
    memory-bound cells."""
    import math

    def tree_bytes(t):
        return sum(math.prod(x.shape) * x.dtype.itemsize
                   for x in jax.tree.leaves(t))

    if shape.kind == "train":
        # fwd+bwd reads weights ~3x + writes grads; params are f32 here
        params = args[0]["params"]
        return 4.0 * tree_bytes(params) / n_dev
    if shape.kind == "prefill":
        return (tree_bytes(args[0]) + tree_bytes(args[1])) / n_dev
    # decode: weights + cache read once, cache written once (~same scale)
    return (tree_bytes(args[0]) + 2.0 * tree_bytes(args[1])) / n_dev


def model_flops(cfg, shape: shp.Shape) -> float:
    """Analytic useful FLOPs per step: 6ND train, 2ND forward (active
    params for MoE)."""
    n_active = registry.count_active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch          # one token


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str | None = None, verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    shape = shp.SHAPES[shape_name]
    t0 = time.time()
    fn, args, in_sh, out_sh, donate, cfg = build_cell(arch, shape_name, mesh)

    bax, extent = cell_batch_axis(arch, shape_name, mesh)
    with mesh, activation_batch_axis(bax, extent), \
            attention_seq_axis("model", mesh.shape.get("model", 1)):
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    cost = hlo_cost.analyze(compiled.as_text(), n_dev)

    live_bytes = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                  + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    # XLA-CPU materializes f32 copies of bf16 dot operands (hoisted, often
    # the full weight set); TPU MXUs consume bf16 natively -> subtract.
    live_tpu = (live_bytes - cost.convert_f32_buffer_bytes
                - 0.5 * cost.dot_f32_out_bytes)
    bytes_tpu = max(cost.bytes - 1.5 * cost.convert_f32_bytes
                    - 0.5 * cost.dot_f32_traffic, 0.0)
    mf = model_flops(cfg, shape)
    compute_s = cost.flops / PEAK_FLOPS_BF16
    memory_s = bytes_tpu / HBM_BW
    collective_s = cost.collective_bytes_bf16 / ICI_BW
    collective_s_raw = cost.collective_bytes / ICI_BW
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", collective_s), key=lambda kv: kv[1])[0]
    # per-device ideal HBM traffic: weights + decode state touched once
    ideal_bytes = _ideal_bytes(cfg, shape, args, n_dev)

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev, "kind": shape.kind,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "compile_seconds": round(time.time() - t0, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "live_bytes_per_device": live_bytes,
            "cpu_f32_convert_bytes": cost.convert_f32_bytes,
            "live_bytes_tpu": live_tpu,
            "hbm_utilization": live_tpu / HBM_PER_CHIP,
            "fits_hbm": bool(live_tpu < HBM_PER_CHIP),
        },
        "xla_cost_analysis": {k: ca.get(k) for k in
                              ("flops", "bytes accessed") if k in ca},
        "hlo_cost": {
            "flops_per_device": cost.flops,
            "bytes_per_device": cost.bytes,
            "collective_bytes_per_device": cost.collective_bytes,
            "by_collective": dict(cost.by_collective),
            "collective_calls": dict(cost.collective_calls),
            "unknown_trip_loops": cost.unknown_loops,
        },
        "roofline": {
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": collective_s,
            "collective_s_raw_f32": collective_s_raw,
            "dominant": dominant,
            "model_flops": mf,
            "useful_flops_ratio": mf / max(cost.flops * n_dev, 1.0),
            # compute-centric score (train/prefill): useful FLOPs over the
            # chip-seconds implied by the slowest roofline term
            "roofline_fraction":
                mf / max(n_dev * PEAK_FLOPS_BF16
                         * max(compute_s, memory_s, collective_s), 1e-30),
            # bandwidth-centric score (decode): ideal bytes / actual bytes
            "ideal_bytes_per_device": ideal_bytes,
            "bytes_efficiency": ideal_bytes / max(bytes_tpu, 1.0),
            # attention-score tensor traffic: a fused flash kernel (shipped
            # in repro.kernels, unlowerable on the CPU proxy) keeps these
            # in VMEM — memory term with the kernel applied:
            "score_traffic_bytes": cost.score_traffic,
            "memory_s_with_flash_kernel":
                max(bytes_tpu - cost.score_traffic, 0.0) / HBM_BW,
        },
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}__{shape_name}__{rec['mesh']}.json"
        with open(os.path.join(out_dir, tag), "w") as f:
            json.dump(rec, f, indent=1)
    if verbose:
        r = rec["roofline"]
        score = (r["bytes_efficiency"] if shape.kind == "decode"
                 else r["roofline_fraction"])
        print(f"[OK] {arch:18s} {shape_name:12s} {rec['mesh']:8s} "
              f"mem/dev={live_tpu/1e9:6.2f}GB "
              f"C={r['compute_s']*1e3:8.2f}ms M={r['memory_s']*1e3:8.2f}ms "
              f"X={r['collective_s']*1e3:8.2f}ms dom={r['dominant']:10s} "
              f"score={score:.3f} "
              f"(compile {rec['compile_seconds']}s)", flush=True)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    all_cells = shp.cells()
    if args.list:
        for a, s in all_cells:
            print(f"{a:20s} {s}")
        print(f"total: {len(all_cells)} cells")
        return 0

    todo = [(a, s) for a, s in all_cells
            if (args.arch in (None, a)) and (args.shape in (None, s))]
    if not todo:
        print("nothing matches the filters")
        return 1
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch, shape_name in todo:
        for mp in meshes:
            try:
                run_cell(arch, shape_name, mp, out_dir=args.out)
            except Exception as e:
                failures.append((arch, shape_name, mp, repr(e)))
                print(f"[FAIL] {arch} {shape_name} multi_pod={mp}: {e}",
                      flush=True)
                traceback.print_exc()
    print(f"\n{len(todo) * len(meshes) - len(failures)}/"
          f"{len(todo) * len(meshes)} cells compiled")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
