"""The assigned (architecture x input-shape) matrix.

4 shapes per LM arch:
  train_4k     seq 4096,   global_batch 256  -> train_step
  prefill_32k  seq 32768,  global_batch 32   -> prefill (forward, last-token
                                               logits)
  decode_32k   seq 32768,  global_batch 128  -> serve_step (1 new token, cache
                                               of seq_len)
  long_500k    seq 524288, global_batch 1    -> serve_step; requires a
                                               sub-quadratic path

Skips (recorded in DESIGN.md sec. Arch-applicability):
  * long_500k for pure full-attention archs (qwen1.5/deepseek/qwen3/pixtral/
    qwen2-moe): a 500k dense KV cache has no sub-quadratic path;
  * decode_32k + long_500k for hubert (encoder-only: no decode step).
=> 32 dry-run cells.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.data import make_batch_specs
from repro.models import registry, transformer
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}

# per-arch knobs for the *full-scale* cells
#   micro: gradient-accumulation microbatches for train_4k (activation fit)
#   kv_quant: int8 KV cache for the 32k decode cell (HBM fit; see DESIGN.md)
ARCH_TUNING: dict[str, dict] = {
    # micro = gradient-accumulation count.  Measured (see EXPERIMENTS
    # §Perf): reducing it barely moves the collective term — the per-layer
    # TP all-reduces scale with tokens, not microbatches — so micro is
    # kept high for activation-memory headroom.
    "qwen1.5-32b":     {"micro": 16, "kv_quant": True, "pad_heads": True,
                        "attn_sp": True},
    "deepseek-67b":    {"micro": 16, "kv_quant": True,
                        "remat_policy": "dots"},
    "deepseek-7b":     {"micro": 8},
    "qwen3-32b":       {"micro": 16},
    "zamba2-1.2b":     {"micro": 4},
    "pixtral-12b":     {"micro": 8},
    "qwen2-moe-a2.7b": {"micro": 8},
    "mixtral-8x7b":    {"micro": 16, "remat_policy": "dots",
                        "train_capacity": 1.0},
    "rwkv6-7b":        {"micro": 8},
    # 1B-param encoder: feature-TP over 16 gives 80-column matmul shards
    # and all-reduces that dwarf the math — use DP+SP instead: weights
    # FSDP over data only, the model axis carries the *sequence* inside
    # attention (attn_sp)
    "hubert-xlarge":   {"micro": 8, "attn_sp": True, "no_tp": True},
}


def cell_is_skipped(cfg: ModelConfig, shape: Shape) -> str | None:
    """-> reason string if this (arch, shape) cell is skipped, else None."""
    if shape.kind == "decode" and not cfg.supports_decode:
        return "encoder-only: no decode step"
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return "full attention: no sub-quadratic path at 500k"
    return None


def cells(archs: list[str] | None = None) -> list[tuple[str, str]]:
    """All non-skipped (arch, shape) pairs."""
    from repro.configs import ARCHS
    out = []
    for arch in archs or ARCHS:
        cfg = registry.get_config(arch)
        for sname, shape in SHAPES.items():
            if cell_is_skipped(cfg, shape) is None:
                out.append((arch, sname))
    return out


def configure_for_cell(cfg: ModelConfig, shape: Shape) -> ModelConfig:
    """Cell-specific model settings (the production configuration)."""
    tune = ARCH_TUNING.get(cfg.name, {})
    if shape.kind == "train":
        # ref attention: true FLOPs in HLO; microbatched fit handled by step
        cfg = cfg.replace(remat_policy=tune.get("remat_policy", "nothing"),
                          attn_sp=tune.get("attn_sp", False))
        if cfg.moe is not None and "train_capacity" in tune:
            cfg = cfg.replace(moe=dataclasses.replace(
                cfg.moe, capacity_factor=tune["train_capacity"]))
        return cfg
    # inference: serve in bf16 params
    cfg = cfg.replace(param_dtype=jnp.bfloat16)
    if shape.kind == "prefill":
        # stream attention over kv blocks: never materialize 32k x 32k
        if cfg.block in ("attn", "zamba2"):
            cfg = cfg.replace(attn_impl="blocked")
        if tune.get("attn_sp"):
            cfg = cfg.replace(attn_sp=True)
        if tune.get("pad_heads"):
            # vLLM-style TP head padding (see models/surgery.py): 40 heads
            # -> 48, sharding 3/device instead of head_dim-sharded q/k/v
            # whose score contractions all-reduce S x T tensors
            from repro.models import surgery
            cfg = surgery.pad_heads_config(cfg, divisor=16)
        if cfg.moe is not None:
            # bound live MoE dispatch buffers over the 1M-token batch
            cfg = cfg.replace(
                moe=dataclasses.replace(cfg.moe, scan_groups=8))
        return cfg
    if shape.name == "decode_32k" and tune.get("kv_quant"):
        cfg = cfg.replace(kv_quant=True)
    return cfg


def microbatches_for(arch: str) -> int:
    return ARCH_TUNING.get(arch, {}).get("micro", 8)


def no_tp(arch: str) -> bool:
    """Small-model cells that skip feature-TP (weights replicated over
    the model axis; the model axis serves sequence parallelism)."""
    return ARCH_TUNING.get(arch, {}).get("no_tp", False)


def decode_cache_len(cfg: ModelConfig, shape: Shape) -> int:
    """Physical cache length for decode cells (window-bounded for SWA)."""
    if cfg.sliding_window is not None:
        return min(shape.seq_len, cfg.sliding_window)
    return shape.seq_len


def input_specs(arch: str, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of one cell."""
    shape = SHAPES[shape_name]
    cfg = configure_for_cell(registry.get_config(arch), shape)
    if shape.kind in ("train", "prefill"):
        specs = make_batch_specs(cfg, shape.global_batch, shape.seq_len)
        if shape.kind == "prefill":
            specs.pop("labels", None)
        return {"batch": specs}
    # decode: cache + one token
    cache, cache_specs = transformer.init_cache_arrays(
        cfg, shape.global_batch, decode_cache_len(cfg, shape), abstract=True)
    return {
        "cache": cache,
        "cache_logical": cache_specs,
        "tokens": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
        "cache_len": jax.ShapeDtypeStruct((), jnp.int32),
    }
