"""HOPAAS worker node — the paper's client-side story, end to end.

A computing node that (1) connects to a HOPAAS server over the wire
(HTTP), (2) asks for a trial, (3) trains the requested arch with the
suggested hyperparameters, reporting intermediate losses through
``should_prune``, and (4) tells the final loss.  Run several of these
(different machines / processes) against one server URL to reproduce the
paper's multi-site campaign; the ``--die-after`` flag simulates the
opportunistic-resource failure mode (the server's lease sweeper requeues
the orphaned trial).

  # terminal 1: the service
  PYTHONPATH=src python -m repro.core.service --port 8731

  # terminals 2..N: workers
  PYTHONPATH=src python -m repro.launch.worker --server localhost:8731 \
      --token <token> --study lm-tune --arch deepseek-7b --trials 4
"""
from __future__ import annotations

import argparse

from repro.core.client import Client, Study, suggestions
from repro.core.transport import HttpTransport
from repro.models import registry
from repro.train.trainer import hopaas_objective


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--server", default="localhost:8731")
    ap.add_argument("--token", required=True)
    ap.add_argument("--study", default="lm-tune")
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--trials", type=int, default=4,
                    help="trials this worker contributes")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--worker-id", default="worker-0")
    ap.add_argument("--die-after", type=int, default=0,
                    help="crash (no tell) after N trials — straggler test")
    args = ap.parse_args()

    host, port = args.server.rsplit(":", 1)
    client = Client(HttpTransport(host, int(port)), args.token,
                    worker_id=args.worker_id)
    print(f"worker {args.worker_id}: server version",
          client.version())

    mcfg = registry.get_config(args.arch, smoke=True)
    objective = hopaas_objective(mcfg, total_steps=args.steps)
    study = Study(
        name=args.study,
        properties={"lr": suggestions.loguniform(1e-5, 1e-2),
                    "b1": suggestions.uniform(0.8, 0.99),
                    "weight_decay": suggestions.loguniform(1e-3, 0.3)},
        direction="minimize", sampler={"name": "tpe"},
        pruner={"name": "median", "n_warmup_steps": 10},
        client=client)

    for i in range(args.trials):
        trial = study.ask()
        print(f"  trial {trial.id}: {trial.params}")
        value = objective(trial.params, trial.should_prune)
        if args.die_after and i + 1 >= args.die_after:
            print("  simulating crash: exiting without tell")
            return 0
        study.tell(trial, value=value,
                   state="pruned" if trial.pruned else None)
        print(f"  trial {trial.id} -> {value:.4f}"
              + (" (pruned)" if trial.pruned else ""))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
