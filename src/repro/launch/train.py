"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b --smoke \
      --steps 50 --batch 8 --seq 64 [--checkpoint-dir ckpt] [--resume]

Runs the real training loop (synthetic deterministic data) on whatever
devices exist.  ``--smoke`` selects the reduced config (CPU-sized); the
full configs are exercised through ``repro.launch.dryrun``.
"""
from __future__ import annotations

import argparse

from repro.data import DataConfig
from repro.models import registry
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    mcfg = registry.get_config(args.arch, smoke=args.smoke)
    opt = AdamWConfig(lr=args.lr)
    dcfg = DataConfig(global_batch=args.batch, seq_len=args.seq,
                      seed=args.seed)
    tcfg = TrainerConfig(
        total_steps=args.steps, microbatches=args.microbatches,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every, log_every=args.log_every,
        seed=args.seed)
    print(f"training {mcfg.name} ({mcfg.n_params()/1e6:.1f}M params) "
          f"for {args.steps} steps, batch={args.batch} seq={args.seq}")
    res = Trainer(mcfg, opt, dcfg, tcfg).run()
    print(f"done: {res.steps_run} steps in {res.wall_seconds:.1f}s, "
          f"loss {res.losses[0]:.4f} -> {res.final_loss:.4f}"
          + (f" (resumed from step {res.restored_from})"
             if res.restored_from else ""))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
