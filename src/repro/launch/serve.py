"""Serving launcher: batched greedy generation with the KV/SSM cache
engine.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --smoke \
      --batch 4 --prompt-len 16 --new-tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.models import registry, transformer
from repro.serve import ServeEngine


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = registry.get_config(args.arch, smoke=args.smoke)
    if args.kv_quant:
        cfg = cfg.replace(kv_quant=True)
    if not cfg.supports_decode:
        print(f"{cfg.name} is encoder-only: no decode step")
        return 1
    params, _ = transformer.init_params(cfg, jax.random.key(args.seed))
    eng = ServeEngine(cfg, params,
                      max_len=args.prompt_len + args.new_tokens)
    prompts = np.asarray(jax.random.randint(
        jax.random.key(args.seed + 1), (args.batch, args.prompt_len),
        0, cfg.vocab_size), np.int32)
    t0 = time.time()
    out = eng.generate(prompts, args.new_tokens)
    dt = time.time() - t0
    print(f"{cfg.name}: generated {out.shape[0]}x{out.shape[1]} tokens "
          f"in {dt:.2f}s ({out.size / dt:.1f} tok/s)")
    print("first row:", out[0].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
