"""Production meshes.

TPU v5e topology: one pod = a 16x16 ICI torus (256 chips); multi-pod adds
a DCN-connected ``pod`` axis.  Defined as FUNCTIONS so importing this
module never touches jax device state (device count locks on first use —
the dry-run forces 512 host devices, the tests keep 1).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Mesh over whatever devices exist (tests / CPU runs)."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


# v5e hardware constants (per chip) — the roofline denominators
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link
