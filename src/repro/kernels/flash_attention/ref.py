"""Pure-jnp oracle for flash attention (GQA + causal + sliding window)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int | None = None
                  ) -> jax.Array:
    """q: (B, Hq, S, hd); k,v: (B, Hkv, T, hd) -> (B, Hq, S, hd).
    Full materialized softmax in fp32 — the correctness reference."""
    B, Hq, S, hd = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    g = Hq // Hkv
    qg = q.reshape(B, Hkv, g, S, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgsh,bkth->bkgst", qg, kf) / math.sqrt(hd)
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window is not None:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)           # fully-masked rows
    out = jnp.einsum("bkgst,bkth->bkgsh", p, vf)
    return out.reshape(B, Hq, S, hd).astype(q.dtype)
