"""Public jit'd wrapper.  Model layout (B, S, H, hd) <-> kernel layout
(B, H, S, hd); interpret mode auto-selected off-TPU so ``attn_impl='flash'``
runs (slowly but exactly) on CPU for validation."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel, ref


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """q: (B, S, Hq, hd); k,v: (B, T, Hkv, hd) -> (B, S, Hq, hd)."""
    if interpret is None:
        interpret = _auto_interpret()
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    S, T = qt.shape[2], kt.shape[2]
    bq = _largest_divisor_block(S, block_q)
    bk = _largest_divisor_block(T, block_k)
    out = kernel.flash_attention_fwd(
        qt, kt, vt, causal=causal, window=window,
        block_q=bq, block_k=bk, interpret=interpret)
    return jnp.swapaxes(out, 1, 2)


def _largest_divisor_block(n: int, cap: int) -> int:
    b = min(cap, n)
    while n % b:
        b -= 1
    return b


def attention_ref(q, k, v, *, causal=True, window=None):
    """Oracle in model layout (re-exported for tests/benches)."""
    return jnp.swapaxes(
        ref.attention_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                          jnp.swapaxes(v, 1, 2), causal=causal,
                          window=window), 1, 2)
