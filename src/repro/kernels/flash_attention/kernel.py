"""Blocked online-softmax attention (Flash-style), TPU-adapted.

TPU adaptation of the GPU flash-attention insight (tile + online softmax
to keep the S x T score matrix out of HBM): tiles are sized for VMEM and
the MXU (q/k blocks of 128/256 rows, lane dim = head_dim), the kv-block
loop is the *innermost sequential grid dimension* (TPU grids execute the
trailing axis in order on one core, so the running (m, l, acc) state
lives in VMEM scratch across grid steps — the TPU analogue of a CUDA
thread-block's registers), and causal/SWA tiles that are fully masked are
skipped with ``pl.when`` rather than warp-level predication.

Supports: causal or full attention, sliding windows (mixtral), GQA
(q-head -> kv-head g:1 mapping done in the BlockSpec index map — no
repeated KV in HBM).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int | None,
            block_q: int, block_k: int, n_kv_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # static-shape block skip: diag/band structure known from block indices
    reachable = True
    if causal:
        reachable = k_start <= q_start + block_q - 1   # traced (dynamic ids)
    if window is not None:
        reachable = jnp.logical_and(
            reachable, k_start + block_k - 1 > q_start - window)

    @pl.when(reachable)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                            # (bq, 128)
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)      # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
        alpha = jnp.exp(m_prev - m_new)                # (bq, 128)
        p = jnp.exp(s - m_new[:, :1])                  # (bq, bk)
        p = jnp.where(mask, p, 0.0)                    # kill exp(NEG-NEG)=1
        l_new = alpha * l_prev + jnp.broadcast_to(
            jnp.sum(p, axis=1, keepdims=True), l_prev.shape)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)        # (bq, hd)
        acc_scr[...] = acc_scr[...] * alpha[:, :1] + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = l_scr[...][:, :1]                          # (bq, 1)
        l = jnp.where(l == 0.0, 1.0, l)                # fully-masked rows
        o_ref[0, 0, :, :] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int | None = None,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False) -> jax.Array:
    """q: (B, Hq, S, hd); k,v: (B, Hkv, T, hd) -> (B, Hq, S, hd)."""
    B, Hq, S, hd = q.shape
    _, Hkv, T, _ = k.shape
    assert Hq % Hkv == 0
    g = Hq // Hkv
    bq, bk = min(block_q, S), min(block_k, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)
    n_kv = T // bk
    scale = 1.0 / math.sqrt(hd)

    grid = (B, Hq, S // bq, n_kv)
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=bq, block_k=bk, n_kv_blocks=n_kv)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),        # running max
            pltpu.VMEM((bq, 128), jnp.float32),        # running denom
            pltpu.VMEM((bq, hd), jnp.float32),         # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
