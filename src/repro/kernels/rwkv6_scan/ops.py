"""Public jit'd wrapper for the WKV6 kernel.  Model layout (b,S,nh,hd)
<-> kernel layout (b,nh,S,hd); the within-chunk decay cumsum is
precomputed here.  ``S0`` (a carried state) short-circuits to the jnp
chunked form — the kernel path is the S0=None training/prefill hot path."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
         u: jax.Array, *, chunk: int = 32, S0: jax.Array | None = None,
         interpret: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """r,k,v,logw: (b,S,nh,hd); u: (nh,hd) -> (o, S_final).
    Matches ref.wkv6_ref."""
    if S0 is not None:
        from repro.models.rwkv6 import wkv6_chunked
        return wkv6_chunked(r, k, v, logw.astype(jnp.float32), u,
                            chunk=chunk, S0=S0)
    if interpret is None:
        interpret = _auto_interpret()
    b, S, nh, hd = r.shape
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)

    def to_k(t):
        return jnp.moveaxis(t, 2, 1)                   # (b,nh,S,hd)

    lw = to_k(logw.astype(jnp.float32))
    lw_c = lw.reshape(b, nh, S // Q, Q, hd)
    cum = jnp.cumsum(lw_c, axis=3).reshape(b, nh, S, hd)

    o, S_fin = kernel.wkv6_fwd(to_k(r), to_k(k), to_k(v), cum, lw, u,
                               chunk=Q, interpret=interpret)
    return jnp.moveaxis(o, 1, 2), S_fin
