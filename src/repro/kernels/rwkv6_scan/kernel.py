"""Chunked RWKV6 (Finch) WKV scan as a Pallas TPU kernel.

TPU adaptation notes (vs the CUDA wkv6 kernel, which assigns one warp per
(batch, head) and serializes over time): the per-channel data-dependent
decay makes the intra-chunk term NOT factorizable into a plain matmul —
``score[t,s] = sum_d r[t,d] k[s,d] exp(W_{t-1,d} - W_{s,d})`` carries the
decay *inside* the contraction.  Naively factoring ``exp(W_t)·exp(-W_s)``
overflows fp32 (W is a large negative cumsum), so the kernel materializes
the (Q, Q, hd) decay tensor per chunk in VMEM and contracts on the VPU —
chunk size Q is chosen so that tensor fits comfortably (Q=32: 256 KiB).
The inter-chunk state (hd, hd) recurrence and its output projection stay
on the MXU, carried in VMEM scratch across the sequential chunk axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, cum_ref, lw_ref, u_ref, o_ref, sout_ref,
            s_scr, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    rc = r_ref[0, 0].astype(jnp.float32)             # (Q, hd)
    kc = k_ref[0, 0].astype(jnp.float32)             # (Q, hd)
    vc = v_ref[0, 0].astype(jnp.float32)             # (Q, hd)
    cum = cum_ref[0, 0].astype(jnp.float32)          # (Q, hd) inclusive cumsum
    lw = lw_ref[0, 0].astype(jnp.float32)            # (Q, hd) log-decays
    u = u_ref[0].astype(jnp.float32)                 # (1, hd) bonus
    S = s_scr[...]                                   # (hd, hd) entering state

    dec_t = cum - lw                                 # W_{t-1} (exclusive)

    # ---- intra-chunk (strictly below diagonal): VPU decay tensor
    expo = dec_t[:, None, :] - cum[None, :, :]       # (Q, Q, hd)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk, 1), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk, 1), 1)
    strict = s_idx < t_idx
    w_ts = jnp.exp(jnp.where(strict, expo, -jnp.inf))  # (Q, Q, hd)
    scores = jnp.sum(rc[:, None, :] * w_ts * kc[None, :, :], axis=-1)  # (Q,Q)
    y = jax.lax.dot_general(scores, vc, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # ---- diagonal bonus: (r_t . (u k_t)) v_t
    diag = jnp.sum(rc * u * kc, axis=-1, keepdims=True)  # (Q, 1)
    y = y + diag * vc

    # ---- inter-chunk: y[t] += (r_t * exp(W_{t-1})) @ S
    y = y + jax.lax.dot_general(rc * jnp.exp(dec_t), S,
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o_ref[0, 0] = y.astype(o_ref.dtype)

    # ---- state update: S' = diag(exp(cum_Q)) S + (k * exp(cum_Q - cum))^T v
    gamma = jnp.exp(cum[chunk - 1])                  # (hd,)
    tail = jnp.exp(cum[chunk - 1:chunk, :] - cum)    # (Q, hd)
    s_scr[...] = S * gamma[:, None] + jax.lax.dot_general(
        kc * tail, vc, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # (hd, hd)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        sout_ref[0, 0] = s_scr[...]


def wkv6_fwd(r: jax.Array, k: jax.Array, v: jax.Array, cum: jax.Array,
             logw: jax.Array, u: jax.Array, *, chunk: int,
             interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """r,k,v,cum,logw: (b, nh, S, hd); u: (nh, hd).
    -> (o (b, nh, S, hd), S_final (b, nh, hd, hd))."""
    b, nh, S, hd = r.shape
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    grid = (b, nh, nc)

    seq_spec = pl.BlockSpec((1, 1, Q, hd), lambda i, h, c: (i, h, c, 0))
    return pl.pallas_call(
        functools.partial(_kernel, chunk=Q, n_chunks=nc),
        grid=grid,
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((1, hd), lambda i, h, c: (h, 0))],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, 1, hd, hd), lambda i, h, c: (i, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nh, S, hd), r.dtype),
            jax.ShapeDtypeStruct((b, nh, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, cum, logw, u)
