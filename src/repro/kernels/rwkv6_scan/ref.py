"""Pure-jnp oracle: the sequential WKV6 recurrence.

    o_t = r_t . (diag(u) k_t v_t^T + S_{t-1})
    S_t = diag(exp(logw_t)) S_{t-1} + k_t v_t^T
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
             u: jax.Array, S0: jax.Array | None = None
             ) -> tuple[jax.Array, jax.Array]:
    """r,k,v,logw: (b,S,nh,hd); u: (nh,hd).
    -> (o (b,S,nh,hd), S_final (b,nh,hd,hd))."""
    b, S, nh, hd = r.shape
    St = jnp.zeros((b, nh, hd, hd), jnp.float32) if S0 is None else S0

    def step(St, inp):
        r_t, k_t, v_t, lw_t = (t.astype(jnp.float32) for t in inp)
        kv = jnp.einsum("bhd,bhe->bhde", k_t, v_t)
        o = jnp.einsum("bhd,bhde->bhe", r_t,
                       St + u.astype(jnp.float32)[None, :, :, None] * kv)
        St = St * jnp.exp(lw_t)[..., None] + kv
        return St, o

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, logw))
    S_fin, os_ = jax.lax.scan(step, St, xs)
    return jnp.moveaxis(os_, 0, 1).astype(r.dtype), S_fin
