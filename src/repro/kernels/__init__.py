"""Pallas TPU kernels for the compute hot-spots of the training substrate
the HOPAAS service orchestrates: blocked flash attention (dense/GQA/SWA
archs), the chunked Mamba2 SSD scan (ssm/hybrid archs), and the chunked
RWKV6 WKV scan.  Each subpackage ships ``kernel.py`` (pl.pallas_call +
BlockSpec VMEM tiling), ``ops.py`` (the jit'd public wrapper; interpret
mode auto-selected off-TPU), and ``ref.py`` (the pure-jnp oracle the tests
sweep against)."""
