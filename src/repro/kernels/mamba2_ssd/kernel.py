"""Chunked Mamba2 SSD scan as a Pallas TPU kernel.

TPU adaptation of the SSD algorithm (Dao & Gu 2024): the GPU version
leans on warp-level matmuls per chunk; here each (batch, head, chunk)
grid cell does three MXU matmuls (C@B^T scores, masked-decay @ x for the
intra-chunk term, and the rank-ds state update) with the inter-chunk
recurrence carried in VMEM scratch across the *sequential* trailing grid
axis — the chunk loop never leaves the core, so the O(S) recurrence costs
one (hd, ds) state tile instead of an HBM round-trip per chunk.

Inputs are pre-conditioned in ops.py (dt-weighted x, per-head log-decay
cumsums) so the kernel body is pure tile math.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(xw_ref, cum_ref, b_ref, c_ref, o_ref, hout_ref, h_scr, *,
            chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    xc = xw_ref[0, 0].astype(jnp.float32)            # (Q, hd)
    cum = cum_ref[0, 0].astype(jnp.float32)          # (Q, 1)
    Bc = b_ref[0].astype(jnp.float32)                # (Q, ds)
    Cc = c_ref[0].astype(jnp.float32)                # (Q, ds)
    h = h_scr[...]                                   # (hd, ds) entering state

    # ---- intra-chunk: y[t] = sum_{s<=t} exp(cum_t-cum_s) (C_t.B_s) x[s]
    scores = jax.lax.dot_general(Cc, Bc, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q,Q)
    dec = cum - cum.reshape(1, chunk)                # cum_t - cum_s
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    # mask inside the exp argument: the dead (s>t) branch has dec>0 and
    # exp(dec) may overflow to inf before the where selects it away
    M = jnp.exp(jnp.where(s_idx <= t_idx, dec, -jnp.inf)) * scores
    y = jax.lax.dot_general(M, xc, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)       # (Q,hd)

    # ---- inter-chunk: y[t] += exp(cum_t) * C_t . h_enter
    y = y + jax.lax.dot_general(Cc, h, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) \
        * jnp.exp(cum)
    o_ref[0, 0] = y.astype(o_ref.dtype)

    # ---- state update: h' = gamma h + (x * exp(cum_Q - cum))^T B
    gamma = jnp.exp(cum[chunk - 1, 0])
    tail = jnp.exp(cum[chunk - 1, 0] - cum)          # (Q, 1)
    h_scr[...] = h * gamma + jax.lax.dot_general(
        xc * tail, Bc, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # (hd, ds)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        hout_ref[0, 0] = h_scr[...]


def ssd_fwd(xw: jax.Array, cum: jax.Array, B: jax.Array, C: jax.Array, *,
            chunk: int, interpret: bool = False
            ) -> tuple[jax.Array, jax.Array]:
    """xw: (b, nh, S, hd) dt-weighted inputs; cum: (b, nh, S, 1) inclusive
    in-chunk log-decay cumsum; B, C: (b, S, ds).
    -> (y (b, nh, S, hd), h_final (b, nh, hd, ds))."""
    b, nh, S, hd = xw.shape
    ds = B.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    grid = (b, nh, nc)

    return pl.pallas_call(
        functools.partial(_kernel, chunk=Q, n_chunks=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, hd), lambda i, h, c: (i, h, c, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda i, h, c: (i, h, c, 0)),
            pl.BlockSpec((1, Q, ds), lambda i, h, c: (i, c, 0)),
            pl.BlockSpec((1, Q, ds), lambda i, h, c: (i, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, hd), lambda i, h, c: (i, h, c, 0)),
            pl.BlockSpec((1, 1, hd, ds), lambda i, h, c: (i, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nh, S, hd), xw.dtype),
            jax.ShapeDtypeStruct((b, nh, hd, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, ds), jnp.float32)],
        interpret=interpret,
    )(xw, cum, B, C)
