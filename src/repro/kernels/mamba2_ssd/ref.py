"""Pure-jnp oracle: the sequential (non-chunked) SSD recurrence.

    h_t = exp(dt_t * A) h_{t-1} + dt_t * x_t B_t^T        (per head)
    y_t = C_t . h_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x: jax.Array, dt: jax.Array, a_log: jax.Array, B: jax.Array,
            C: jax.Array, h0: jax.Array | None = None
            ) -> tuple[jax.Array, jax.Array]:
    """x: (b,S,nh,hd); dt: (b,S,nh); a_log: (nh,); B,C: (b,S,ds).
    -> (y (b,S,nh,hd), h_final (b,nh,hd,ds))."""
    b, S, nh, hd = x.shape
    ds = B.shape[-1]
    A = -jnp.exp(a_log.astype(jnp.float32))
    h = jnp.zeros((b, nh, hd, ds), jnp.float32) if h0 is None else h0

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp                     # (b,nh,hd),(b,nh),(b,ds)
        g = jnp.exp(dt_t.astype(jnp.float32) * A)     # (b,nh)
        upd = jnp.einsum("bhd,bs->bhds",
                         (x_t * dt_t[..., None]).astype(jnp.float32),
                         B_t.astype(jnp.float32))
        h = h * g[:, :, None, None] + upd
        y = jnp.einsum("bhds,bs->bhd", h, C_t.astype(jnp.float32))
        return h, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(B, 1, 0), jnp.moveaxis(C, 1, 0))
    h_fin, ys = jax.lax.scan(step, h, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h_fin
