"""Public jit'd wrapper for the SSD kernel.

Model layout x: (b, S, nh, hd) <-> kernel layout (b, nh, S, hd); the
dt-weighting and per-chunk log-decay cumsum are precomputed here (cheap,
bandwidth-bound, XLA-fusable) so the kernel is pure tile math."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x: jax.Array, dt: jax.Array, a_log: jax.Array, B: jax.Array,
        C: jax.Array, *, chunk: int = 64, interpret: bool | None = None
        ) -> tuple[jax.Array, jax.Array]:
    """x: (b,S,nh,hd); dt: (b,S,nh); a_log: (nh,); B,C: (b,S,ds).
    -> (y (b,S,nh,hd), h_final (b,nh,hd,ds)).  Matches ref.ssd_ref."""
    if interpret is None:
        interpret = _auto_interpret()
    b, S, nh, hd = x.shape
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)

    A = -jnp.exp(a_log.astype(jnp.float32))                    # (nh,)
    dtf = dt.astype(jnp.float32)
    ldec = dtf * A                                             # (b,S,nh)
    # inclusive cumsum *within* each chunk
    ldec_c = ldec.reshape(b, S // Q, Q, nh)
    cum = jnp.cumsum(ldec_c, axis=2).reshape(b, S, nh)
    cum_k = jnp.moveaxis(cum, -1, 1)[..., None]                # (b,nh,S,1)
    xw = jnp.moveaxis(x * dt[..., None].astype(x.dtype), 2, 1)  # (b,nh,S,hd)

    y, h_fin = kernel.ssd_fwd(xw, cum_k, B, C, chunk=Q, interpret=interpret)
    return jnp.moveaxis(y, 1, 2), h_fin
