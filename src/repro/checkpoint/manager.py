"""Checkpointing for fault-tolerant training (no orbax in the image).

* **Atomic**: writes go to ``step_XXXX.tmp`` then ``os.replace`` — a crash
  mid-save never corrupts the latest checkpoint.
* **Async**: device->host transfer happens on the caller thread (cheap),
  serialization + fsync on a background thread — the train loop blocks
  only if a previous save is still in flight (single-buffer back-pressure).
* **Elastic / reshardable**: arrays are stored *unsharded* (host-gathered)
  with the pytree structure; ``restore`` re-device_puts against whatever
  mesh/sharding the *new* job passes in, so restarts may change topology
  (e.g. 256 -> 512 chips) — the ZeRO/FSDP layout is re-derived, not stored.
* **Self-pruning**: keeps the newest ``keep`` checkpoints.

Format: one ``.npz`` per step with flattened-keypath arrays + a JSON
manifest of the treedef and scalar metadata.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V":         # bf16 etc: not .npz-native;
            arr = arr.astype(np.float32)  # bf16 -> f32 is exact
        flat[key] = arr
    return flat


def save_tree(path: str, tree: Any, metadata: dict | None = None) -> None:
    """Blocking atomic save of one pytree."""
    flat = _flatten_with_paths(tree)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    if metadata is not None:
        mtmp = path + ".meta.tmp"
        with open(mtmp, "w") as f:
            json.dump(metadata, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(mtmp, path + ".meta")


def restore_tree(path: str, like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of ``like``; if ``shardings`` given,
    device_put each leaf to its (possibly brand-new) sharding."""
    with np.load(path) as zf:
        flat = {k: zf[k] for k in zf.files}
    paths_leaves = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_, leaf in paths_leaves[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_)
        arr = flat[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        leaves.append(arr.astype(leaf.dtype))
    tree = jax.tree_util.tree_unflatten(paths_leaves[1], leaves)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ---------------- write path ----------------
    def save(self, step: int, tree: Any, metadata: dict | None = None,
             blocking: bool = False) -> None:
        self.wait()                              # single in-flight save
        host_tree = jax.tree.map(np.asarray, tree)   # device->host now
        meta = dict(metadata or {}, step=step)

        def work():
            save_tree(self._path(step), host_tree, meta)
            self._prune()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ---------------- read path ----------------
    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def all_steps(self) -> list[int]:
        out = []
        for fn in os.listdir(self.directory):
            if fn.startswith("step_") and fn.endswith(".npz"):
                out.append(int(fn[5:-4]))
        return sorted(out)

    def restore(self, step: int, like: Any, shardings: Any = None
                ) -> tuple[Any, dict]:
        path = self._path(step)
        tree = restore_tree(path, like, shardings)
        meta = {}
        if os.path.exists(path + ".meta"):
            with open(path + ".meta") as f:
                meta = json.load(f)
        return tree, meta

    def restore_latest(self, like: Any, shardings: Any = None
                       ) -> tuple[Any, dict] | None:
        step = self.latest_step()
        if step is None:
            return None
        return self.restore(step, like, shardings)

    # ---------------- internals ----------------
    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}.npz")

    def _prune(self) -> None:
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            for suffix in (".npz", ".npz.meta"):
                p = os.path.join(self.directory, f"step_{s:08d}{suffix}")
                if os.path.exists(p):
                    os.remove(p)
