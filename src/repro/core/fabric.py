"""Multi-process shard fabric: a consistent-hash worker pool behind the
event-loop frontend.

The paper deploys Hopaas as "a scalable set of Uvicorn instances behind
NGINX" (sec. 3).  PRs 1-5 made one Python process fast; the GIL is now
the wall.  This module spreads the study shards across N *worker
processes*, extending PR 5's crc32 study-key lane dispatch across the
process boundary:

* **Workers** — each worker process runs its own ``EventLoopFrontend``
  + ``HopaasServer`` over a consistent-hash slice of the study shards,
  with a *private* durable WAL directory (``root/worker-<id>``, guarded
  by an exclusive flock so two processes can never share a segment
  stream).
* **Router** — the parent process fronts the fleet with a dispatcher
  plugged into the event-loop frontend: each request is classified to
  its study key (URL, trial uid, or study-spec content hash), mapped to
  the owning worker through a consistent-hash ring, and proxied as raw
  bytes over a per-lane persistent upstream connection.  Requests for
  one study always flow through one lane to one worker, so the
  per-study ordering the single-process frontend guaranteed survives
  the process split.  Study lists scatter-gather across the fleet;
  ``tell_batch`` bodies are split by owner and merged back in order.
  Where the platform offers ``SO_REUSEPORT`` the workers can accept on
  the public port directly (``reuseport=True``) — every worker runs the
  same dispatcher, so a connection landing on a non-owner is forwarded
  one hop to the owner; the router's byte-level proxy remains the
  portable fallback accept point on the same port.
* **Shard handoff** (rebalance on worker join/leave) — the owning
  worker freezes the shard (requests get a retryable 503
  ``shard_migrating`` under the shard lock, so nothing mutates after
  the cut), seals its WAL, and ships snapshot + sealed segments to the
  new owner, which filter-replays the shard's records into a shadow
  store and adopts it only if ``InMemoryStorage.shard_digest`` matches
  the exporter's — index-identical or no cutover.  Traffic flips via a
  per-key override pushed to every routing table before the old owner
  drops the shard, so no request ever lands on a missing shard.
* **Crash respawn** — a monitor thread respawns dead workers on their
  own WAL directory (digest-verified recovery via the WAL), re-pushes
  the endpoint table, and sweeps lapsed leases so trials leased through
  the dead worker are requeued.  A worker that hangs mid-request trips
  the proxy's per-upstream timeout and the client sees a retryable 502
  ``bad_upstream`` instead of a hung router.
* **Replication + failover** (``replicas > 0``, durable storage) —
  every leader worker publishes its WAL stream through a
  ``ReplicationHub``; per-leader follower processes subscribe with a
  ``ReplicationClient`` and continuously replay the stream into their
  own journaled store (``--replication semisync`` makes the leader's
  fsync ack additionally wait for a follower ack).  When the monitor
  declares a leader dead (process exit) or hung (control-plane pings
  failing for ``hang_grace`` seconds), it promotes the most-caught-up
  follower: the follower replays the dead leader's WAL directory
  read-only as the digest authority, reconciles, bumps the lease
  epoch, and takes over the dead leader's ring id — the routing
  tables flip workers-first, so placement never changes.  A deposed
  leader that comes back is *fenced*: the monitor delivers the new
  epoch and every data-plane request it would serve answers a
  retryable 409 ``shard_failover``.

``ShardFabric(workers=1, replicas=0)`` collapses to the plain
single-process event-loop service (no children, no proxy hop) so N=1
matches PR 5's numbers exactly.
"""
from __future__ import annotations

import bisect
import contextlib
import http.client
import json
import logging
import os
import select
import signal
import socket
import subprocess
import sys
import tempfile
import threading
import time
import zlib
from typing import Any

from . import faults
from .aio import (EventLoopFrontend, _encode_body, _encode_response,
                  _study_key_of_target)
from .api.errors import error_payload
from .auth import AuthError, TokenManager, bearer_token
from .durable import DurableStorage
from .replication import (ReplicationClient, ReplicationHub,
                          recover_dir_state, reconcile_with)
from .server import HopaasServer
from .storage import InMemoryStorage, record_study_key

logger = logging.getLogger("repro.fabric")

_HOP_HEADER = "X-Fabric-Hop"
_SCOPE_HEADER = "X-Fabric-Scope"
_MAX_HOPS = 2
_GATHER_PAGE = 500                     # upstream page size for scatters


# --------------------------------------------------------------------- #
# consistent-hash ring
# --------------------------------------------------------------------- #
class HashRing:
    """Consistent-hash ring over integer worker ids with virtual nodes.

    Key placement is a pure function of the *live id set*: adding a
    worker remaps only the keys the new worker takes over, removing one
    remaps only the keys it owned — the property that keeps a rebalance
    proportional to 1/N of the studies instead of a full reshuffle.
    crc32 is used for both vnode points and keys so every process
    (router, workers, clients) computes identical placement.
    """

    def __init__(self, worker_ids, replicas: int = 64):
        self.worker_ids = sorted(set(int(w) for w in worker_ids))
        if not self.worker_ids:
            raise ValueError("HashRing needs at least one worker id")
        self.replicas = max(1, int(replicas))
        points: list[tuple[int, int]] = []
        for wid in self.worker_ids:
            for v in range(self.replicas):
                h = zlib.crc32(f"fabric-{wid}#{v}".encode()) & 0xFFFFFFFF
                points.append((h, wid))
        points.sort()
        self._points = [p for p, _ in points]
        self._owners = [w for _, w in points]

    def owner(self, key: str) -> int:
        h = zlib.crc32(key.encode()) & 0xFFFFFFFF
        i = bisect.bisect_right(self._points, h) % len(self._points)
        return self._owners[i]


class RouteTable:
    """Mutable routing state shared by one dispatcher: worker endpoints,
    the ring membership, and per-key overrides (the cutover mechanism —
    during a handoff the override flips one study to its new owner
    before the ring itself moves)."""

    def __init__(self, endpoints: dict[int, tuple[str, int]] | None = None,
                 self_id: int | None = None, replicas: int = 64):
        self._lock = threading.Lock()
        self.self_id = self_id
        self.replicas = int(replicas)
        self._endpoints: dict[int, tuple[str, int]] = dict(endpoints or {})
        self._ring_ids: list[int] = sorted(self._endpoints)
        self._ring = (HashRing(self._ring_ids, replicas)
                      if self._ring_ids else None)
        self._overrides: dict[str, int] = {}

    def update(self, endpoints: dict[int, tuple[str, int]] | None = None,
               ring_ids: list[int] | None = None,
               overrides: dict[str, int] | None = None,
               clear_overrides: bool = False) -> None:
        with self._lock:
            if endpoints is not None:
                self._endpoints = dict(endpoints)
            if ring_ids is not None:
                self._ring_ids = sorted(set(int(w) for w in ring_ids))
            elif endpoints is not None and self._ring is None:
                self._ring_ids = sorted(self._endpoints)
            if self._ring_ids:
                self._ring = HashRing(self._ring_ids, self.replicas)
            if clear_overrides:
                self._overrides = {}
            if overrides:
                self._overrides.update(
                    {str(k): int(v) for k, v in overrides.items()})

    def owner(self, key: str) -> int:
        with self._lock:
            wid = self._overrides.get(key)
            if wid is not None:
                return wid
            if self._ring is None:
                raise RuntimeError("routing table has no workers")
            return self._ring.owner(key)

    def default_owner(self) -> int:
        with self._lock:
            if not self._ring_ids:
                raise RuntimeError("routing table has no workers")
            return self._ring_ids[0]

    def worker_ids(self) -> list[int]:
        with self._lock:
            return list(self._ring_ids)

    def n_workers(self) -> int:
        with self._lock:
            return len(self._ring_ids)

    def endpoint(self, wid: int) -> tuple[str, int]:
        with self._lock:
            return self._endpoints[wid]

    def snapshot(self) -> dict[str, Any]:
        with self._lock:
            return {
                "endpoints": {str(w): list(ep)
                              for w, ep in self._endpoints.items()},
                "ring_ids": list(self._ring_ids),
                "overrides": dict(self._overrides),
            }


# --------------------------------------------------------------------- #
# request classification (shared by dispatcher + worker freeze gate)
# --------------------------------------------------------------------- #
def classify_target(method: str, target: str) -> tuple:
    """Route class of one request: ("key", k) for URL-keyed paths,
    ("spec",) when the study key is the content hash of the body's
    study spec, ("uid",) when it is derived from a trial uid in the
    body, ("tell_batch",) / ("gather",) for the scatter endpoints, and
    ("default",) for everything keyless."""
    path = target.partition("?")[0]
    key = _study_key_of_target(path)
    if key is not None:
        return ("key", key)
    if path == "/api/v2/trials:tell_batch":
        return ("tell_batch",) if method == "POST" else ("default",)
    if path == "/api/v2/studies":
        if method == "POST":
            return ("spec",)
        if method in ("GET", "HEAD"):
            return ("gather",)
        return ("default",)
    parts = path.split("/")
    if len(parts) == 4 and parts[0] == "" and parts[1] == "api":
        op = parts[2]
        if op in ("ask", "ask_batch"):
            return ("spec",) if method == "POST" else ("default",)
        if op in ("tell", "should_prune"):
            return ("uid",) if method == "POST" else ("default",)
        if op == "tell_batch":
            return ("tell_batch",) if method == "POST" else ("default",)
        if op == "studies":
            return ("gather",) if method in ("GET", "HEAD") else ("default",)
    return ("default",)


def _key_from_spec(body: Any) -> str | None:
    """Study content key from an ask / create-study body, or None when
    the body cannot produce one (the owning default worker will then
    emit the proper validation error)."""
    if not isinstance(body, dict):
        return None
    try:
        return HopaasServer._study_config(body).key()
    except Exception:
        return None


def _key_from_uid(body: Any) -> str | None:
    if not isinstance(body, dict):
        return None
    uid = body.get("trial_uid")
    if not isinstance(uid, str) or ":" not in uid:
        return None
    return uid.partition(":")[0]


def request_study_keys(method: str, target: str, body: Any) -> list[str]:
    """Concrete study key(s) a request touches — the freeze gate's view.
    Empty list = keyless (never gated)."""
    kind = classify_target(method, target)
    if kind[0] == "key":
        return [kind[1]]
    if kind[0] == "spec":
        key = _key_from_spec(body)
        return [key] if key else []
    if kind[0] == "uid":
        key = _key_from_uid(body)
        return [key] if key else []
    if kind[0] == "tell_batch":
        if not isinstance(body, dict) or not isinstance(body.get("tells"),
                                                        list):
            return []
        keys = []
        for item in body["tells"]:
            key = _key_from_uid(item)
            if key:
                keys.append(key)
        return sorted(set(keys))
    return []


# --------------------------------------------------------------------- #
# upstream proxy connections
# --------------------------------------------------------------------- #
class _UpstreamConn:
    """One blocking keep-alive connection to a worker's data port.  Lane
    threads each own their connections, so per-study request order is
    preserved across the proxy hop (one study -> one lane -> one
    ordered byte stream to one worker)."""

    def __init__(self, host: str, port: int, timeout: float):
        self.sock = socket.create_connection((host, port), timeout=timeout)
        try:
            self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass
        self._buf = b""

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass

    def roundtrip(self, data: bytes, head: bool = False
                  ) -> tuple[int, list[tuple[str, str]], bytes]:
        self.sock.sendall(data)
        while b"\r\n\r\n" not in self._buf:
            chunk = self.sock.recv(65536)
            if not chunk:
                raise ConnectionError("upstream closed the connection")
            self._buf += chunk
        head_blob, _, rest = self._buf.partition(b"\r\n\r\n")
        lines = head_blob.split(b"\r\n")
        try:
            status = int(lines[0].split(None, 2)[1])
        except (IndexError, ValueError):
            raise ConnectionError("malformed upstream status line")
        headers: list[tuple[str, str]] = []
        clen = 0
        for ln in lines[1:]:
            name, sep, val = ln.partition(b":")
            if not sep:
                continue
            k = name.decode("latin-1").strip()
            v = val.decode("latin-1").strip()
            headers.append((k, v))
            if k.lower() == "content-length":
                try:
                    clen = int(v)
                except ValueError:
                    raise ConnectionError("malformed upstream Content-Length")
        if head:
            # HEAD responses advertise the would-be body length but never
            # send it — waiting on clen bytes would hang the lane
            self._buf = rest
            return status, headers, b""
        while len(rest) < clen:
            chunk = self.sock.recv(65536)
            if not chunk:
                raise ConnectionError("upstream closed mid-body")
            rest += chunk
        self._buf = rest[clen:]
        return status, headers, rest[:clen]


# failures that prove the reused idle socket died *before* the request
# was processed — safe to resend once on a fresh connection.  Timeouts
# are deliberately absent: a timed-out request may have been executed.
_RESEND_SAFE = (ConnectionResetError, BrokenPipeError, ConnectionError)

_HOP_BY_HOP = ("connection", "content-length", "content-type")


class FabricDispatcher:
    """The cross-process extension of the frontend's lane dispatch.

    Plugged into ``EventLoopFrontend(dispatcher=...)``: every request is
    offered here first.  Returns encoded response bytes (proxied from
    the owning worker, or a scatter-gather merge), or None when the
    local process owns the study (worker processes run the same
    dispatcher with ``local`` set, so misrouted requests forward one
    hop instead of being served from the wrong shard slice).
    """

    def __init__(self, table: RouteTable, local: Any = None,
                 timeout: float = 10.0):
        self._table = table
        self._local = local               # local request sink (workers)
        self._timeout = float(timeout)
        # lane.idx -> {wid: (endpoint, conn)}; each lane is a single
        # thread, so its connection map needs no lock.  The outer map is
        # only ever extended under _conns_lock via setdefault; the
        # lock-free .get() probe is a GIL-atomic read and a stale miss
        # just retries under the lock.
        self._conns: dict[int, dict[int, tuple[tuple[str, int],  # repro-check: allow(shared-state)
                                               _UpstreamConn]]] = {}
        self._conns_lock = threading.Lock()   # map-of-maps creation only
        # lossy observability counters: concurrent += from lanes may drop
        # an increment, which stats() tolerates by design
        self.proxied = 0  # repro-check: allow(shared-state)
        self.scatters = 0  # repro-check: allow(shared-state)
        self.bad_upstream = 0  # repro-check: allow(shared-state)

    # -- public entry (called by the frontend, lane threads only) ------- #
    def handle(self, lane, method: str, target: str,
               headers: dict[str, str], body_bytes: bytes,
               keep_alive: bool):
        if target.partition("?")[0].startswith("/fabric/"):
            if self._local is not None:
                return None              # worker control plane is local
            blob = _encode_body(error_payload(
                "not_found", "no /fabric control plane on the router"))
            return _encode_response(404, blob, close=not keep_alive,
                                    head_only=method == "HEAD")
        if headers.get(_SCOPE_HEADER) == "local":
            return None                  # scatter subrequest: no re-fanout
        try:
            hop = int(headers.get(_HOP_HEADER, 0))
        except (TypeError, ValueError):
            hop = 0
        kind = classify_target(method, target)
        single = self._table.n_workers() <= 1
        if kind[0] == "gather" and not single:
            self.scatters += 1
            if target.partition("?")[0] == "/api/v2/studies":
                return self._gather_studies_v2(lane, method, target,
                                               headers, keep_alive)
            return self._gather_studies_v1(lane, method, target, headers,
                                           keep_alive)
        if kind[0] == "tell_batch" and not single:
            self.scatters += 1
            return self._scatter_tell_batch(lane, target, headers,
                                            body_bytes, keep_alive)
        if kind[0] == "key":
            wid = self._owner_or_default(kind[1])
        elif kind[0] == "spec":
            wid = self._owner_or_default(_key_from_spec(
                self._parse_body(body_bytes)))
        elif kind[0] == "uid":
            wid = self._owner_or_default(_key_from_uid(
                self._parse_body(body_bytes)))
        else:
            wid = self._table.default_owner()
        if wid == self._table.self_id:
            return None
        if hop >= _MAX_HOPS and self._local is not None:
            # routing tables disagree mid-update: stop the ping-pong and
            # answer from here; the freeze gate still protects migrating
            # shards with a retryable 503
            return None
        self.proxied += 1
        return self._forward(lane, wid, method, target, headers,
                             body_bytes, keep_alive, hop + 1)

    def close(self) -> None:
        with self._conns_lock:
            lanes = list(self._conns.values())
            self._conns = {}
        for conns in lanes:
            for _ep, conn in conns.values():
                conn.close()

    def stats(self) -> dict[str, Any]:
        return {"proxied": self.proxied, "scatters": self.scatters,
                "bad_upstream": self.bad_upstream,
                "workers": self._table.n_workers()}

    # -- internals ------------------------------------------------------ #
    @staticmethod
    def _parse_body(body_bytes: bytes) -> Any:
        if not body_bytes:
            return None
        try:
            return json.loads(body_bytes)
        except ValueError:
            return None

    def _owner_or_default(self, key: str | None) -> int:
        if key is None:
            return self._table.default_owner()
        return self._table.owner(key)

    def _lane_conns(self, lane) -> dict:
        conns = self._conns.get(lane.idx)
        if conns is None:
            with self._conns_lock:
                conns = self._conns.setdefault(lane.idx, {})
        return conns

    @staticmethod
    def _encode_upstream(method: str, target: str, headers: dict[str, str],
                         body: bytes, hop: int,
                         scope_local: bool = False) -> bytes:
        lines = [f"{method} {target} HTTP/1.1"]
        for k, v in headers.items():
            if k.lower() in ("connection", "content-length") \
                    or k in (_HOP_HEADER, _SCOPE_HEADER):
                continue
            lines.append(f"{k}: {v}")
        lines.append(f"{_HOP_HEADER}: {hop}")
        if scope_local:
            lines.append(f"{_SCOPE_HEADER}: local")
        lines.append(f"Content-Length: {len(body)}")
        return ("\r\n".join(lines) + "\r\n\r\n").encode() + body

    def _roundtrip(self, lane, wid: int, data: bytes, head: bool = False
                   ) -> tuple[int, list[tuple[str, str]], bytes]:
        conns = self._lane_conns(lane)
        ep = self._table.endpoint(wid)
        entry = conns.get(wid)
        conn: _UpstreamConn | None = None
        reused = False
        if entry is not None:
            if entry[0] == ep:
                conn, reused = entry[1], True
            else:
                entry[1].close()         # worker respawned on a new port
                conns.pop(wid, None)
        for attempt in (0, 1):
            if conn is None:
                conn = _UpstreamConn(ep[0], ep[1], self._timeout)
                conns[wid] = (ep, conn)
                reused = False
            try:
                return conn.roundtrip(data, head=head)
            except _RESEND_SAFE:
                conn.close()
                conns.pop(wid, None)
                conn = None
                if reused and attempt == 0:
                    continue             # idle keep-alive died: one resend
                raise
            except Exception:
                conn.close()
                conns.pop(wid, None)
                raise
        raise ConnectionError("unreachable")

    def _forward(self, lane, wid: int, method: str, target: str,
                 headers: dict[str, str], body: bytes, keep_alive: bool,
                 hop: int) -> bytes:
        head_only = method == "HEAD"
        data = self._encode_upstream(method, target, headers, body, hop)
        try:
            status, up_headers, up_body = self._roundtrip(lane, wid, data,
                                                          head=head_only)
        except Exception as e:
            self.bad_upstream += 1
            blob = _encode_body(error_payload(
                "bad_upstream",
                f"worker {wid} did not answer: {type(e).__name__}: {e}"))
            return _encode_response(502, blob, close=not keep_alive,
                                    head_only=head_only)
        extras = {k: v for k, v in up_headers
                  if k.lower() not in _HOP_BY_HOP}
        if head_only:
            # relay the upstream's advertised length: the encoder frames
            # Content-Length from len(blob), and head_only drops the bytes
            clen = next((int(v) for k, v in up_headers
                         if k.lower() == "content-length"), 0)
            up_body = b"\x00" * clen
        return _encode_response(status, up_body, extras or None,
                                close=not keep_alive, head_only=head_only)

    def _sub_request(self, lane, wid: int, method: str, target: str,
                     headers: dict[str, str], body: Any
                     ) -> tuple[int, Any]:
        """One scatter subrequest: local direct call when this process
        owns ``wid``, else a scope-local proxied exchange (the receiver
        must not fan out again)."""
        if wid == self._table.self_id and self._local is not None:
            status, payload, _extra = self._local.handle_request(
                method, target, body, headers, None)
            return status, payload
        blob = b"" if body is None else _encode_body(body)
        data = self._encode_upstream(method, target, headers, blob,
                                     hop=_MAX_HOPS, scope_local=True)
        status, _up_headers, up_body = self._roundtrip(lane, wid, data)
        try:
            payload = json.loads(up_body) if up_body else {}
        except ValueError:
            raise ConnectionError("non-JSON scatter subresponse")
        return status, payload

    def _relay(self, status: int, payload: Any, keep_alive: bool,
               head_only: bool = False) -> bytes:
        return _encode_response(status, _encode_body(payload),
                                close=not keep_alive, head_only=head_only)

    def _upstream_error(self, wid: int, e: Exception,
                        keep_alive: bool) -> bytes:
        self.bad_upstream += 1
        blob = _encode_body(error_payload(
            "bad_upstream",
            f"worker {wid} did not answer: {type(e).__name__}: {e}"))
        return _encode_response(502, blob, close=not keep_alive)

    def _gather_studies_v2(self, lane, method: str, target: str,
                           headers: dict[str, str],
                           keep_alive: bool) -> bytes:
        head_only = method == "HEAD"
        try:
            limit, cursor = _parse_page_query(target.partition("?")[2])
        except ValueError:
            # invalid paging params: let the default worker's router
            # produce the canonical 422
            return self._forward(lane, self._table.default_owner(), method,
                                 target, headers, b"", keep_alive, 1)
        merged: list[dict] = []
        seen: set[str] = set()
        for wid in self._table.worker_ids():
            cur: int | None = None
            while True:
                t = f"/api/v2/studies?limit={_GATHER_PAGE}"
                if cur is not None:
                    t += f"&cursor={cur}"
                try:
                    status, payload = self._sub_request(lane, wid, "GET", t,
                                                        headers, None)
                except Exception as e:
                    return self._upstream_error(wid, e, keep_alive)
                if status != 200:
                    return self._relay(status, payload, keep_alive,
                                       head_only)
                for s in payload.get("studies", []):
                    k = s.get("key")
                    if k not in seen:
                        seen.add(k)
                        merged.append(s)
                cur = payload.get("next_cursor")
                if cur is None:
                    break
        start = 0 if cursor is None else cursor + 1
        page = merged[start:start + limit]
        next_cursor = (start + len(page) - 1) if len(page) == limit else None
        return self._relay(200, {"studies": page,
                                 "next_cursor": next_cursor},
                           keep_alive, head_only)

    def _gather_studies_v1(self, lane, method: str, target: str,
                           headers: dict[str, str],
                           keep_alive: bool) -> bytes:
        head_only = method == "HEAD"
        merged: list[dict] = []
        seen: set[str] = set()
        for wid in self._table.worker_ids():
            try:
                status, payload = self._sub_request(lane, wid, "GET", target,
                                                    headers, None)
            except Exception as e:
                return self._upstream_error(wid, e, keep_alive)
            if status != 200:
                return self._relay(status, payload, keep_alive, head_only)
            for s in payload.get("studies", []):
                k = s.get("key")
                if k not in seen:
                    seen.add(k)
                    merged.append(s)
        return self._relay(200, {"studies": merged}, keep_alive, head_only)

    def _scatter_tell_batch(self, lane, target: str,
                            headers: dict[str, str], body_bytes: bytes,
                            keep_alive: bool) -> bytes:
        body = self._parse_body(body_bytes)
        if not isinstance(body, dict) or not isinstance(body.get("tells"),
                                                        list):
            # malformed: the default worker produces the canonical error
            return self._forward(lane, self._table.default_owner(), "POST",
                                 target, headers, body_bytes, keep_alive, 1)
        tells = body["tells"]
        groups: dict[int, list[tuple[int, Any]]] = {}
        for i, item in enumerate(tells):
            key = _key_from_uid(item)
            wid = self._owner_or_default(key)
            groups.setdefault(wid, []).append((i, item))
        results: list[Any] = [None] * len(tells)
        for wid, items in groups.items():
            sub = dict(body)
            sub["tells"] = [item for _i, item in items]
            try:
                status, payload = self._sub_request(lane, wid, "POST",
                                                    target, headers, sub)
            except Exception as e:
                return self._upstream_error(wid, e, keep_alive)
            if status != 200:
                # whole-batch failure (auth / schema): relay it verbatim;
                # other owner groups may already have executed — their
                # retried items answer 409 per item, never double-count
                return self._relay(status, payload, keep_alive)
            sub_results = payload.get("results", [])
            for (i, _item), r in zip(items, sub_results):
                results[i] = r
        return self._relay(200, {"results": results}, keep_alive)


def _parse_page_query(query: str) -> tuple[int, int | None]:
    """``limit``/``cursor`` of a studies-list query with the router's
    bounds; raises ValueError on anything the router would 422."""
    import urllib.parse
    limit, cursor = 100, None
    for k, vals in urllib.parse.parse_qs(query,
                                         keep_blank_values=True).items():
        if k == "limit":
            limit = int(vals[-1])
            if not 1 <= limit <= 500:
                raise ValueError(f"limit out of range: {limit}")
        elif k == "cursor":
            cursor = int(vals[-1])
            if cursor < 0:
                raise ValueError(f"cursor out of range: {cursor}")
    return limit, cursor


# --------------------------------------------------------------------- #
# worker-process server wrapper: freeze gate + /fabric control plane
# --------------------------------------------------------------------- #
class FabricWorkerServer:
    """Wraps one ``HopaasServer`` for a fabric worker process.

    Adds the migration *freeze gate* — while a shard is being exported,
    every request touching it answers a retryable 503
    ``shard_migrating`` (the check runs under the shard lock, so a
    request that passed the gate finishes before the export reads the
    shard) — and the ``/fabric/*`` control plane (freeze / export /
    import / drop / ring / sweep / digest), authenticated with the same
    HMAC bearer tokens as the data plane.
    """

    def __init__(self, server: HopaasServer, worker_id: int = 0):
        self.server = server
        self.storage = server.storage
        self.tokens = server.tokens
        self.worker_id = int(worker_id)
        self.table: RouteTable | None = None     # attached by the host
        self._gate_lock = threading.Lock()
        self._frozen: set[str] = set()
        self._moved: set[str] = set()
        # replication / failover state (wired up by _serve_worker)
        self.role = "leader"
        self.fenced = False
        self.fence_epoch: int | None = None
        self.replication_mode = "async"
        self.hub: ReplicationHub | None = None
        self.repl_client: ReplicationClient | None = None

    @property
    def epoch(self) -> int:
        return int(getattr(self.storage, "lease_epoch", 0))

    # -- wire entry ----------------------------------------------------- #
    def handle_request(self, method: str, path: str, body: Any = None,
                       headers: dict[str, str] | None = None,
                       body_error: str | None = None
                       ) -> tuple[int, dict[str, Any], dict[str, str]]:
        if path.partition("?")[0].startswith("/fabric/"):
            return self._control(method, path.partition("?")[0], body,
                                 headers or {})
        gated = self._role_gate(method, path)
        if gated is not None:
            return gated
        keys = request_study_keys(method, path, body)
        if not keys:
            return self.server.handle_request(method, path, body, headers,
                                              body_error)
        with self._gate_lock:
            blocked = any(k in self._frozen or k in self._moved
                          for k in keys)
        if blocked:
            return self._migrating(keys)
        # hold every touched shard lock (sorted — same order as the
        # freeze path) across the whole dispatch: a freeze that lands
        # after this gate check waits for the request to finish, so the
        # exported shard always contains it
        with contextlib.ExitStack() as stack:
            for k in keys:
                try:
                    stack.enter_context(self.storage.study_lock(k))
                except KeyError:
                    continue             # study not created here (yet)
            with self._gate_lock:
                blocked = any(k in self._frozen or k in self._moved
                              for k in keys)
            if blocked:
                return self._migrating(keys)
            return self.server.handle_request(method, path, body, headers,
                                              body_error)

    def _role_gate(self, method: str, path: str
                   ) -> tuple[int, dict[str, Any], dict[str, str]] | None:
        """Data-plane admission by replication role.  Followers and
        fenced ex-leaders answer a retryable 409 ``shard_failover`` —
        the client's retry lands on the current leader once the routing
        tables flip.  Health and version probes stay answerable from
        any role (that is how lag is observed)."""
        if self.role == "leader" and not self.fenced:
            return None
        p = path.partition("?")[0]
        if method in ("GET", "HEAD") and p in ("/api/v2/health",
                                               "/api/v2/version"):
            return None
        if self.fenced:
            msg = (f"worker {self.worker_id} was deposed: lease epoch "
                   f"{self.epoch} is fenced by epoch {self.fence_epoch}; "
                   "retry against the current leader")
        else:
            msg = (f"worker {self.worker_id} is a replication follower "
                   "(read-only replica); retry against the leader")
        return 409, error_payload("shard_failover", msg), {
            "Retry-After": "0.1"}

    def health_extra(self) -> dict[str, Any]:
        """``HopaasServer.health_hook``: merge the fabric role, lease
        epoch, and live replication lag into ``GET /api/v2/health``."""
        out: dict[str, Any] = {"epoch": self.epoch}
        if self.fenced:
            out["status"] = "fenced"
            out["role"] = "leader"
        elif self.role != "leader":
            out["status"] = "follower"
            out["role"] = "follower"
        repl: dict[str, Any] = {}
        if self.hub is not None:
            repl["mode"] = self.replication_mode
            repl.update(self.hub.status())
        if self.repl_client is not None:
            repl["client"] = self.repl_client.status()
        if repl:
            out["replication"] = repl
        return out

    @staticmethod
    def _migrating(keys: list[str]
                   ) -> tuple[int, dict[str, Any], dict[str, str]]:
        payload = error_payload(
            "shard_migrating",
            f"stud{'ies' if len(keys) > 1 else 'y'} "
            f"{', '.join(keys)} is being rebalanced; retry")
        return 503, payload, {"Retry-After": "0.1"}

    # -- control plane -------------------------------------------------- #
    def _control(self, method: str, path: str, body: Any,
                 headers: dict[str, str]
                 ) -> tuple[int, dict[str, Any], dict[str, str]]:
        token = bearer_token(headers)
        if token is None:
            return 401, error_payload("unauthorized",
                                      "control plane needs a bearer "
                                      "token"), {}
        try:
            self.tokens.verify(token)
        except AuthError as e:
            return 401, error_payload("unauthorized", str(e)), {}
        body = body if isinstance(body, dict) else {}
        try:
            op = path[len("/fabric/"):]
            if op == "ping":
                return 200, {"ok": True, "worker": self.worker_id,
                             "pid": os.getpid()}, {}
            if op == "digest":
                return 200, {"digest": self.storage.state_digest()}, {}
            if op == "studies":
                return 200, {"keys": sorted(
                    s.key for s in self.storage.studies())}, {}
            if op == "stats":
                with self._gate_lock:
                    frozen = sorted(self._frozen)
                return 200, {"worker": self.worker_id, "pid": os.getpid(),
                             "frozen": frozen,
                             "storage": self.storage.storage_stats()}, {}
            if op == "shard_digest":
                digest = self.storage.shard_digest(str(body.get(
                    "study_key", "")))
                if digest is None:
                    return 404, error_payload("study_not_found",
                                              "unknown study"), {}
                return 200, {"digest": digest}, {}
            if op == "freeze":
                return self._op_freeze(str(body.get("study_key", "")))
            if op == "unfreeze":
                key = str(body.get("study_key", ""))
                with self._gate_lock:
                    self._frozen.discard(key)
                return 200, {"frozen": False}, {}
            if op == "export":
                return self._op_export(str(body.get("study_key", "")))
            if op == "import":
                return self._op_import(body)
            if op == "drop":
                return self._op_drop(str(body.get("study_key", "")))
            if op == "ring":
                return self._op_ring(body)
            if op == "sweep":
                if self.role != "leader":
                    # a follower's state is whatever the stream says —
                    # expiring leases locally would diverge from the WAL
                    return 200, {"expired": 0, "suppressed": True}, {}
                return 200, {"expired": self.server.sweep_expired()}, {}
            if op == "replication":
                return 200, self._replication_status(), {}
            if op == "promote":
                return self._op_promote(body)
            if op == "fence":
                return self._op_fence(body)
            return 404, error_payload("not_found",
                                      f"unknown control op {op!r}"), {}
        except Exception as e:          # control bugs must not kill the gate
            logger.exception("control op %s failed", path)
            return 500, error_payload(
                "internal", f"{type(e).__name__}: {e}"), {}

    def _op_freeze(self, key: str
                   ) -> tuple[int, dict[str, Any], dict[str, str]]:
        try:
            lock = self.storage.study_lock(key)
        except KeyError:
            return 404, error_payload("study_not_found",
                                      f"unknown study {key!r}"), {}
        # taking the shard lock fences out every in-flight request that
        # already passed the gate; once we hold it, the freeze flag is
        # visible before any further mutation can start
        with lock:
            with self._gate_lock:
                self._frozen.add(key)
        return 200, {"frozen": True}, {}

    def _op_export(self, key: str
                   ) -> tuple[int, dict[str, Any], dict[str, str]]:
        with self._gate_lock:
            if key not in self._frozen:
                return 409, error_payload(
                    "not_frozen", f"study {key!r} must be frozen before "
                    "export"), {}
        lock = self.storage.study_lock(key)
        with lock:
            digest = self.storage.shard_digest(key)
            if isinstance(self.storage, DurableStorage):
                # seal the WAL so every acknowledged record of this shard
                # lives in an immutable file, then ship snapshot+segments
                # (the importer filter-replays just this study's records)
                self.storage.seal_active()
                files = self.storage.read_immutable_files()
                return 200, {"study_key": key, "digest": digest,
                             "snapshot": files["snapshot"],
                             "segments": files["segments"]}, {}
            return 200, {"study_key": key, "digest": digest,
                         "record": self.storage.shard_record(key)}, {}

    def _op_import(self, body: dict[str, Any]
                   ) -> tuple[int, dict[str, Any], dict[str, str]]:
        key = str(body.get("study_key", ""))
        want = body.get("digest")
        if self.storage.get_study(key) is not None:
            return 409, error_payload(
                "shard_exists", f"study {key!r} is already owned here"), {}
        shadow = InMemoryStorage()
        if body.get("record") is not None:
            shadow._restore_shard(body["record"])
        else:
            _filter_replay(shadow, key, body.get("snapshot"),
                           body.get("segments") or [])
        got = shadow.shard_digest(key)
        if got is None:
            return 404, error_payload(
                "study_not_found",
                f"study {key!r} not present in the shipped files"), {}
        if want is not None and got != want:
            return 409, error_payload(
                "digest_mismatch",
                f"migrated shard digest {got} != exporter digest "
                f"{want}"), {}
        self.storage.adopt_shard(shadow.shard_record(key))
        self.server.evict_context(key)
        with self._gate_lock:
            self._frozen.discard(key)
            self._moved.discard(key)
        return 200, {"adopted": True, "digest": got}, {}

    def _op_drop(self, key: str
                 ) -> tuple[int, dict[str, Any], dict[str, str]]:
        # mark moved *before* removing the shard: a request arriving in
        # between answers a retryable 503 instead of recreating the
        # study locally
        with self._gate_lock:
            self._moved.add(key)
            self._frozen.discard(key)
        dropped = self.storage.drop_shard(key)
        self.server.evict_context(key)
        return 200, {"dropped": dropped}, {}

    def _op_ring(self, body: dict[str, Any]
                 ) -> tuple[int, dict[str, Any], dict[str, str]]:
        if self.table is None:
            return 409, error_payload("no_table",
                                      "worker has no routing table"), {}
        endpoints = None
        if isinstance(body.get("endpoints"), dict):
            endpoints = {int(w): (ep[0], int(ep[1]))
                         for w, ep in body["endpoints"].items()}
        ring_ids = body.get("ring_ids")
        overrides = body.get("overrides") or None
        self.table.update(endpoints=endpoints,
                          ring_ids=ring_ids,
                          overrides=overrides,
                          clear_overrides=bool(body.get("clear_overrides")))
        return 200, {"table": self.table.snapshot()}, {}

    # -- replication control ops ---------------------------------------- #
    def _replication_status(self) -> dict[str, Any]:
        out: dict[str, Any] = {"worker": self.worker_id, "pid": os.getpid(),
                               "role": self.role, "epoch": self.epoch,
                               "fenced": self.fenced}
        if self.hub is not None:
            out["mode"] = self.replication_mode
            out["hub"] = self.hub.status()
        if self.repl_client is not None:
            out["client"] = self.repl_client.status()
        out["speculation"] = self.server.speculation_stats()
        return out

    def _op_promote(self, body: dict[str, Any]
                    ) -> tuple[int, dict[str, Any], dict[str, str]]:
        """Become the leader at ``epoch``: stop following, replay the
        dead leader's WAL directory read-only as the digest authority,
        reconcile to it through journaled drop/adopt, journal the new
        lease epoch, and open the data plane."""
        epoch = int(body.get("epoch", 0))
        if epoch <= self.epoch:
            return 409, error_payload(
                "stale_epoch",
                f"promotion epoch {epoch} is not newer than the current "
                f"lease epoch {self.epoch}"), {}
        if self.repl_client is not None:
            self.repl_client.stop()
        out: dict[str, Any] = {"promoted": True, "epoch": epoch,
                               "worker": self.worker_id}
        leader_root = body.get("leader_root")
        if leader_root:
            # the dead leader's disk is a superset of every acked write
            # (flush precedes publish; the page cache survives SIGKILL),
            # so it is the authority the promoted state must match
            authority, recovery = recover_dir_state(str(leader_root))
            out["recovery"] = recovery
            out["reconcile"] = reconcile_with(self.storage, authority)
            out["digest_match"] = out["reconcile"]["digest_match"]
        self.storage.note_lease(epoch)
        if self.hub is not None:
            # the leader write path now waits on *this* hub's followers
            self.storage.attach_replicator(
                self.hub, semisync=self.replication_mode == "semisync")
        # sampler/pruner contexts built from a partially-replayed view
        # must be rebuilt from the reconciled trials
        for study in list(self.storage.studies()):
            self.server.evict_context(study.key)
        self.role = "leader"
        self.fenced = False
        self.fence_epoch = None
        faults.set_context(role="leader")
        out["digest"] = self.storage.state_digest()
        return 200, out, {}

    def _op_fence(self, body: dict[str, Any]
                  ) -> tuple[int, dict[str, Any], dict[str, str]]:
        epoch = int(body.get("epoch", 0))
        if epoch <= self.epoch:
            return 409, error_payload(
                "stale_epoch",
                f"fence epoch {epoch} is not newer than the current "
                f"lease epoch {self.epoch}"), {}
        self.fence_epoch = epoch
        self.fenced = True
        return 200, {"fenced": True, "epoch": epoch}, {}


def _filter_replay(shadow: InMemoryStorage, key: str,
                   snapshot_text: str | None,
                   segment_texts: list[str]) -> None:
    """Rebuild one study's shard inside ``shadow`` from a shipped
    snapshot + sealed segments, replaying only the records that belong
    to ``key`` (both files interleave every study the exporter owns)."""
    if snapshot_text:
        snap = json.loads(snapshot_text)
        for srec in snap["state"]["studies"]:
            if srec["key"] == key:
                shadow._restore_shard(srec)
    for text in segment_texts:
        for line in text.splitlines():
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            if record_study_key(rec) == key:
                shadow._apply(rec)


# --------------------------------------------------------------------- #
# worker process entry point
# --------------------------------------------------------------------- #
def _serve_worker(args) -> int:
    faults.load_from_env()
    role = "follower" if args.follow else "leader"
    faults.set_context(worker=args.worker_id, role=role)
    if args.storage == "durable":
        storage: InMemoryStorage = DurableStorage(
            args.root, fsync=args.fsync, segment_bytes=args.segment_bytes)
    else:
        storage = InMemoryStorage()
    if role == "leader" and args.epoch > storage.lease_epoch:
        storage.note_lease(args.epoch)
    hub = None
    if args.repl_listen and args.storage == "durable":
        hub = ReplicationHub(storage)
        storage.attach_replicator(
            hub, semisync=(role == "leader"
                           and args.replication == "semisync"))
    secret = os.environ.get("REPRO_FABRIC_SECRET", "hopaas-secret")
    tokens = TokenManager(secret)
    server = HopaasServer(storage=storage, tokens=tokens,
                          lease_seconds=args.lease_seconds, seed=args.seed,
                          worker_name=f"fabric-{args.worker_id}")
    worker = FabricWorkerServer(server, worker_id=args.worker_id)
    worker.role = role
    worker.replication_mode = args.replication
    worker.hub = hub
    server.health_hook = worker.health_extra
    repl_client = None
    if args.follow:
        fhost, _, fport = args.follow.rpartition(":")
        follower_id = (os.path.basename(args.root) if args.root
                       else f"worker-{args.worker_id}-f{os.getpid()}")
        repl_client = ReplicationClient(storage, (fhost, int(fport)),
                                        follower_id=follower_id)
        worker.repl_client = repl_client
        repl_client.start()
    table = RouteTable({args.worker_id: (args.host, 0)},
                       self_id=args.worker_id)
    worker.table = table
    dispatcher = FabricDispatcher(table, local=worker,
                                  timeout=args.upstream_timeout)
    frontend = EventLoopFrontend(
        [worker], host=args.host, port=0, lanes=args.lanes,
        dispatcher=dispatcher,
        extra_port=args.reuseport_port if args.reuseport_port else None)
    frontend.start()
    stop_event = threading.Event()
    for sig in (signal.SIGTERM, signal.SIGINT):
        signal.signal(sig, lambda *_a: stop_event.set())
    ready = {"worker": args.worker_id, "port": frontend.port,
             "pid": os.getpid(), "digest": storage.state_digest(),
             "recovery": getattr(storage, "last_recovery", None),
             "role": role, "epoch": storage.lease_epoch,
             "repl_port": hub.port if hub is not None else None}
    sys.stdout.write(json.dumps(ready) + "\n")
    sys.stdout.flush()
    stop_event.wait()
    frontend.stop()
    dispatcher.close()
    if repl_client is not None:
        repl_client.stop()
    if hub is not None:
        hub.stop()
    storage.close()
    return 0


def main(argv: list[str] | None = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(prog="repro.core.fabric")
    ap.add_argument("--serve-worker", action="store_true")
    ap.add_argument("--worker-id", type=int, default=0)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--root", default=None)
    ap.add_argument("--storage", choices=("durable", "memory"),
                    default="durable")
    ap.add_argument("--fsync", choices=("always", "group", "off"),
                    default="off")
    ap.add_argument("--segment-bytes", type=int, default=4 * 1024 * 1024)
    ap.add_argument("--lease-seconds", type=float, default=60.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lanes", type=int, default=None)
    ap.add_argument("--upstream-timeout", type=float, default=10.0)
    ap.add_argument("--reuseport-port", type=int, default=0)
    ap.add_argument("--epoch", type=int, default=0,
                    help="initial leader lease epoch (journaled if newer "
                         "than the recovered one)")
    ap.add_argument("--follow", default=None, metavar="HOST:PORT",
                    help="run as a follower replicating from this "
                         "leader's replication hub")
    ap.add_argument("--replication", choices=("async", "semisync"),
                    default="async")
    ap.add_argument("--repl-listen", action="store_true",
                    help="serve a replication hub (durable storage only)")
    args = ap.parse_args(argv)
    if not args.serve_worker:
        ap.error("only --serve-worker mode is supported")
    if args.storage == "durable" and not args.root:
        ap.error("--root is required for durable storage")
    return _serve_worker(args)


# --------------------------------------------------------------------- #
# the fabric: spawn, route, rebalance, respawn
# --------------------------------------------------------------------- #
def _merge_speculation(entries: list[dict[str, Any]]) -> dict[str, Any]:
    """Sum per-worker speculative-ask counters into one fleet block.

    Each worker's ``/fabric/replication`` payload carries the
    ``speculation`` dict from ``HopaasServer.speculation_stats()``;
    workers that failed the control ping (or predate the field) simply
    don't contribute."""
    blocks = [e["speculation"] for e in entries
              if isinstance(e.get("speculation"), dict)]
    merged: dict[str, Any] = {
        "enabled": any(b.get("enabled") for b in blocks),
        "workers_reporting": len(blocks)}
    for key in ("hits", "stale_hits", "misses", "published", "rejected",
                "discarded", "queued", "pending_trials", "rounds",
                "errors"):
        merged[key] = sum(int(b.get(key, 0)) for b in blocks)
    return merged


class _WorkerProc:
    __slots__ = ("wid", "proc", "host", "port", "pid", "root", "digest",
                 "recovery", "role", "epoch", "repl_port", "replica_k")

    def __init__(self, wid: int, proc: subprocess.Popen, host: str,
                 port: int, pid: int, root: str | None,
                 digest: str | None, recovery: Any, *,
                 role: str = "leader", epoch: int = 0,
                 repl_port: int | None = None,
                 replica_k: int | None = None):
        self.wid = wid
        self.proc = proc
        self.host = host
        self.port = port
        self.pid = pid
        self.root = root
        self.digest = digest             # state digest reported at ready
        self.recovery = recovery         # DurableStorage.last_recovery
        self.role = role
        self.epoch = epoch               # lease epoch reported at ready
        self.repl_port = repl_port       # replication hub port, if any
        self.replica_k = replica_k       # follower slot (None = leader)


class ShardFabric:
    """N worker processes over consistent-hash study slices, fronted by
    a router (see module docstring).  ``workers=1`` runs fully inline —
    no children, no proxy hop — matching the PR 5 single-process path.
    """

    def __init__(self, workers: int = 2, *, host: str = "127.0.0.1",
                 port: int = 0, root: str | None = None,
                 storage: str = "durable", fsync: str = "off",
                 segment_bytes: int = 4 * 1024 * 1024,
                 lease_seconds: float = 60.0, seed: int = 0,
                 secret: str = "hopaas-secret", lanes: int | None = None,
                 upstream_timeout: float = 10.0, respawn: bool = True,
                 respawn_poll: float = 0.2, drain_seconds: float = 5.0,
                 reuseport: bool = False, api_workers: int = 2,
                 spawn_timeout: float = 30.0,
                 replicas: int | None = None,
                 replication: str | None = None,
                 hang_grace: float = 2.0):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        if storage not in ("durable", "memory"):
            raise ValueError(f"unknown fabric storage {storage!r}")
        if replicas is None:
            try:
                replicas = int(os.environ.get("REPRO_REPLICAS", "0") or 0)
            except ValueError:
                replicas = 0
        if replication is None:
            replication = os.environ.get("REPRO_REPLICATION",
                                         "async") or "async"
        if replication not in ("async", "semisync"):
            raise ValueError(f"unknown replication mode {replication!r}")
        if storage != "durable":
            replicas = 0                 # nothing durable to ship
        self.n_workers = int(workers)
        self.host = host
        self._port = int(port)
        self.storage_kind = storage
        self.fsync = fsync
        self.segment_bytes = int(segment_bytes)
        self.lease_seconds = float(lease_seconds)
        self.seed = int(seed)
        self.secret = secret
        self.lanes = lanes
        self.upstream_timeout = float(upstream_timeout)
        self.respawn = bool(respawn)
        self.respawn_poll = float(respawn_poll)
        self.drain_seconds = float(drain_seconds)
        self.reuseport = bool(reuseport)
        self.api_workers = max(1, int(api_workers))
        self.spawn_timeout = float(spawn_timeout)
        self.replicas = max(0, int(replicas))
        self.replication = replication
        self.hang_grace = float(hang_grace)
        self.inline = self.n_workers == 1 and self.replicas == 0
        self.tokens = TokenManager(secret)
        self._tmp: tempfile.TemporaryDirectory | None = None
        if root is None and storage == "durable":
            self._tmp = tempfile.TemporaryDirectory(prefix="hopaas-fabric-")
            root = self._tmp.name
        self.root = root
        # runtime state
        self._fleet_lock = threading.RLock()
        self._workers: dict[int, _WorkerProc] = {}
        self._next_wid = 0
        self._table: RouteTable | None = None
        self._dispatcher: FabricDispatcher | None = None
        self._frontend: EventLoopFrontend | None = None
        self._monitor: threading.Thread | None = None
        self._stop_event = threading.Event()
        self._started = False
        self._stopped = False
        self._control_token = self.tokens.issue("fabric-control")
        self.respawns = 0
        self.failovers = 0
        self.handoffs: list[dict[str, Any]] = []
        self.events: list[dict[str, Any]] = []
        # replication bookkeeping: leader wid -> live follower procs,
        # monotonically numbered replica roots, deposed leaders awaiting
        # a fence, and deposed procs to reap at stop()
        self._followers: dict[int, list[_WorkerProc]] = {}
        self._replica_seq: dict[int, int] = {}
        self._fence_pending: list[dict[str, Any]] = []
        self._deposed: list[_WorkerProc] = []
        # inline (workers=1) state
        self.storage: InMemoryStorage | None = None
        self.servers: list[HopaasServer] = []

    # -- lifecycle ------------------------------------------------------ #
    def start(self) -> "ShardFabric":
        if self._started:
            return self
        self._started = True
        if self.inline:
            self._start_inline()
            return self
        self._table = RouteTable({}, self_id=None)
        self._dispatcher = FabricDispatcher(self._table, local=None,
                                            timeout=self.upstream_timeout)
        self._frontend = EventLoopFrontend(
            [], host=self.host, port=self._port, lanes=self.lanes,
            dispatcher=self._dispatcher, drain_seconds=self.drain_seconds,
            reuseport=self.reuseport)
        with self._fleet_lock:
            for _ in range(self.n_workers):
                wid = self._next_wid
                self._next_wid += 1
                wp = self._spawn(wid)
                self._workers[wid] = self._cold_start_adopt(wid, wp)
            self._table.update(endpoints=self._endpoint_map())
        self._frontend.start()
        self._push_tables()
        if self.replicas:
            with self._fleet_lock:
                wids = sorted(self._workers)
            for wid in wids:
                self._followers[wid] = [self._spawn_follower(wid)
                                        for _ in range(self.replicas)]
        if self.respawn:
            self._monitor = threading.Thread(target=self._monitor_loop,
                                             daemon=True,
                                             name="fabric-monitor")
            self._monitor.start()
        return self

    def _start_inline(self) -> None:
        if self.storage_kind == "durable":
            self.storage = DurableStorage(
                os.path.join(self.root, "worker-0"), fsync=self.fsync,
                segment_bytes=self.segment_bytes)
        else:
            self.storage = InMemoryStorage()
        self.servers = [
            HopaasServer(storage=self.storage, tokens=self.tokens,
                         lease_seconds=self.lease_seconds, seed=self.seed,
                         worker_name=f"fabric-0-api-{i}")
            for i in range(self.api_workers)]
        self._frontend = EventLoopFrontend(
            self.servers, host=self.host, port=self._port, lanes=self.lanes,
            drain_seconds=self.drain_seconds)
        self._frontend.start()

    def stop(self) -> None:
        if self._stopped or not self._started:
            self._stopped = True
            return
        self._stopped = True
        self._stop_event.set()
        if self._monitor is not None:
            self._monitor.join(timeout=5.0)
        if self._frontend is not None:
            self._frontend.stop()
        if self._dispatcher is not None:
            self._dispatcher.close()
        with self._fleet_lock:
            procs = [wp.proc for wp in self._workers.values()]
            procs += [fp.proc for fols in self._followers.values()
                      for fp in fols]
            procs += [wp.proc for wp in self._deposed]
        for proc in procs:
            if proc.poll() is None:
                proc.terminate()
        deadline = time.monotonic() + 5.0
        for proc in procs:
            timeout = max(0.1, deadline - time.monotonic())
            try:
                proc.wait(timeout=timeout)
            except subprocess.TimeoutExpired:
                proc.kill()
                proc.wait(timeout=5.0)
        if self.storage is not None:
            self.storage.close()
        if self._tmp is not None:
            self._tmp.cleanup()

    # -- addresses ------------------------------------------------------ #
    @property
    def port(self) -> int:
        return self._frontend.port if self._frontend is not None else 0

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    @property
    def endpoints(self) -> list[tuple[str, int]]:
        """Data endpoints of every live worker (private ports), for
        endpoint-aware clients running without the router hop."""
        if self.inline:
            return [(self.host, self.port)]
        with self._fleet_lock:
            return [(wp.host, wp.port)
                    for _wid, wp in sorted(self._workers.items())]

    def issue_token(self, user: str = "fabric-user",
                    ttl_seconds: float = 24 * 3600.0) -> str:
        return self.tokens.issue(user, ttl_seconds=ttl_seconds)

    def owner_of(self, study_key: str) -> int:
        if self.inline:
            return 0
        return self._table.owner(study_key)

    def owner_endpoint(self, study_key: str) -> tuple[str, int]:
        if self.inline:
            return (self.host, self.port)
        wp = self._workers[self._table.owner(study_key)]
        return (wp.host, wp.port)

    def stats(self) -> dict[str, Any]:
        out: dict[str, Any] = {
            "workers": 1 if self.inline else len(self._workers),
            "inline": self.inline,
            "respawns": self.respawns,
            "failovers": self.failovers,
            "replicas": self.replicas,
            "replication": self.replication,
            "handoffs": len(self.handoffs),
        }
        if self._frontend is not None:
            out["frontend"] = self._frontend.stats()
        if self._dispatcher is not None:
            out["dispatcher"] = self._dispatcher.stats()
        return out

    # -- child processes ------------------------------------------------ #
    def _worker_root(self, wid: int) -> str | None:
        if self.storage_kind != "durable":
            return None
        return os.path.join(self.root, f"worker-{wid}")

    def _spawn(self, wid: int, *, epoch: int = 0,
               follow: tuple[str, int] | None = None,
               replica_k: int | None = None) -> _WorkerProc:
        # -c instead of -m: runpy warns when the module is also imported
        # through the package __init__ (it is, for the API exports)
        entry = ("import sys; from repro.core.fabric import main; "
                 "sys.exit(main(sys.argv[1:]))")
        cmd = [sys.executable, "-c", entry, "--serve-worker",
               "--worker-id", str(wid), "--host", self.host,
               "--storage", self.storage_kind, "--fsync", self.fsync,
               "--segment-bytes", str(self.segment_bytes),
               "--lease-seconds", str(self.lease_seconds),
               "--seed", str(self.seed + wid),
               "--upstream-timeout", str(self.upstream_timeout)]
        if replica_k is None:
            root = self._worker_root(wid)
        else:
            root = (os.path.join(self.root,
                                 f"worker-{wid}-replica-{replica_k}")
                    if self.storage_kind == "durable" else None)
        if root is not None:
            cmd += ["--root", root]
        if self.lanes is not None:
            cmd += ["--lanes", str(self.lanes)]
        if self.reuseport and replica_k is None \
                and self._frontend is not None:
            cmd += ["--reuseport-port", str(self._frontend.port)]
        if self.replicas and self.storage_kind == "durable":
            cmd += ["--repl-listen", "--replication", self.replication]
        if epoch:
            cmd += ["--epoch", str(epoch)]
        if follow is not None:
            cmd += ["--follow", f"{follow[0]}:{follow[1]}"]
        env = dict(os.environ)
        env["REPRO_FABRIC_SECRET"] = self.secret
        src_dir = os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
        env["PYTHONPATH"] = (src_dir + os.pathsep + env["PYTHONPATH"]
                             if env.get("PYTHONPATH") else src_dir)
        proc = subprocess.Popen(cmd, stdout=subprocess.PIPE, env=env)
        try:
            ready = self._read_ready(proc)
        except Exception:
            proc.kill()
            raise
        return _WorkerProc(wid, proc, self.host, int(ready["port"]),
                           int(ready["pid"]), root, ready.get("digest"),
                           ready.get("recovery"),
                           role=ready.get("role", "leader"),
                           epoch=int(ready.get("epoch") or 0),
                           repl_port=ready.get("repl_port"),
                           replica_k=replica_k)

    def _spawn_follower(self, wid: int) -> _WorkerProc:
        with self._fleet_lock:
            leader = self._workers[wid]
            k = self._replica_seq.get(wid, 0)
            self._replica_seq[wid] = k + 1
        if leader.repl_port is None:
            raise RuntimeError(
                f"worker {wid} serves no replication hub; cannot attach "
                "a follower")
        return self._spawn(wid, follow=(leader.host, leader.repl_port),
                           replica_k=k)

    def _replica_roots(self, wid: int) -> list[tuple[int, str]]:
        """``worker-{wid}-replica-{k}`` directories present on disk,
        sorted by replica index."""
        if self.storage_kind != "durable" or self.root is None:
            return []
        prefix = f"worker-{wid}-replica-"
        out: list[tuple[int, str]] = []
        try:
            names = os.listdir(self.root)
        except OSError:
            return []
        for name in names:
            if not name.startswith(prefix):
                continue
            suffix = name[len(prefix):]
            if suffix.isdigit() and os.path.isdir(
                    os.path.join(self.root, name)):
                out.append((int(suffix), os.path.join(self.root, name)))
        return sorted(out)

    def _cold_start_adopt(self, wid: int, wp: _WorkerProc) -> _WorkerProc:
        """Epoch-aware cold start: a full-fleet kill after an in-flight
        failover leaves the highest-epoch state in a
        ``worker-{wid}-replica-{k}`` directory while the restarted
        worker boots from ``worker-{wid}`` at the old epoch — acked
        post-failover writes would sit recoverable on disk but unserved.
        Scan every candidate root, replay each read-only
        (``recover_dir_state`` is the authority, exactly as in runtime
        promotion), and if any replica journaled a newer lease epoch,
        promote the fresh worker onto that state before the fleet takes
        traffic.  Also seeds ``_replica_seq`` past any surviving replica
        directories so new followers never collide with old roots."""
        replicas = self._replica_roots(wid)
        if not replicas:
            return wp
        with self._fleet_lock:
            self._replica_seq[wid] = max(self._replica_seq.get(wid, 0),
                                         replicas[-1][0] + 1)
        best_root: str | None = None
        best = (wp.epoch, -1)            # (lease epoch, records replayed)
        for _k, root in replicas:
            try:
                store, meta = recover_dir_state(root)
            except Exception:
                logger.warning("cold start: replica root %s unreadable, "
                               "skipping", root, exc_info=True)
                continue
            cand = (int(getattr(store, "lease_epoch", 0) or 0),
                    int(meta.get("records_replayed") or 0))
            if cand[0] > wp.epoch and cand > best:
                best, best_root = cand, root
        if best_root is None:
            return wp
        # strictly newer term than any root on disk, mirroring _failover:
        # the adopting worker's own WAL journals the reconcile + lease,
        # so the next cold start picks worker-{wid} again
        new_epoch = best[0] + 1
        promoted = self._control_checked(wp, "/fabric/promote", {
            "epoch": new_epoch, "leader_root": best_root})
        wp.epoch = new_epoch
        wp.digest = promoted.get("digest")
        wp.recovery = promoted.get("recovery")
        self.events.append({
            "event": "cold_start_adopt", "worker": wid,
            "adopted_root": best_root, "epoch": new_epoch,
            "digest_match": bool(promoted.get("digest_match", True)),
            "reconcile": promoted.get("reconcile")})
        return wp

    def _read_ready(self, proc: subprocess.Popen) -> dict[str, Any]:
        deadline = time.monotonic() + self.spawn_timeout
        fd = proc.stdout.fileno()
        buf = b""
        while b"\n" not in buf:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TimeoutError("fabric worker did not become ready")
            if proc.poll() is not None:
                raise RuntimeError(
                    f"fabric worker exited with {proc.returncode} before "
                    "becoming ready")
            ready, _, _ = select.select([fd], [], [], min(remaining, 0.25))
            if not ready:
                continue
            chunk = os.read(fd, 4096)
            if not chunk:
                raise RuntimeError("fabric worker closed stdout before "
                                   "becoming ready")
            buf += chunk
        return json.loads(buf.split(b"\n", 1)[0])

    def _endpoint_map(self) -> dict[int, tuple[str, int]]:
        return {wid: (wp.host, wp.port) for wid, wp in self._workers.items()}

    # -- control-plane client ------------------------------------------- #
    def _control(self, wp: _WorkerProc, path: str,
                 body: dict[str, Any] | None = None, *,
                 timeout: float | None = None
                 ) -> tuple[int, dict[str, Any]]:
        conn = http.client.HTTPConnection(wp.host, wp.port,
                                          timeout=timeout or 10.0)
        try:
            data = json.dumps(body or {}).encode()
            conn.request("POST", path, data, {
                "Authorization": f"Bearer {self._control_token}",
                "Content-Type": "application/json"})
            resp = conn.getresponse()
            blob = resp.read()
            payload = json.loads(blob) if blob else {}
            return resp.status, payload
        finally:
            conn.close()

    def _control_checked(self, wp: _WorkerProc, path: str,
                         body: dict[str, Any] | None = None
                         ) -> dict[str, Any]:
        status, payload = self._control(wp, path, body)
        if status != 200:
            raise RuntimeError(
                f"fabric control {path} on worker {wp.wid} failed: "
                f"{status} {payload}")
        return payload

    def _push_tables(self, **update: Any) -> None:
        """Push the parent's routing view (plus ``update`` deltas) to
        every worker, then apply it to the router's own table last —
        workers learn a cutover before the router starts using it."""
        with self._fleet_lock:
            body = {"endpoints": {str(w): [h, p] for w, (h, p)
                                  in self._endpoint_map().items()},
                    "ring_ids": self._table.worker_ids(), **update}
            workers = list(self._workers.values())
        for wp in workers:
            try:
                self._control(wp, "/fabric/ring", body, timeout=5.0)
            except Exception:
                logger.warning("ring push to worker %d failed", wp.wid,
                               exc_info=True)
        self._table.update(
            endpoints=self._endpoint_map(),
            ring_ids=body.get("ring_ids"),
            overrides=body.get("overrides"),
            clear_overrides=bool(body.get("clear_overrides")))

    # -- membership / rebalance ----------------------------------------- #
    def locations(self) -> dict[int, list[str]]:
        """Actual shard placement: worker id -> study keys it owns."""
        if self.inline:
            return {0: sorted(s.key for s in self.storage.studies())}
        out: dict[int, list[str]] = {}
        with self._fleet_lock:
            workers = list(self._workers.values())
        for wp in workers:
            out[wp.wid] = self._control_checked(
                wp, "/fabric/studies")["keys"]
        return out

    def worker_digest(self, wid: int) -> str:
        with self._fleet_lock:
            wp = self._workers[wid]
        digest = self._control_checked(wp, "/fabric/digest")["digest"]
        wp.digest = digest
        return digest

    def migrate(self, study_key: str, src_wid: int, dst_wid: int
                ) -> dict[str, Any]:
        """Hand one shard from ``src`` to ``dst``: freeze -> seal+export
        -> filter-replay import -> digest verify -> override cutover ->
        drop.  Zero lost writes: requests hitting the frozen shard get
        a retryable 503 until the override lands."""
        with self._fleet_lock:
            src = self._workers[src_wid]
            dst = self._workers[dst_wid]
        self._control_checked(src, "/fabric/freeze",
                              {"study_key": study_key})
        try:
            export = self._control_checked(src, "/fabric/export",
                                           {"study_key": study_key})
            imported = self._control_checked(dst, "/fabric/import", {
                "study_key": study_key, "digest": export["digest"],
                "snapshot": export.get("snapshot"),
                "segments": export.get("segments"),
                "record": export.get("record")})
            if imported["digest"] != export["digest"]:
                raise RuntimeError("digest mismatch after import")
        except Exception:
            with contextlib.suppress(Exception):
                self._control(src, "/fabric/unfreeze",
                              {"study_key": study_key}, timeout=5.0)
            raise
        # cutover: flip this one key everywhere, then drop the source
        self._push_tables(overrides={study_key: dst_wid})
        self._control_checked(src, "/fabric/drop", {"study_key": study_key})
        record = {"study_key": study_key, "src": src_wid, "dst": dst_wid,
                  "src_digest": export["digest"],
                  "dst_digest": imported["digest"],
                  "verified": imported["digest"] == export["digest"]}
        self.handoffs.append(record)
        self.events.append({"event": "handoff", **record})
        return record

    def add_worker(self) -> int:
        """Grow the fleet by one worker and rebalance: consistent
        hashing moves only the keys the new worker takes over."""
        if self.inline:
            raise RuntimeError("inline fabric (workers=1) cannot grow; "
                               "start with workers>=2")
        with self._fleet_lock:
            old_ids = self._table.worker_ids()
            wid = self._next_wid
            self._next_wid += 1
            self._workers[wid] = self._spawn(wid)
            # workers can *reach* the newcomer before any key routes to
            # it: endpoints grow now, the ring flips only after the moves
            self._push_tables(ring_ids=old_ids)
            new_ring = HashRing(old_ids + [wid],
                                replicas=self._table.replicas)
            moves = []
            for src_wid, keys in self.locations().items():
                if src_wid == wid:
                    continue
                for key in keys:
                    dst = new_ring.owner(key)
                    if dst != src_wid:
                        moves.append((key, src_wid, dst))
            for key, src_wid, dst in moves:
                self.migrate(key, src_wid, dst)
            self._push_tables(ring_ids=old_ids + [wid],
                              clear_overrides=True)
            if self.replicas:
                self._followers[wid] = [self._spawn_follower(wid)
                                        for _ in range(self.replicas)]
            self.n_workers = len(self._workers)
            return wid

    def remove_worker(self, wid: int) -> None:
        """Shrink the fleet: migrate every shard off ``wid``, flip the
        ring, then terminate the worker."""
        with self._fleet_lock:
            ids = self._table.worker_ids()
            if wid not in ids or len(ids) < 2:
                raise ValueError(f"cannot remove worker {wid}")
            remaining = [w for w in ids if w != wid]
            new_ring = HashRing(remaining, replicas=self._table.replicas)
            for key in self.locations().get(wid, []):
                self.migrate(key, wid, new_ring.owner(key))
            wp = self._workers.pop(wid)
            doomed = [wp] + self._followers.pop(wid, [])
            self._push_tables(ring_ids=remaining, clear_overrides=True)
            for dp in doomed:
                dp.proc.terminate()
            for dp in doomed:
                try:
                    dp.proc.wait(timeout=5.0)
                except subprocess.TimeoutExpired:
                    dp.proc.kill()
                    dp.proc.wait(timeout=5.0)
            self.n_workers = len(self._workers)

    def kill_worker(self, wid: int, sig: int = signal.SIGKILL) -> None:
        """Send ``sig`` to a worker process (crash injection for tests)."""
        with self._fleet_lock:
            os.kill(self._workers[wid].pid, sig)

    def wait_respawn(self, wid: int, old_pid: int,
                     timeout: float = 30.0) -> _WorkerProc:
        """Block until the monitor respawned worker ``wid``."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._fleet_lock:
                wp = self._workers[wid]
            if wp.pid != old_pid and wp.proc.poll() is None:
                return wp
            time.sleep(0.05)
        raise TimeoutError(f"worker {wid} was not respawned")

    # -- fleet health ---------------------------------------------------- #
    def health(self) -> dict[str, Any]:
        """Fleet-wide health: per-worker role, lease epoch, and
        replication lag gathered over the control plane (leaders *and*
        their followers), plus the fabric's failover counters."""
        if self.inline:
            h = self.servers[0].op_health()
            h["workers"] = [{"worker": 0, "role": "leader",
                             "epoch": h.get("epoch", 0)}]
            return h
        with self._fleet_lock:
            leaders = sorted(self._workers.items())
            followers = {wid: list(fols)
                         for wid, fols in self._followers.items()}
        entries: list[dict[str, Any]] = []
        for wid, wp in leaders:
            for peer in [wp] + followers.get(wid, []):
                entry: dict[str, Any] = {
                    "worker": wid, "pid": peer.pid,
                    "endpoint": [peer.host, peer.port]}
                try:
                    status, payload = self._control(
                        peer, "/fabric/replication", {}, timeout=2.0)
                    if status == 200:
                        entry.update(payload)
                    else:
                        entry["error"] = f"control status {status}"
                except Exception as e:
                    entry["error"] = f"{type(e).__name__}: {e}"
                entries.append(entry)
        return {"status": "ok", "workers": entries,
                "speculation": _merge_speculation(entries),
                "replicas": self.replicas, "replication": self.replication,
                "respawns": self.respawns, "failovers": self.failovers}

    # -- crash respawn / failover ----------------------------------------- #
    def _monitor_loop(self) -> None:
        ping_fail: dict[int, int] = {}
        hang_ticks = max(1, int(round(self.hang_grace
                                      / max(self.respawn_poll, 1e-3))))
        while not self._stop_event.wait(self.respawn_poll):
            self._deliver_fences()
            self._reap_followers()
            with self._fleet_lock:
                leaders = list(self._workers.items())
            dead = [(wid, wp) for wid, wp in leaders
                    if wp.proc.poll() is not None]
            hung: list[tuple[int, _WorkerProc]] = []
            if self.replicas:
                # a leader that stops answering control pings while its
                # process lives (wedged, SIGSTOPped) is as gone as a dead
                # one — but only failover can help, so only probe leaders
                # that have followers to promote
                for wid, wp in leaders:
                    if wp.proc.poll() is not None:
                        ping_fail.pop(wid, None)
                        continue
                    with self._fleet_lock:
                        has_followers = bool(self._followers.get(wid))
                    if not has_followers:
                        continue
                    try:
                        status, _ = self._control(wp, "/fabric/ping", {},
                                                  timeout=0.5)
                        ok = status == 200
                    except Exception:
                        ok = False
                    if ok:
                        ping_fail[wid] = 0
                    else:
                        ping_fail[wid] = ping_fail.get(wid, 0) + 1
                        if ping_fail[wid] >= hang_ticks:
                            hung.append((wid, wp))
            if not dead and not hung:
                continue
            respawned: list[int] = []
            for wid, old in dead:
                if self._stop_event.is_set():
                    return
                if self.replicas and self._failover(wid, old,
                                                    reason="dead"):
                    ping_fail[wid] = 0
                    continue
                try:
                    # same WAL directory: recovery rebuilds the exact
                    # pre-crash state (the ready line reports the
                    # recovered digest + replay stats)
                    wp = self._spawn(wid)
                except Exception:
                    logger.exception("respawn of worker %d failed", wid)
                    continue
                with self._fleet_lock:
                    self._workers[wid] = wp
                self.respawns += 1
                self.events.append({
                    "event": "respawn", "worker": wid,
                    "old_pid": old.pid, "pid": wp.pid,
                    "recovered_digest": wp.digest,
                    "recovery": wp.recovery,
                    "digest_match": (old.digest is not None
                                     and wp.digest == old.digest)})
                respawned.append(wid)
            for wid, old in hung:
                if self._stop_event.is_set():
                    return
                with self._fleet_lock:
                    current = self._workers.get(wid)
                if current is not old or old.proc.poll() is not None:
                    continue             # already handled above
                if self._failover(wid, old, reason="hung"):
                    ping_fail[wid] = 0
            if not respawned:
                continue
            self._push_tables()
            for wid in respawned:
                with self._fleet_lock:
                    wp = self._workers[wid]
                with contextlib.suppress(Exception):
                    # requeue trials leased through the dead worker
                    # whose leases already lapsed; later expiries are
                    # caught by the normal per-ask sweep
                    self._control(wp, "/fabric/sweep", {}, timeout=5.0)
                if self.replicas:
                    # the old followers stream from a hub that died with
                    # the old process; give the respawn a fresh set
                    self._replace_followers(wid)

    def _failover(self, wid: int, old: _WorkerProc, *,
                  reason: str) -> bool:
        """Promote the most-caught-up follower of ``wid`` to leader.
        Returns False when no follower can take over (the caller falls
        back to a WAL respawn)."""
        with self._fleet_lock:
            candidates = [fp for fp in self._followers.get(wid, ())
                          if fp.proc.poll() is None]
        best: _WorkerProc | None = None
        best_pos = -1
        for fp in candidates:
            try:
                st = self._control_checked(fp, "/fabric/replication")
            except Exception as exc:
                # an unreachable follower just loses the election — but
                # say so, or a fleet that silently elects a stale one
                # looks identical to a healthy failover
                logger.warning("promote(%s): follower worker %s "
                               "unreachable, skipping: %s",
                               reason, fp.wid, exc)
                continue
            pos = int((st.get("client") or {}).get("pos") or 0)
            if pos > best_pos:
                best, best_pos = fp, pos
        if best is None:
            return False
        new_epoch = max(old.epoch, best.epoch) + 1
        try:
            promoted = self._control_checked(best, "/fabric/promote", {
                "epoch": new_epoch, "leader_root": old.root})
        except Exception:
            logger.exception("promotion of a worker-%d follower failed",
                             wid)
            return False
        best.role = "leader"
        best.epoch = new_epoch
        best.digest = promoted.get("digest")
        best.recovery = promoted.get("recovery")
        with self._fleet_lock:
            fols = self._followers.get(wid)
            if fols and best in fols:
                fols.remove(best)
            # the promoted follower keeps the dead leader's ring id —
            # HashRing placement is a pure function of the id set, so
            # no shard moves; only the endpoint behind the id changes
            self._workers[wid] = best
            self._deposed.append(old)
            self.failovers += 1
        self.events.append({
            "event": "failover", "worker": wid, "reason": reason,
            "old_pid": old.pid, "pid": best.pid, "epoch": new_epoch,
            "digest_match": bool(promoted.get("digest_match", True)),
            "recovery": promoted.get("recovery"),
            "reconcile": promoted.get("reconcile")})
        # workers learn the cutover before the router flips to it
        self._push_tables()
        with contextlib.suppress(Exception):
            self._control(best, "/fabric/sweep", {}, timeout=5.0)
        self._replace_followers(wid)
        if old.proc.poll() is None:
            # STONITH-free fencing: keep delivering the new epoch until
            # the deposed process takes it (or finally dies), so a
            # SIGSTOPped ex-leader resuming cannot ack stale writes
            with self._fleet_lock:
                self._fence_pending.append(
                    {"wid": wid, "wp": old, "epoch": new_epoch})
        return True

    def _replace_followers(self, wid: int) -> None:
        """Tear down ``wid``'s remaining followers (their upstream hub
        is gone) and spawn a full fresh set against the current leader."""
        with self._fleet_lock:
            stale = self._followers.pop(wid, [])
        for fp in stale:
            with contextlib.suppress(Exception):
                fp.proc.terminate()
        fresh: list[_WorkerProc] = []
        for _ in range(self.replicas):
            try:
                fresh.append(self._spawn_follower(wid))
            except Exception:
                logger.exception("follower spawn for worker %d failed",
                                 wid)
        with self._fleet_lock:
            self._followers[wid] = fresh
        for fp in stale:
            with contextlib.suppress(Exception):
                fp.proc.wait(timeout=5.0)

    def _reap_followers(self) -> None:
        """Respawn spontaneously-dead followers so the replica count
        holds (leader transitions rebuild their sets wholesale)."""
        if not self.replicas:
            return
        with self._fleet_lock:
            dead = [(wid, fp) for wid, fols in self._followers.items()
                    for fp in list(fols) if fp.proc.poll() is not None]
        for wid, fp in dead:
            with self._fleet_lock:
                fols = self._followers.get(wid)
                if fols and fp in fols:
                    fols.remove(fp)
                leader = self._workers.get(wid)
            if leader is None or leader.proc.poll() is not None:
                continue                 # leader is down: failover first
            try:
                nfp = self._spawn_follower(wid)
            except Exception:
                logger.exception("follower respawn for worker %d failed",
                                 wid)
                continue
            with self._fleet_lock:
                self._followers.setdefault(wid, []).append(nfp)
            self.events.append({"event": "follower_respawn", "worker": wid,
                                "old_pid": fp.pid, "pid": nfp.pid})

    def _deliver_fences(self) -> None:
        with self._fleet_lock:
            pending = list(self._fence_pending)
        for item in pending:
            wp: _WorkerProc = item["wp"]
            done = wp.proc.poll() is not None
            if not done:
                try:
                    status, _ = self._control(
                        wp, "/fabric/fence", {"epoch": item["epoch"]},
                        timeout=0.5)
                    done = status == 200
                except Exception:
                    done = False
                if done:
                    self.events.append({"event": "fence",
                                        "worker": item["wid"],
                                        "pid": wp.pid,
                                        "epoch": item["epoch"]})
            if done:
                with self._fleet_lock:
                    with contextlib.suppress(ValueError):
                        self._fence_pending.remove(item)


if __name__ == "__main__":
    sys.exit(main())
