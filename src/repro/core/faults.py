"""Deterministic fault injection for durability / replication tests.

Crash-recovery code is only as trustworthy as the crashes it has been
tested against.  This module gives the test-suite named *injection
points* compiled into the production paths (``durable._ensure_durable``,
``replication`` shipping, lease stamping) that are inert unless armed:

* **In-process**: ``install({"name": {...}})`` arms faults for the
  current process — unit tests exercising torn ships or skewed clocks.
* **Cross-process**: the fabric spawns workers as subprocesses, so chaos
  tests arm faults through the ``REPRO_FAULTS`` environment variable (a
  JSON spec, read once at worker startup).  ``set_context`` lets a spec
  target one worker / role ("kill the *leader* of worker 1 before its
  3rd fsync") while every other process ignores it.

Every injector is seeded: given the same spec and the same sequence of
``fire`` calls, the same faults trigger at the same points — chaos runs
are replayable.

Spec format (one entry per fault name)::

    {
      "crash_before_fsync": {"mode": "nth", "n": 3, "worker": 0,
                             "role": "leader"},
      "torn_ship":          {"mode": "once", "arg": "torn"},
      "lease_skew":         {"mode": "always", "arg": -30.0},
    }

``mode`` is ``always`` | ``once`` | ``nth`` (fire only on the n-th
arrival, 1-based).  ``worker`` / ``role`` restrict the fault to a
matching ``set_context``.  ``arg`` carries a per-fault payload (mangle
style, skew seconds).
"""
from __future__ import annotations

import json
import os
import random
import threading
from typing import Any

ENV_VAR = "REPRO_FAULTS"


class FaultInjector:
    """Named, seeded, context-filtered fault points (see module doc)."""

    def __init__(self, spec: dict[str, dict[str, Any]] | None = None,
                 *, seed: int = 0):
        self._spec = dict(spec or {})
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        self._arrivals: dict[str, int] = {}
        self.fired: dict[str, int] = {}
        self._context: dict[str, Any] = {}

    # -- arming / context ------------------------------------------------
    def set_context(self, **ctx: Any) -> None:
        """Describe the current process (worker id, role, ...) so specs
        carrying matching filter keys only fire here."""
        with self._lock:
            self._context.update(ctx)

    def _matches(self, entry: dict[str, Any]) -> bool:
        for key in ("worker", "role"):
            if key in entry and self._context.get(key) != entry[key]:
                return False
        return True

    # -- the core decision ----------------------------------------------
    def fire(self, name: str) -> bool:
        """True if the named fault should trigger at this arrival.
        Counts every arrival (matching or not armed alike) so ``nth``
        specs are deterministic regardless of when the spec was armed."""
        with self._lock:
            self._arrivals[name] = self._arrivals.get(name, 0) + 1
            entry = self._spec.get(name)
            if entry is None or not self._matches(entry):
                return False
            mode = entry.get("mode", "always")
            hit = False
            if mode == "always":
                hit = True
            elif mode == "once":
                hit = self.fired.get(name, 0) == 0
            elif mode == "nth":
                hit = self._arrivals[name] == int(entry.get("n", 1))
            if hit:
                self.fired[name] = self.fired.get(name, 0) + 1
            return hit

    def arg(self, name: str, default: Any = None) -> Any:
        with self._lock:
            entry = self._spec.get(name) or {}
            return entry.get("arg", default)

    # -- fault flavours ---------------------------------------------------
    def crash(self, name: str) -> None:
        """Die NOW, skipping every atexit/finally handler — the closest a
        test can get to power loss without actually pulling the plug."""
        if self.fire(name):
            os._exit(137)

    def mangle(self, name: str, data: bytes) -> bytes:
        """Corrupt ``data`` in flight: ``arg`` picks the style —
        ``"torn"`` truncates at a seeded offset (a partial send),
        ``"bitflip"`` flips one seeded bit (wire corruption)."""
        if not self.fire(name) or not data:
            return data
        style = self.arg(name, "torn")
        with self._lock:
            if style == "bitflip":
                i = self._rng.randrange(len(data))
                return data[:i] + bytes([data[i] ^ 0x40]) + data[i + 1:]
            # torn: keep a strict prefix (at least 1 byte short)
            cut = self._rng.randrange(max(1, len(data) - 1))
            return data[:cut]

    def skew(self, name: str) -> float:
        """Clock-skew seconds to add at a lease-stamping point (0.0 when
        the fault is not armed/firing)."""
        if self.fire(name):
            return float(self.arg(name, 0.0))
        return 0.0

    def stats(self) -> dict[str, Any]:
        with self._lock:
            return {"armed": sorted(self._spec),
                    "fired": dict(self.fired),
                    "arrivals": dict(self._arrivals)}


# ---------------------------------------------------------------------- #
# process-wide injector (inert by default)
# ---------------------------------------------------------------------- #
_injector = FaultInjector()


def injector() -> FaultInjector:
    return _injector


def install(spec: dict[str, dict[str, Any]] | None, *,
            seed: int = 0, **context: Any) -> FaultInjector:
    """Arm the process-wide injector (tests).  ``install(None)`` disarms."""
    global _injector
    _injector = FaultInjector(spec, seed=seed)
    if context:
        _injector.set_context(**context)
    return _injector


def set_context(**ctx: Any) -> None:
    _injector.set_context(**ctx)


def load_from_env(environ: dict[str, str] | None = None) -> FaultInjector:
    """Arm from ``REPRO_FAULTS`` (JSON: ``{"seed": 0, "faults": {...}}``
    or just the fault dict).  Called once per worker process at startup;
    a missing/empty variable leaves the injector inert."""
    raw = (environ if environ is not None else os.environ).get(ENV_VAR, "")
    if not raw.strip():
        return _injector
    spec = json.loads(raw)
    if "faults" in spec:
        return install(spec["faults"], seed=int(spec.get("seed", 0)))
    return install(spec)


# convenience passthroughs used by the injection points
def fire(name: str) -> bool:
    return _injector.fire(name)


def crash(name: str) -> None:
    _injector.crash(name)


def mangle(name: str, data: bytes) -> bytes:
    return _injector.mangle(name, data)


def skew(name: str) -> float:
    return _injector.skew(name)
