from __future__ import annotations

import abc
from typing import Any

import numpy as np

from ..obs_cache import liar_value
from ..space import SearchSpace
from ..types import Direction, Trial, TrialState


class Sampler(abc.ABC):
    """Strategy that proposes the next hyperparameter set for a study."""

    #: numeric samplers set this so the server hands them the per-study
    #: ObservationCache (``cache=`` kwarg) instead of letting them rescan
    #: the trial list on every ask
    uses_cache = False

    #: pending-aware samplers understand the constant-liar view (RUNNING
    #: trials as fantasy observations) and can batch with incremental
    #: liar updates — the prerequisites for speculative precompute
    pending_aware = False

    @abc.abstractmethod
    def suggest(self, space: SearchSpace, trials: list[Trial],
                direction: Direction, rng: np.random.Generator) -> dict[str, Any]:
        ...

    def suggest_batch(self, space: SearchSpace, trials: list[Trial],
                      direction: Direction, rng: np.random.Generator,
                      n: int, **kwargs: Any) -> list[dict[str, Any]]:
        """Propose ``n`` parameter sets at once (the `ask_batch` path).

        The default extends the trial history with RUNNING placeholders
        between draws so index-based samplers (grid, Halton) advance and
        don't hand the same point to every worker in the batch.  Samplers
        with a vectorized proposal path (e.g. TPE top-k) override this.
        """
        virtual = list(trials)
        out: list[dict[str, Any]] = []
        for _ in range(n):
            params = self.suggest(space, virtual, direction, rng, **kwargs)
            out.append(params)
            virtual.append(Trial(trial_id=len(virtual), uid="", study_key="",
                                 params=params, state=TrialState.RUNNING))
        return out

    # -- helpers shared by the numeric samplers -------------------------
    @staticmethod
    def observations(space: SearchSpace, trials: list[Trial], direction: Direction,
                     cache: Any = None) -> tuple[np.ndarray, np.ndarray]:
        """(X, y) of observations in unit-cube coords, minimization sign.

        With an ``ObservationCache`` (the service ask path) this is O(1):
        the cache was synced incrementally on tell.  Without one (direct
        sampler use, tests) the trial list is featurized from scratch with
        the vectorized space codec — same rows, bit-identical.
        """
        if cache is not None:
            return cache.observations()
        done = [t for t in trials
                if t.state == TrialState.COMPLETED and t.value is not None]
        if not done:
            return np.zeros((0, space.dim)), np.zeros((0,))
        X = space.to_unit_matrix([t.params for t in done])
        sign = 1.0 if direction == Direction.MINIMIZE else -1.0
        y = np.array([sign * t.value for t in done], dtype=np.float64)
        return X, y

    @classmethod
    def observations_pending(cls, space: SearchSpace, trials: list[Trial],
                             direction: Direction, cache: Any = None,
                             liar: str = "mean"
                             ) -> tuple[np.ndarray, np.ndarray, int]:
        """(X, y, n_obs): the constant-liar view of the history.

        The first ``n_obs`` rows are real observations (trial-id order);
        the rest are RUNNING trials with an imputed objective so the
        acquisition repels in-flight points.  With a liar-enabled
        ``ObservationCache`` this is the incrementally maintained
        ``augmented()`` view; without one the trial list is scanned —
        same sorted construction, bit-identical rows.  Startup gating
        must use ``n_obs``, never ``len(y)``: fantasy rows are not
        evidence.
        """
        if cache is not None and liar != "none":
            X, y = cache.augmented()
            return X, y, cache.count
        X, y = cls.observations(space, trials, direction, cache=cache)
        n_obs = len(y)
        if liar != "none" and n_obs:
            pend = [t for t in trials if t.state == TrialState.RUNNING]
            if pend:
                lv = liar_value(y, liar)
                Xp = space.to_unit_matrix([t.params for t in pend])
                X = np.concatenate([X, Xp])
                y = np.concatenate([y, np.full(len(pend), lv)])
        return X, y, n_obs

    def speculative_ready(self, cache: Any) -> bool:
        """Whether a precomputed proposal batch against ``cache`` would
        be purely model-driven.  False while an index-based startup
        fallback (which needs the live trial count) would kick in — the
        precompute worker must not publish from that regime."""
        return False
