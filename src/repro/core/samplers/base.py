from __future__ import annotations

import abc
from typing import Any

import numpy as np

from ..space import SearchSpace
from ..types import Direction, Trial, TrialState


class Sampler(abc.ABC):
    """Strategy that proposes the next hyperparameter set for a study."""

    #: numeric samplers set this so the server hands them the per-study
    #: ObservationCache (``cache=`` kwarg) instead of letting them rescan
    #: the trial list on every ask
    uses_cache = False

    @abc.abstractmethod
    def suggest(self, space: SearchSpace, trials: list[Trial],
                direction: Direction, rng: np.random.Generator) -> dict[str, Any]:
        ...

    def suggest_batch(self, space: SearchSpace, trials: list[Trial],
                      direction: Direction, rng: np.random.Generator,
                      n: int, **kwargs: Any) -> list[dict[str, Any]]:
        """Propose ``n`` parameter sets at once (the `ask_batch` path).

        The default extends the trial history with RUNNING placeholders
        between draws so index-based samplers (grid, Halton) advance and
        don't hand the same point to every worker in the batch.  Samplers
        with a vectorized proposal path (e.g. TPE top-k) override this.
        """
        virtual = list(trials)
        out: list[dict[str, Any]] = []
        for _ in range(n):
            params = self.suggest(space, virtual, direction, rng, **kwargs)
            out.append(params)
            virtual.append(Trial(trial_id=len(virtual), uid="", study_key="",
                                 params=params, state=TrialState.RUNNING))
        return out

    # -- helpers shared by the numeric samplers -------------------------
    @staticmethod
    def observations(space: SearchSpace, trials: list[Trial], direction: Direction,
                     cache: Any = None) -> tuple[np.ndarray, np.ndarray]:
        """(X, y) of observations in unit-cube coords, minimization sign.

        With an ``ObservationCache`` (the service ask path) this is O(1):
        the cache was synced incrementally on tell.  Without one (direct
        sampler use, tests) the trial list is featurized from scratch with
        the vectorized space codec — same rows, bit-identical.
        """
        if cache is not None:
            return cache.observations()
        done = [t for t in trials
                if t.state == TrialState.COMPLETED and t.value is not None]
        if not done:
            return np.zeros((0, space.dim)), np.zeros((0,))
        X = space.to_unit_matrix([t.params for t in done])
        sign = 1.0 if direction == Direction.MINIMIZE else -1.0
        y = np.array([sign * t.value for t in done], dtype=np.float64)
        return X, y
