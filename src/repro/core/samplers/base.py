from __future__ import annotations

import abc
from typing import Any

import numpy as np

from ..space import SearchSpace
from ..types import Direction, Trial, TrialState


class Sampler(abc.ABC):
    """Strategy that proposes the next hyperparameter set for a study."""

    @abc.abstractmethod
    def suggest(self, space: SearchSpace, trials: list[Trial],
                direction: Direction, rng: np.random.Generator) -> dict[str, Any]:
        ...

    # -- helpers shared by the numeric samplers -------------------------
    @staticmethod
    def observations(space: SearchSpace, trials: list[Trial], direction: Direction
                     ) -> tuple[np.ndarray, np.ndarray]:
        """(X, y) of completed trials in unit-cube coords, minimization sign."""
        done = [t for t in trials if t.state == TrialState.COMPLETED and t.value is not None]
        if not done:
            return np.zeros((0, space.dim)), np.zeros((0,))
        X = np.stack([space.to_unit_vector(t.params) for t in done])
        sign = 1.0 if direction == Direction.MINIMIZE else -1.0
        y = np.array([sign * t.value for t in done], dtype=np.float64)
        return X, y
