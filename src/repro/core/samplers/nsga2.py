"""NSGA-II sampler for multi-objective studies (the paper's sec. 5
future work: "introduce support to multi-objective optimizations").

Deb et al. 2002, adapted to the ask/tell service model: each `suggest`
call performs binary-tournament selection over the completed trials
(rank by non-dominated front, tie-break by crowding distance), then SBX
crossover + polynomial mutation in the unit hypercube.  Matches the
spirit of Optuna's NSGAIISampler default configuration.
"""
from __future__ import annotations

from typing import Any

import numpy as np

from ..space import SearchSpace
from ..types import Direction, Trial, TrialState
from .base import Sampler


def _objective_matrix(trials: list[Trial], signs: list[float]
                      ) -> tuple[np.ndarray, list[Trial]]:
    done = [t for t in trials if t.state == TrialState.COMPLETED
            and t.values is not None and len(t.values) == len(signs)]
    if not done:
        return np.zeros((0, len(signs))), []
    Y = np.array([[s * v for s, v in zip(signs, t.values)] for t in done])
    return Y, done


def non_dominated_sort(Y: np.ndarray) -> list[np.ndarray]:
    """-> list of fronts (arrays of row indices), best first.  All
    objectives minimized."""
    n = len(Y)
    dominated_by = [[] for _ in range(n)]
    dom_count = np.zeros(n, dtype=int)
    for i in range(n):
        for j in range(i + 1, n):
            if np.all(Y[i] <= Y[j]) and np.any(Y[i] < Y[j]):
                dominated_by[i].append(j)
                dom_count[j] += 1
            elif np.all(Y[j] <= Y[i]) and np.any(Y[j] < Y[i]):
                dominated_by[j].append(i)
                dom_count[i] += 1
    fronts = []
    current = np.flatnonzero(dom_count == 0)
    while len(current):
        fronts.append(current)
        nxt = []
        for i in current:
            for j in dominated_by[i]:
                dom_count[j] -= 1
                if dom_count[j] == 0:
                    nxt.append(j)
        current = np.array(sorted(set(nxt)), dtype=int)
    return fronts


def crowding_distance(Y: np.ndarray) -> np.ndarray:
    n, m = Y.shape
    if n <= 2:
        return np.full(n, np.inf)
    dist = np.zeros(n)
    for k in range(m):
        order = np.argsort(Y[:, k])
        span = Y[order[-1], k] - Y[order[0], k]
        dist[order[0]] = dist[order[-1]] = np.inf
        if span <= 0:
            continue
        dist[order[1:-1]] += (Y[order[2:], k] - Y[order[:-2], k]) / span
    return dist


class NSGA2Sampler(Sampler):
    multi_objective = True          # server passes direction signs

    def __init__(self, population: int = 16, crossover_prob: float = 0.9,
                 eta_crossover: float = 20.0, eta_mutation: float = 20.0,
                 mutation_prob: float | None = None):
        self.population = int(population)
        self.crossover_prob = float(crossover_prob)
        self.eta_c = float(eta_crossover)
        self.eta_m = float(eta_mutation)
        self.mutation_prob = mutation_prob

    # ------------------------------------------------------------------
    def _ranked(self, Y: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        fronts = non_dominated_sort(Y)
        rank = np.zeros(len(Y), dtype=int)
        crowd = np.zeros(len(Y))
        for r, f in enumerate(fronts):
            rank[f] = r
            crowd[f] = crowding_distance(Y[f])
        return rank, crowd

    def _make_child(self, space: SearchSpace, done: list[Trial],
                    rank: np.ndarray, crowd: np.ndarray,
                    rng: np.random.Generator) -> dict[str, Any]:
        def tournament() -> int:
            i, j = rng.integers(0, len(done), size=2)
            if rank[i] != rank[j]:
                return i if rank[i] < rank[j] else j
            return i if crowd[i] >= crowd[j] else j

        i1 = tournament()
        i2 = tournament()
        for _ in range(4):                       # prefer distinct parents
            if i2 != i1:
                break
            i2 = tournament()
        p1 = space.to_unit_vector(done[i1].params)
        p2 = space.to_unit_vector(done[i2].params)
        child = self._sbx(np.asarray(p1), np.asarray(p2), rng)
        child = self._mutate(child, rng)
        return space.from_unit_vector(np.clip(child, 0.0, 1.0))

    def suggest(self, space: SearchSpace, trials: list[Trial],
                direction: Direction, rng: np.random.Generator,
                signs: list[float] | None = None) -> dict[str, Any]:
        signs = signs or [1.0]
        Y, done = _objective_matrix(trials, signs)
        if len(done) < self.population:
            return space.sample_uniform(rng)         # random warmup
        rank, crowd = self._ranked(Y)
        return self._make_child(space, done, rank, crowd, rng)

    def suggest_batch(self, space: SearchSpace, trials: list[Trial],
                      direction: Direction, rng: np.random.Generator,
                      n: int, signs: list[float] | None = None,
                      **kwargs: Any) -> list[dict[str, Any]]:
        """One non-dominated sort serves the whole offspring batch — the
        generational shape NSGA-II actually wants (Deb et al. 2002)."""
        signs = signs or [1.0]
        Y, done = _objective_matrix(trials, signs)
        if len(done) < self.population:
            return [space.sample_uniform(rng) for _ in range(n)]
        rank, crowd = self._ranked(Y)
        return [self._make_child(space, done, rank, crowd, rng)
                for _ in range(n)]

    # ------------------------------------------------------------------
    def _sbx(self, a: np.ndarray, b: np.ndarray,
             rng: np.random.Generator) -> np.ndarray:
        if rng.uniform() > self.crossover_prob:
            return a.copy()
        u = rng.uniform(size=a.shape)
        beta = np.where(u <= 0.5,
                        (2 * u) ** (1.0 / (self.eta_c + 1)),
                        (1.0 / (2 * (1 - u))) ** (1.0 / (self.eta_c + 1)))
        c1 = 0.5 * ((1 + beta) * a + (1 - beta) * b)
        c2 = 0.5 * ((1 - beta) * a + (1 + beta) * b)
        # per-variable exchange (standard SBX): pick c1 or c2 per dim
        return np.where(rng.uniform(size=a.shape) < 0.5, c1, c2)

    def _mutate(self, x: np.ndarray, rng: np.random.Generator) -> np.ndarray:
        prob = self.mutation_prob
        if prob is None:
            prob = 1.0 / max(len(x), 1)
        u = rng.uniform(size=x.shape)
        do = rng.uniform(size=x.shape) < prob
        delta = np.where(u < 0.5,
                         (2 * u) ** (1.0 / (self.eta_m + 1)) - 1.0,
                         1.0 - (2 * (1 - u)) ** (1.0 / (self.eta_m + 1)))
        return np.where(do, x + delta, x)
