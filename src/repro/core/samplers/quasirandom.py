from __future__ import annotations

from typing import Any

import numpy as np

from ..space import SearchSpace
from ..types import Direction, Trial
from .base import Sampler

_PRIMES = [2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47, 53, 59,
           61, 67, 71, 73, 79, 83, 89, 97, 101, 103, 107, 109, 113]


def _radical_inverse(i: np.ndarray, base: int) -> np.ndarray:
    """Vectorized van-der-Corput radical inverse of an index array."""
    i = np.asarray(i, dtype=np.int64).copy()
    f = 1.0
    r = np.zeros(i.shape, dtype=np.float64)
    while i.max(initial=0) > 0:
        f /= base
        r += f * (i % base)
        i //= base
    return r


class QuasiRandomSampler(Sampler):
    """Scrambled Halton low-discrepancy sequence.

    Better space coverage than i.i.d. uniform for the startup phase of an
    optimization campaign; used as the TPE startup strategy too.
    """

    def __init__(self, scramble: bool = True, seed: int = 0):
        self.scramble = scramble
        self.seed = int(seed)

    def points(self, start: int, n: int, dim: int) -> np.ndarray:
        """(n, dim) Halton points for indices start..start+n-1, computed
        as one array expression per dimension (no per-point Python)."""
        idx = np.arange(start + 1, start + n + 1, dtype=np.int64)
        u = np.empty((n, dim), dtype=np.float64)
        for d in range(dim):
            u[:, d] = _radical_inverse(idx, _PRIMES[d % len(_PRIMES)])
        if self.scramble:
            shift = np.random.default_rng(self.seed).uniform(size=dim)
            u = (u + shift) % 1.0
        return u

    def point(self, index: int, dim: int) -> np.ndarray:
        return self.points(index, 1, dim)[0]

    def suggest(self, space: SearchSpace, trials: list[Trial],
                direction: Direction, rng: np.random.Generator) -> dict[str, Any]:
        return space.from_unit_vector(self.point(len(trials), space.dim))
