"""CMA-ES (covariance matrix adaptation evolution strategy).

Evolutionary backend (paper sec. 2 mentions evolutionary algorithms as a
search modality).  Standard (mu/mu_w, lambda) CMA-ES on the unit cube,
adapted to the asynchronous ask/tell service model: a generation's
candidates are handed out as trials; the covariance update runs whenever
>= lambda new completed trials have accumulated since the last update.
"""
from __future__ import annotations

import math
from typing import Any

import numpy as np

from ..space import SearchSpace
from ..types import Direction, Trial
from .base import Sampler


class CmaEsSampler(Sampler):
    uses_cache = True

    def __init__(self, sigma0: float = 0.3, popsize: int | None = None, seed: int = 0):
        self.sigma0 = float(sigma0)
        self.popsize = popsize
        self._state: dict[str, Any] | None = None
        self._seen = 0
        self._queue: list[np.ndarray] = []

    def _init_state(self, d: int) -> dict[str, Any]:
        lam = self.popsize or (4 + int(3 * math.log(max(d, 1))))
        mu = lam // 2
        w = np.log(mu + 0.5) - np.log(np.arange(1, mu + 1))
        w /= w.sum()
        mueff = 1.0 / (w ** 2).sum()
        cc = (4 + mueff / d) / (d + 4 + 2 * mueff / d)
        cs = (mueff + 2) / (d + mueff + 5)
        c1 = 2 / ((d + 1.3) ** 2 + mueff)
        cmu = min(1 - c1, 2 * (mueff - 2 + 1 / mueff) / ((d + 2) ** 2 + mueff))
        damps = 1 + 2 * max(0.0, math.sqrt((mueff - 1) / (d + 1)) - 1) + cs
        return dict(lam=lam, mu=mu, w=w, mueff=mueff, cc=cc, cs=cs, c1=c1,
                    cmu=cmu, damps=damps, mean=np.full(d, 0.5), sigma=self.sigma0,
                    C=np.eye(d), ps=np.zeros(d), pc=np.zeros(d), gen=0)

    def _update(self, X: np.ndarray, y: np.ndarray) -> None:
        s = self._state
        d = len(s["mean"])
        order = np.argsort(y)[: s["mu"]]
        xsel = X[order]
        old_mean = s["mean"].copy()
        s["mean"] = s["w"] @ xsel

        eig, B = np.linalg.eigh(s["C"])
        eig = np.maximum(eig, 1e-12)
        inv_sqrt_C = B @ np.diag(eig ** -0.5) @ B.T

        zmean = inv_sqrt_C @ (s["mean"] - old_mean) / s["sigma"]
        s["ps"] = (1 - s["cs"]) * s["ps"] + math.sqrt(
            s["cs"] * (2 - s["cs"]) * s["mueff"]) * zmean
        chi_n = math.sqrt(d) * (1 - 1 / (4 * d) + 1 / (21 * d ** 2))
        hsig = float(np.linalg.norm(s["ps"]) /
                     math.sqrt(1 - (1 - s["cs"]) ** (2 * (s["gen"] + 1))) < (1.4 + 2 / (d + 1)) * chi_n)
        s["pc"] = (1 - s["cc"]) * s["pc"] + hsig * math.sqrt(
            s["cc"] * (2 - s["cc"]) * s["mueff"]) * (s["mean"] - old_mean) / s["sigma"]

        artmp = (xsel - old_mean) / s["sigma"]
        s["C"] = ((1 - s["c1"] - s["cmu"]) * s["C"]
                  + s["c1"] * (np.outer(s["pc"], s["pc"])
                               + (1 - hsig) * s["cc"] * (2 - s["cc"]) * s["C"])
                  + s["cmu"] * (artmp.T * s["w"]) @ artmp)
        s["sigma"] *= math.exp((s["cs"] / s["damps"]) *
                               (np.linalg.norm(s["ps"]) / chi_n - 1))
        s["sigma"] = float(np.clip(s["sigma"], 1e-4, 1.0))
        s["gen"] += 1

    def suggest(self, space: SearchSpace, trials: list[Trial],
                direction: Direction, rng: np.random.Generator,
                cache: Any = None) -> dict[str, Any]:
        d = space.dim
        if d == 0:
            return space.sample_uniform(rng)
        if self._state is None:
            self._state = self._init_state(d)

        X, y = self.observations(space, trials, direction, cache=cache)
        # consume newly completed evaluations generation-wise
        if len(y) - self._seen >= self._state["lam"]:
            self._update(X[self._seen:], y[self._seen:])
            self._seen = len(y)

        if not self._queue:
            s = self._state
            eig, B = np.linalg.eigh(s["C"])
            eig = np.maximum(eig, 1e-12)
            A = B @ np.diag(np.sqrt(eig))
            z = rng.standard_normal((s["lam"], d))
            pts = np.clip(s["mean"] + s["sigma"] * z @ A.T, 0.0, 1.0)
            self._queue = list(pts)
        return space.from_unit_vector(self._queue.pop(0))
