from __future__ import annotations

from typing import Any

import numpy as np

from ..space import SearchSpace
from ..types import Direction, Trial
from .base import Sampler


class RandomSampler(Sampler):
    """Independent uniform sampling (the paper's non-Bayesian baseline)."""

    def suggest(self, space: SearchSpace, trials: list[Trial],
                direction: Direction, rng: np.random.Generator) -> dict[str, Any]:
        return space.sample_uniform(rng)
