"""Gradient-less optimization backends (the Optuna role in the paper).

All samplers implement ``suggest(space, trials, direction, rng) ->
params`` where ``trials`` is the study's full trial list (the numeric
samplers filter completed observations themselves).  On the service ask
path the samplers that set ``uses_cache`` additionally receive the
per-study ``ObservationCache`` (``cache=`` kwarg), so the observation
matrix is an O(1) incrementally maintained buffer instead of a per-ask
rescan of the history.  Registry keyed by the ``sampler`` spec of the
study config, e.g. ``{"name": "tpe"}``.
"""
from __future__ import annotations

from typing import Any

from .base import Sampler
from .random import RandomSampler
from .grid import GridSampler
from .quasirandom import QuasiRandomSampler
from .tpe import TPESampler
from .gp import GPSampler
from .cmaes import CmaEsSampler
from .nsga2 import NSGA2Sampler

_REGISTRY = {
    "random": RandomSampler,
    "grid": GridSampler,
    "halton": QuasiRandomSampler,
    "quasirandom": QuasiRandomSampler,
    "tpe": TPESampler,
    "gp": GPSampler,
    "cmaes": CmaEsSampler,
    "nsga2": NSGA2Sampler,
}


def known_samplers() -> list[str]:
    """Registered sampler names (used by the API schema validation)."""
    return sorted(_REGISTRY)


def make_sampler(spec: dict[str, Any]) -> Sampler:
    spec = dict(spec or {"name": "tpe"})
    name = spec.pop("name", "tpe")
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown sampler {name!r}; known: {sorted(_REGISTRY)}")
    return cls(**spec)


__all__ = ["Sampler", "make_sampler", "known_samplers", "RandomSampler", "GridSampler",
           "QuasiRandomSampler", "TPESampler", "GPSampler", "CmaEsSampler"]
