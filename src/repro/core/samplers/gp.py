"""Gaussian-process Bayesian optimization with Expected Improvement.

A second Bayesian backend beside TPE (the paper plans 'future extensions to
additional frameworks').  Matérn-5/2 kernel on the unit cube, Cholesky
posterior in JAX, EI acquisition maximized over quasi-random candidates.

The covariance matrices go through ``repro.core.kernels.matern52_cross``
(Pallas tiled matmul-form on TPU, equivalent jnp fallback elsewhere — no
(A, B, D) pairwise-difference intermediate), the EI pipeline is one fused
jit, and on the service ask path the padded (X, y, mask) buffers come
straight from the per-study ``ObservationCache`` (pow-2 capacity, so the
jit signature only changes when the history doubles).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import matern52_cross
from ..obs_cache import check_liar
from ..obs_cache import liar_value as _liar_value
from ..obs_cache import pad_pow2 as _pad_pow2
from ..space import SearchSpace
from ..types import Direction, Trial
from .base import Sampler
from .quasirandom import QuasiRandomSampler


@jax.jit
def _gp_ei(X: jnp.ndarray, y: jnp.ndarray, mask: jnp.ndarray,
           cands: jnp.ndarray, ls: jnp.ndarray) -> jnp.ndarray:
    """Expected improvement of candidates under a GP fit to (X, y, mask)."""
    n = jnp.maximum(mask.sum(), 1.0)
    mu0 = (y * mask).sum() / n
    var0 = ((y - mu0) ** 2 * mask).sum() / n + 1e-12
    yn = (y - mu0) / jnp.sqrt(var0)

    K = matern52_cross(X, X, ls)
    K = jnp.where(mask[:, None] * mask[None, :] > 0, K, 0.0)
    diag = jnp.where(mask > 0, 1e-6 + 1e-3, 1.0)   # unit diag for padded rows
    K = K + jnp.diag(diag)
    L = jnp.linalg.cholesky(K)
    alpha = jax.scipy.linalg.cho_solve((L, True), yn * mask)

    Ks = matern52_cross(cands, X, ls) * mask[None, :]
    mu = Ks @ alpha
    v = jax.scipy.linalg.solve_triangular(L, Ks.T, lower=True)
    var = jnp.maximum(1.0 - (v ** 2).sum(0), 1e-9)
    sd = jnp.sqrt(var)

    best = jnp.min(jnp.where(mask > 0, yn, jnp.inf))
    z = (best - mu) / sd
    phi = jnp.exp(-0.5 * z ** 2) / math.sqrt(2 * math.pi)
    Phi = 0.5 * (1 + jax.scipy.special.erf(z / math.sqrt(2)))
    return sd * (z * Phi + phi)


class GPSampler(Sampler):
    uses_cache = True
    pending_aware = True

    # GP is O(n^3); beyond this many observations defer to quasirandom
    # exploration (TPE is the scalable default anyway).
    MAX_OBSERVATIONS = 512

    def __init__(self, n_startup_trials: int = 8, n_candidates: int = 256,
                 lengthscale: float = 0.25, seed: int = 0,
                 liar: str = "mean"):
        self.n_startup_trials = int(n_startup_trials)
        self.n_candidates = int(n_candidates)
        self.lengthscale = float(lengthscale)
        self.liar = check_liar(liar)
        self._startup = QuasiRandomSampler(seed=seed)

    def _padded_obs(self, space: SearchSpace, trials: list[Trial],
                    direction: Direction, cache: Any
                    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, int,
                               float | None]:
        """(Xp, yp, mp, n_obs, liar) — pow-2 padded posterior evidence
        including the constant-liar fantasy rows for RUNNING trials."""
        if cache is not None:
            n_obs = cache.count
            if self.liar != "none":
                Xp, yp, mp = cache.padded_augmented()
                lv = cache.liar_value()
            else:
                Xp, yp, mp = cache.padded()
                lv = None
            return Xp, yp, mp, n_obs, lv
        X, y, n_obs = self.observations_pending(
            space, trials, direction, liar=self.liar)
        total = len(y)
        n = _pad_pow2(total)
        Xp = np.zeros((n, space.dim)); Xp[:total] = X
        yp = np.zeros(n); yp[:total] = y
        mp = np.zeros(n); mp[:total] = 1.0
        lv = (_liar_value(y[:n_obs], self.liar)
              if self.liar != "none" and n_obs else None)
        return Xp, yp, mp, n_obs, lv

    def _ei_argmax(self, space: SearchSpace, rng: np.random.Generator,
                   Xp: np.ndarray, yp: np.ndarray, mp: np.ndarray
                   ) -> np.ndarray:
        """Unit-cube point maximizing EI over one fresh Halton pool."""
        # one batched Halton draw — no per-candidate sampler construction
        qr = QuasiRandomSampler(seed=int(rng.integers(0, 2**31 - 1)))
        cands = qr.points(0, self.n_candidates, space.dim)
        ls = jnp.full((space.dim,), self.lengthscale)
        ei = _gp_ei(jnp.asarray(Xp), jnp.asarray(yp), jnp.asarray(mp),
                    jnp.asarray(cands), ls)
        return cands[int(np.argmax(np.asarray(ei)))]

    def speculative_ready(self, cache: Any) -> bool:
        return (self.liar != "none"
                and self.n_startup_trials <= cache.count
                <= self.MAX_OBSERVATIONS)

    def suggest(self, space: SearchSpace, trials: list[Trial],
                direction: Direction, rng: np.random.Generator,
                cache: Any = None) -> dict[str, Any]:
        Xp, yp, mp, n_obs, _ = self._padded_obs(
            space, trials, direction, cache)
        if n_obs < self.n_startup_trials or space.dim == 0 \
                or n_obs > self.MAX_OBSERVATIONS:
            return self._startup.suggest(space, trials, direction, rng)
        return space.from_unit_vector(
            self._ei_argmax(space, rng, Xp, yp, mp))

    def suggest_batch(self, space: SearchSpace, trials: list[Trial],
                      direction: Direction, rng: np.random.Generator,
                      n: int, cache: Any = None, chunk: int | None = None,
                      **kwargs: Any) -> list[dict[str, Any]]:
        """Fantasy-accumulating batch: after each pick the point is
        appended as a liar-valued observation, so the next EI round is
        repelled from it — n distinct proposals, not n argmax copies.
        ``chunk`` (the speculative streaming hint) is accepted for API
        parity with TPE and ignored: GP batches are inherently
        per-point fantasy updates."""
        Xp, yp, mp, n_obs, lv = self._padded_obs(
            space, trials, direction, cache)
        if lv is None or n_obs < self.n_startup_trials or space.dim == 0 \
                or n_obs > self.MAX_OBSERVATIONS:
            return super().suggest_batch(space, trials, direction, rng, n,
                                         cache=cache, **kwargs)
        # private copies: the padded views may be the cache's memoized
        # buffers and must not see our fantasy rows
        Xc, yc, mc = np.array(Xp), np.array(yp), np.array(mp)
        total = int(mc.sum())
        out: list[np.ndarray] = []
        for _ in range(n):
            pick = self._ei_argmax(space, rng, Xc, yc, mc)
            out.append(pick)
            if total == len(yc):          # grow to the next pow-2 shape
                cap = _pad_pow2(total + 1)
                Xg = np.zeros((cap, space.dim)); Xg[:total] = Xc[:total]
                yg = np.zeros(cap); yg[:total] = yc[:total]
                mg = np.zeros(cap); mg[:total] = mc[:total]
                Xc, yc, mc = Xg, yg, mg
            Xc[total], yc[total], mc[total] = pick, lv, 1.0
            total += 1
        return space.from_unit_matrix(np.stack(out))
