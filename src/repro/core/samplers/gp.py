"""Gaussian-process Bayesian optimization with Expected Improvement.

A second Bayesian backend beside TPE (the paper plans 'future extensions to
additional frameworks').  Matérn-5/2 kernel on the unit cube, Cholesky
posterior in JAX, EI acquisition maximized over quasi-random candidates.
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..space import SearchSpace
from ..types import Direction, Trial
from .base import Sampler
from .quasirandom import QuasiRandomSampler


def _pad_pow2(n: int, lo: int = 8) -> int:
    return max(lo, 1 << (n - 1).bit_length())


def _matern52(x1: jnp.ndarray, x2: jnp.ndarray, ls: jnp.ndarray) -> jnp.ndarray:
    d = jnp.sqrt(jnp.maximum(
        ((x1[:, None, :] - x2[None, :, :]) ** 2 / ls ** 2).sum(-1), 1e-12))
    s5d = math.sqrt(5.0) * d
    return (1.0 + s5d + s5d ** 2 / 3.0) * jnp.exp(-s5d)


@jax.jit
def _gp_ei(X: jnp.ndarray, y: jnp.ndarray, mask: jnp.ndarray,
           cands: jnp.ndarray, ls: jnp.ndarray) -> jnp.ndarray:
    """Expected improvement of candidates under a GP fit to (X, y, mask)."""
    n = jnp.maximum(mask.sum(), 1.0)
    mu0 = (y * mask).sum() / n
    var0 = ((y - mu0) ** 2 * mask).sum() / n + 1e-12
    yn = (y - mu0) / jnp.sqrt(var0)

    K = _matern52(X, X, ls)
    K = jnp.where(mask[:, None] * mask[None, :] > 0, K, 0.0)
    diag = jnp.where(mask > 0, 1e-6 + 1e-3, 1.0)   # unit diag for padded rows
    K = K + jnp.diag(diag)
    L = jnp.linalg.cholesky(K)
    alpha = jax.scipy.linalg.cho_solve((L, True), yn * mask)

    Ks = _matern52(cands, X, ls) * mask[None, :]
    mu = Ks @ alpha
    v = jax.scipy.linalg.solve_triangular(L, Ks.T, lower=True)
    var = jnp.maximum(1.0 - (v ** 2).sum(0), 1e-9)
    sd = jnp.sqrt(var)

    best = jnp.min(jnp.where(mask > 0, yn, jnp.inf))
    z = (best - mu) / sd
    phi = jnp.exp(-0.5 * z ** 2) / math.sqrt(2 * math.pi)
    Phi = 0.5 * (1 + jax.scipy.special.erf(z / math.sqrt(2)))
    return sd * (z * Phi + phi)


class GPSampler(Sampler):
    def __init__(self, n_startup_trials: int = 8, n_candidates: int = 256,
                 lengthscale: float = 0.25, seed: int = 0):
        self.n_startup_trials = int(n_startup_trials)
        self.n_candidates = int(n_candidates)
        self.lengthscale = float(lengthscale)
        self._startup = QuasiRandomSampler(seed=seed)

    def suggest(self, space: SearchSpace, trials: list[Trial],
                direction: Direction, rng: np.random.Generator) -> dict[str, Any]:
        X, y = self.observations(space, trials, direction)
        if len(y) < self.n_startup_trials or space.dim == 0 or len(y) > 512:
            # GP is O(n^3); beyond 512 observations defer to quasirandom
            # exploration (TPE is the scalable default anyway).
            return self._startup.suggest(space, trials, direction, rng)

        n = _pad_pow2(len(y))
        Xp = np.zeros((n, space.dim)); Xp[: len(y)] = X
        mp = np.zeros(n); mp[: len(y)] = 1.0
        yp = np.zeros(n); yp[: len(y)] = y

        cands = np.stack([
            QuasiRandomSampler(seed=int(rng.integers(0, 2**31 - 1))).point(i, space.dim)
            for i in range(self.n_candidates)])
        ls = jnp.full((space.dim,), self.lengthscale)
        ei = _gp_ei(jnp.asarray(Xp), jnp.asarray(yp), jnp.asarray(mp),
                    jnp.asarray(cands), ls)
        return space.from_unit_vector(cands[int(np.argmax(np.asarray(ei)))])
