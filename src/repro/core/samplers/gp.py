"""Gaussian-process Bayesian optimization with Expected Improvement.

A second Bayesian backend beside TPE (the paper plans 'future extensions to
additional frameworks').  Matérn-5/2 kernel on the unit cube, Cholesky
posterior in JAX, EI acquisition maximized over quasi-random candidates.

The covariance matrices go through ``repro.core.kernels.matern52_cross``
(Pallas tiled matmul-form on TPU, equivalent jnp fallback elsewhere — no
(A, B, D) pairwise-difference intermediate), the EI pipeline is one fused
jit, and on the service ask path the padded (X, y, mask) buffers come
straight from the per-study ``ObservationCache`` (pow-2 capacity, so the
jit signature only changes when the history doubles).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import matern52_cross
from ..obs_cache import pad_pow2 as _pad_pow2
from ..space import SearchSpace
from ..types import Direction, Trial
from .base import Sampler
from .quasirandom import QuasiRandomSampler


@jax.jit
def _gp_ei(X: jnp.ndarray, y: jnp.ndarray, mask: jnp.ndarray,
           cands: jnp.ndarray, ls: jnp.ndarray) -> jnp.ndarray:
    """Expected improvement of candidates under a GP fit to (X, y, mask)."""
    n = jnp.maximum(mask.sum(), 1.0)
    mu0 = (y * mask).sum() / n
    var0 = ((y - mu0) ** 2 * mask).sum() / n + 1e-12
    yn = (y - mu0) / jnp.sqrt(var0)

    K = matern52_cross(X, X, ls)
    K = jnp.where(mask[:, None] * mask[None, :] > 0, K, 0.0)
    diag = jnp.where(mask > 0, 1e-6 + 1e-3, 1.0)   # unit diag for padded rows
    K = K + jnp.diag(diag)
    L = jnp.linalg.cholesky(K)
    alpha = jax.scipy.linalg.cho_solve((L, True), yn * mask)

    Ks = matern52_cross(cands, X, ls) * mask[None, :]
    mu = Ks @ alpha
    v = jax.scipy.linalg.solve_triangular(L, Ks.T, lower=True)
    var = jnp.maximum(1.0 - (v ** 2).sum(0), 1e-9)
    sd = jnp.sqrt(var)

    best = jnp.min(jnp.where(mask > 0, yn, jnp.inf))
    z = (best - mu) / sd
    phi = jnp.exp(-0.5 * z ** 2) / math.sqrt(2 * math.pi)
    Phi = 0.5 * (1 + jax.scipy.special.erf(z / math.sqrt(2)))
    return sd * (z * Phi + phi)


class GPSampler(Sampler):
    uses_cache = True

    def __init__(self, n_startup_trials: int = 8, n_candidates: int = 256,
                 lengthscale: float = 0.25, seed: int = 0):
        self.n_startup_trials = int(n_startup_trials)
        self.n_candidates = int(n_candidates)
        self.lengthscale = float(lengthscale)
        self._startup = QuasiRandomSampler(seed=seed)

    def suggest(self, space: SearchSpace, trials: list[Trial],
                direction: Direction, rng: np.random.Generator,
                cache: Any = None) -> dict[str, Any]:
        if cache is not None:
            n_obs = cache.count
        else:
            X, y = self.observations(space, trials, direction)
            n_obs = len(y)
        if n_obs < self.n_startup_trials or space.dim == 0 or n_obs > 512:
            # GP is O(n^3); beyond 512 observations defer to quasirandom
            # exploration (TPE is the scalable default anyway).
            return self._startup.suggest(space, trials, direction, rng)

        if cache is not None:
            Xp, yp, mp = cache.padded()     # pre-padded, pow-2 capacity
        else:
            n = _pad_pow2(n_obs)
            Xp = np.zeros((n, space.dim)); Xp[:n_obs] = X
            mp = np.zeros(n); mp[:n_obs] = 1.0
            yp = np.zeros(n); yp[:n_obs] = y

        # one batched Halton draw — no per-candidate sampler construction
        qr = QuasiRandomSampler(seed=int(rng.integers(0, 2**31 - 1)))
        cands = qr.points(0, self.n_candidates, space.dim)
        ls = jnp.full((space.dim,), self.lengthscale)
        ei = _gp_ei(jnp.asarray(Xp), jnp.asarray(yp), jnp.asarray(mp),
                    jnp.asarray(cands), ls)
        return space.from_unit_vector(cands[int(np.argmax(np.asarray(ei)))])
