from __future__ import annotations

from typing import Any

import numpy as np

from ..space import SearchSpace
from ..types import Direction, Trial
from .base import Sampler


class GridSampler(Sampler):
    """Full-factorial grid search; cycles once the lattice is exhausted."""

    def __init__(self, points_per_dim: int = 5):
        self.points_per_dim = int(points_per_dim)
        self._lattice: list[dict[str, Any]] | None = None

    def suggest(self, space: SearchSpace, trials: list[Trial],
                direction: Direction, rng: np.random.Generator) -> dict[str, Any]:
        if self._lattice is None:
            self._lattice = space.grid(self.points_per_dim)
        idx = len(trials) % len(self._lattice)
        return dict(self._lattice[idx])
