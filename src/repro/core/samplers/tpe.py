"""Tree-structured Parzen Estimator (Bergstra et al. 2011) — the Optuna
default sampler the paper's reference implementation relies on.

The surrogate split/score path is implemented with JAX and jitted: trial
histories are padded to power-of-two lengths so that the jit cache stays
small while the KDE math runs as one fused XLA computation.  The Parzen
mixture scores go through ``repro.core.kernels.parzen_log_density`` — a
Pallas TPU kernel (tiled candidates x observations, online logsumexp,
no (C, N, D) intermediate) with an equivalent matmul-form ``jnp``
fallback off-TPU.

On the service ask path the observation matrix comes from the per-study
``ObservationCache`` (``cache=`` kwarg): history featurization is an O(1)
incremental append on tell, not a per-ask rescan of every trial.

Model: completed observations are split into the best ``gamma``-fraction
(l, "good") and the rest (g, "bad").  Each set defines a per-dimension
Parzen mixture (truncated Gaussians on the unit cube; categorical weights
for discrete dims).  ``n_candidates`` points are drawn from l(x) and the
one maximizing  log l(x) - log g(x)  (equivalently EI) is suggested.
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import parzen_log_density
from ..obs_cache import check_liar, liar_value
from ..obs_cache import pad_pow2 as _pad_pow2
from ..space import SearchSpace
from ..types import Direction, Trial
from .base import Sampler
from .quasirandom import QuasiRandomSampler


@functools.partial(jax.jit, static_argnames=("n_candidates",))
def _tpe_propose(xg: jnp.ndarray, mg: jnp.ndarray,
                 xb: jnp.ndarray, mb: jnp.ndarray,
                 key: jax.Array, n_candidates: int) -> jnp.ndarray:
    """Propose points on the unit cube, best acquisition score first (the
    caller slices the top-k it needs — keeping the batch size out of the
    jit signature avoids a recompile per distinct k).

    xg: (Ng, D) good observations (padded), mg: (Ng,) validity mask.
    xb: (Nb, D) bad observations (padded),  mb: (Nb,) validity mask.
    Returns (n_candidates, D) candidates sorted by descending score.

    Both mixtures carry a uniform-prior component (a wide Gaussian at the
    cube center with weight 1, Optuna's ``prior_weight``): without it the
    l/g ratio over-exploits the incumbent cluster and TPE degenerates to
    local search.
    """
    d = xg.shape[1]
    kcand, kpick, kunif = jax.random.split(key, 3)

    def _bandwidth(obs, mask, lo, hi):
        n = jnp.maximum(mask.sum(), 1.0)
        mean = (obs * mask[:, None]).sum(0) / n
        var = ((obs - mean) ** 2 * mask[:, None]).sum(0) / n
        return jnp.clip(jnp.sqrt(var + 1e-12) * n ** (-1.0 / (d + 4)), lo, hi)

    bw = _bandwidth(xg, mg, 0.05, 0.5)
    bw_b = _bandwidth(xb, mb, 0.08, 0.7)

    # Candidates: 3/4 sampled from l(x) (good point + bandwidth jitter),
    # 1/4 uniform exploration.
    ng = jnp.maximum(mg.sum(), 1.0)
    idx = jax.random.categorical(kcand, jnp.log(mg / ng + 1e-20),
                                 shape=(n_candidates,))
    noise = jax.random.normal(kpick, (n_candidates, d)) * bw
    from_l = jnp.clip(xg[idx] + noise, 0.0, 1.0)
    uniform = jax.random.uniform(kunif, (n_candidates, d))
    take_l = (jnp.arange(n_candidates) % 4 != 3)[:, None]
    cands = jnp.where(take_l, from_l, uniform)

    def log_parzen(x, obs, mask, bws):
        # fused mixture log-density (Pallas on TPU, matmul-form jnp
        # fallback elsewhere) + the uniform-prior component
        logk = parzen_log_density(x, obs, mask, bws)
        zp = (x - 0.5) / 1.0
        logp = (-0.5 * zp * zp - jnp.log(math.sqrt(2 * math.pi))).sum(-1)
        n = jnp.maximum(mask.sum(), 1.0)
        return jnp.logaddexp(logk, logp) - jnp.log(n + 1.0)

    score = log_parzen(cands, xg, mg, bw) - log_parzen(cands, xb, mb, bw_b)
    return cands[jnp.argsort(-score)]


class TPESampler(Sampler):
    uses_cache = True
    pending_aware = True

    def __init__(self, n_startup_trials: int = 10, gamma: float | None = None,
                 n_candidates: int = 64, seed: int = 0, liar: str = "mean",
                 liar_chunk: int = 4):
        self.n_startup_trials = int(n_startup_trials)
        self.gamma = gamma                 # None -> Optuna default schedule
        self.n_candidates = int(n_candidates)
        self.liar = check_liar(liar)
        # batched asks re-split after every `liar_chunk` fantasy appends:
        # within a chunk the proposals are distinct top-scored candidates
        # of one fused evaluation, across chunks the liar rows push the
        # next chunk away from what the batch already claimed
        self.liar_chunk = max(1, int(liar_chunk))
        self._startup = QuasiRandomSampler(seed=seed)
        # good/bad split of the cached observations, memoized on the
        # cache token (observed count + pending-set fingerprint): the
        # split (and the padded device buffers) only changes when a tell
        # lands or the in-flight set churns — repeat asks against an
        # unchanged history skip straight to the jitted proposal
        self._split_key: tuple | None = None
        self._split: tuple | None = None

    def _n_good(self, n: int) -> int:
        if self.gamma is not None:
            return max(2, int(math.ceil(self.gamma * n)))
        return max(2, min(int(math.ceil(0.1 * n)), 25))   # Optuna default_gamma

    def _split_xy(self, space: SearchSpace, X: np.ndarray, y: np.ndarray
                  ) -> tuple:
        """Good/bad Parzen split of (X, y) as padded device buffers."""
        n_good = self._n_good(len(y))
        order = np.argsort(y)
        good, bad = X[order[:n_good]], X[order[n_good:]]
        if len(bad) == 0:       # degenerate split: everything is "good"
            bad = good

        ng, nb = _pad_pow2(len(good)), _pad_pow2(len(bad))
        xg = np.zeros((ng, space.dim)); xg[: len(good)] = good
        mg = np.zeros(ng); mg[: len(good)] = 1.0
        xb = np.zeros((nb, space.dim)); xb[: len(bad)] = bad
        mb = np.zeros(nb); mb[: len(bad)] = 1.0
        return (jnp.asarray(xg), jnp.asarray(mg),
                jnp.asarray(xb), jnp.asarray(mb))

    def _split_observations(self, space: SearchSpace, trials: list[Trial],
                            direction: Direction, cache: Any) -> tuple | None:
        """Padded (xg, mg, xb, mb) device buffers, or None in startup."""
        memo_key = None if cache is None else (id(cache), cache.token)
        if memo_key is not None and memo_key == self._split_key:
            return self._split
        X, y, n_obs = self.observations_pending(
            space, trials, direction, cache=cache, liar=self.liar)
        if n_obs < self.n_startup_trials or space.dim == 0:
            return None
        split = self._split_xy(space, X, y)
        if memo_key is not None:
            self._split_key, self._split = memo_key, split
        return split

    def speculative_ready(self, cache: Any) -> bool:
        return (self.liar != "none"
                and cache.count >= self.n_startup_trials)

    def _propose(self, space: SearchSpace, trials: list[Trial],
                 direction: Direction, rng: np.random.Generator,
                 k: int, cache: Any = None) -> np.ndarray | None:
        """(k, D) unit-cube proposals, or None while still in startup."""
        split = self._split_observations(space, trials, direction, cache)
        if split is None:
            return None
        xg, mg, xb, mb = split
        key = jax.random.PRNGKey(int(rng.integers(0, 2**31 - 1)))
        u = _tpe_propose(xg, mg, xb, mb, key, self._pool(k))
        return np.asarray(u[:k])

    def _pool(self, k: int) -> int:
        """Candidate-pool size for a top-``k`` draw: at least 4x the
        ask so the acquisition keeps selection pressure (top-k of a
        k-sized pool is just the pool, ranked), pow-2-padded so the jit
        cache stays small when k varies."""
        return max(self.n_candidates, _pad_pow2(4 * k))

    def suggest(self, space: SearchSpace, trials: list[Trial],
                direction: Direction, rng: np.random.Generator,
                cache: Any = None) -> dict[str, Any]:
        u = self._propose(space, trials, direction, rng, 1, cache=cache)
        if u is None:
            return self._startup.suggest(space, trials, direction, rng)
        return space.from_unit_vector(u[0])

    def suggest_batch(self, space: SearchSpace, trials: list[Trial],
                      direction: Direction, rng: np.random.Generator,
                      n: int, cache: Any = None, chunk: int | None = None,
                      **kwargs: Any) -> list[dict[str, Any]]:
        """Batch proposal with incremental constant-liar updates.

        The batch is built in chunks of ``liar_chunk``: each chunk takes
        the top-scored candidates of one fused KDE evaluation (distinct
        points, not copies of the argmax), then the chunk is appended to
        the history as fantasy rows at the liar value and the split is
        recomputed — so later chunks are repelled from what the batch
        already claimed, the same way concurrent workers repel each
        other through the pending view.  With ``liar="none"`` this
        degrades to the legacy single fused top-n draw.

        ``chunk`` overrides the adaptive chunk size — the speculative
        precompute streams a round as slices whose liar chaining happens
        in the caller (``CacheSnapshot.with_fantasies``), so each slice
        must be exactly one fused evaluation, not re-chunked here.
        """
        if self.liar == "none":
            u = self._propose(space, trials, direction, rng, n, cache=cache)
            if u is None:       # startup: fall back to the sequential path
                return super().suggest_batch(space, trials, direction, rng,
                                             n, cache=cache, **kwargs)
            return space.from_unit_matrix(u)

        X, y, n_obs = self.observations_pending(
            space, trials, direction, cache=cache, liar=self.liar)
        if n_obs < self.n_startup_trials or space.dim == 0:
            return super().suggest_batch(space, trials, direction, rng, n,
                                         cache=cache, **kwargs)
        lv = liar_value(y[:n_obs], self.liar)
        # large batches (speculative precompute at high parallelism) cap
        # the split count at 8: re-splitting every `liar_chunk` rows
        # would make a 256-proposal round ~64 KDE rebuilds, slow enough
        # to starve the queue it is meant to fill
        if chunk is None:
            chunk = max(self.liar_chunk, -(-n // 8))
        else:
            chunk = max(1, int(chunk))
        chunks: list[np.ndarray] = []
        got = 0
        while got < n:
            k = min(chunk, n - got)
            xg, mg, xb, mb = self._split_xy(space, X, y)
            key = jax.random.PRNGKey(int(rng.integers(0, 2**31 - 1)))
            u = np.asarray(_tpe_propose(xg, mg, xb, mb, key,
                                        self._pool(k))[:k])
            chunks.append(u)
            got += k
            if got < n:
                X = np.concatenate([X, u])
                y = np.concatenate([y, np.full(k, lv)])
        return space.from_unit_matrix(np.concatenate(chunks))
