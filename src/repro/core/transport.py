"""Transports between HOPAAS clients and the service.

* ``DirectTransport``      — in-process function call (fast path for tests
                             and single-host campaigns).
* ``HttpTransport``        — one persistent HTTP/1.1 connection (stdlib
                             ``http.client``), reconnect-once on stale
                             keep-alive sockets.
* ``PooledHttpTransport``  — N persistent connections with checkout /
                             checkin, so multi-threaded workers sharing
                             one transport stop serializing on a single
                             socket.
* ``HttpServiceRunner``    — the server side: mounts ``HopaasServer``
                             workers behind either the event-loop
                             frontend (``repro.core.aio``, the default)
                             or the legacy thread-per-connection stdlib
                             server (``backend="threaded"``).
* ``ReverseProxy`` role    — both frontends fan requests out over N
                             backend workers sharing one storage (the
                             NGINX + Uvicorn×N shape of paper sec. 3).

All transports carry request *headers* (the v2 surface authenticates via
``Authorization: Bearer``) and pass query strings through untouched, so
``GET /api/v2/studies/{key}/trials?state=completed&limit=50`` works
identically in-process and over the wire.  ``request_full`` additionally
exposes response headers (e.g. the ``Allow`` list on a 405).

The frontend backend is selected per runner (``backend=``) or globally
via ``REPRO_FRONTEND=evloop|threaded`` (CI runs the suite under both).
"""
from __future__ import annotations

import http.client
import itertools
import json
import os
import queue
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any

from .server import HopaasServer

# (status, payload) / (status, payload, response headers)
Result = tuple[int, dict[str, Any]]
FullResult = tuple[int, dict[str, Any], dict[str, str]]


class Transport:
    def request(self, method: str, path: str,
                body: dict[str, Any] | None = None,
                headers: dict[str, str] | None = None) -> Result:
        return self.request_full(method, path, body, headers)[:2]

    def request_full(self, method: str, path: str,
                     body: dict[str, Any] | None = None,
                     headers: dict[str, str] | None = None) -> FullResult:
        raise NotImplementedError


class DirectTransport(Transport):
    def __init__(self, server: HopaasServer):
        self.server = server

    def request_full(self, method, path, body=None, headers=None):
        return self.server.handle_request(method, path, body, headers)


class RoundRobinTransport(Transport):
    """Client-side round robin across several in-proc workers (used to test
    the shared-storage consistency of horizontally scaled servers)."""

    def __init__(self, servers: list[HopaasServer]):
        self.servers = servers
        self._counter = itertools.count()    # next() is GIL-atomic

    def request_full(self, method, path, body=None, headers=None):
        i = next(self._counter) % len(self.servers)
        return self.servers[i].handle_request(method, path, body, headers)


# --------------------------------------------------------------------------- #
# HTTP server side
# --------------------------------------------------------------------------- #
def _make_handler(target):
    class Handler(BaseHTTPRequestHandler):
        # HTTP/1.1 => persistent connections; every response carries an
        # explicit Content-Length so keep-alive framing is unambiguous.
        protocol_version = "HTTP/1.1"

        def log_message(self, *a):   # quiet
            pass

        def _respond(self, status: int, payload: dict[str, Any],
                     extra_headers: dict[str, str] | None = None,
                     head_only: bool = False) -> None:
            blob = json.dumps(payload).encode()
            self.send_response(status)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(blob)))
            for k, v in (extra_headers or {}).items():
                self.send_header(k, v)
            self.end_headers()
            if not head_only:      # HEAD: headers only (RFC 7231 §4.3.2)
                self.wfile.write(blob)

        def _read_body(self) -> tuple[Any, str | None]:
            """(parsed JSON, parse-error message).  Always drains the
            socket so keep-alive framing survives a bad body."""
            n = int(self.headers.get("Content-Length", 0) or 0)
            raw = self.rfile.read(n) if n else b""
            if not raw:
                return None, None
            try:
                return json.loads(raw), None
            except json.JSONDecodeError as e:
                return None, f"request body is not valid JSON: {e.msg}"

        def _dispatch(self, method: str, body: Any,
                      body_error: str | None) -> None:
            self._respond(*target(self.path, method, body,
                                  dict(self.headers), body_error),
                          head_only=method == "HEAD")

        def do_GET(self):
            self._read_body()    # drain any body; GET bodies are ignored
            self._dispatch("GET", None, None)

        def do_HEAD(self):
            self._read_body()
            self._dispatch("HEAD", None, None)

        # every other method reaches the router, which answers 405 with
        # an ``Allow`` header (not the stdlib's bare 501) for paths that
        # exist under a different method — wire parity with
        # ``Router.dispatch``
        def _do_with_body(self, method: str) -> None:
            body, err = self._read_body()
            self._dispatch(method, body, err)

        def do_POST(self):
            self._do_with_body("POST")

        def do_PUT(self):
            self._do_with_body("PUT")

        def do_PATCH(self):
            self._do_with_body("PATCH")

        def do_DELETE(self):
            self._do_with_body("DELETE")

        def do_OPTIONS(self):
            self._do_with_body("OPTIONS")

    return Handler


class _ThreadedFrontend:
    """Legacy thread-per-connection frontend (stdlib ThreadingHTTPServer).

    Kept as the ``backend="threaded"`` reference implementation and the
    baseline for ``benchmarks/bench_transport.py``.
    """

    def __init__(self, workers: list[HopaasServer], host: str, port: int):
        self.workers = workers
        # lock-free round robin: itertools.count().__next__ is atomic
        # under the GIL, so the old per-request Lock is pure overhead
        self._counter = itertools.count()
        self.httpd = ThreadingHTTPServer((host, port), _make_handler(
            lambda path, method, body, headers, body_error:
                self._pick().handle_request(method, path, body, headers,
                                            body_error)))
        self.host, self.port = self.httpd.server_address[:2]
        self._thread = threading.Thread(target=self.httpd.serve_forever,
                                        daemon=True)

    def _pick(self) -> HopaasServer:
        return self.workers[next(self._counter) % len(self.workers)]

    def start(self) -> "_ThreadedFrontend":
        self._thread.start()
        return self

    def stop(self) -> None:
        self.httpd.shutdown()
        self.httpd.server_close()

    def stats(self) -> dict[str, Any]:
        return {"backend": "threaded"}


def _env_workers() -> int:
    try:
        return max(1, int(os.environ.get("REPRO_WORKERS", "1") or 1))
    except ValueError:
        return 1


class HttpServiceRunner:
    """Hosts one or more HopaasServer workers behind an HTTP frontend.

    ``backend`` selects the frontend: ``"evloop"`` (default) is the
    selector-based event-loop server with sharded dispatch lanes
    (``repro.core.aio``); ``"threaded"`` is the legacy stdlib
    thread-per-connection server.  ``REPRO_FRONTEND`` overrides the
    default process-wide (CI exercises both).  With multiple workers,
    requests fan out across worker instances that share one storage —
    the paper's Uvicorn×N + PostgreSQL deployment shape; the event loop
    pins each dispatch lane (and therefore each study) to one worker.

    ``workers=N`` (or ``REPRO_WORKERS=N``) additionally threads the
    shard-fabric router into the request path: the public frontend runs
    the consistent-hash ``FabricDispatcher`` and proxies every request
    to one of N internal shard frontends, exercising classification,
    ring routing, the byte-level proxy and scatter-gather on every
    request.  The shard frontends share the caller's workers (and
    therefore one storage), so semantics are identical to the
    single-frontend runner — CI runs the whole suite in this mode.  For
    *process*-level parallelism with private per-worker storage, use
    ``repro.core.fabric.ShardFabric`` (the service CLI's ``--workers``).
    The threaded backend ignores ``workers`` (it has no dispatcher
    hook).
    """

    def __init__(self, server: HopaasServer | list[HopaasServer],
                 host: str = "127.0.0.1", port: int = 0,
                 backend: str | None = None, lanes: int | None = None,
                 workers: int | None = None):
        self.workers = server if isinstance(server, list) else [server]
        self.backend = (backend
                        or os.environ.get("REPRO_FRONTEND", "evloop")).lower()
        self.fabric_workers = (_env_workers() if workers is None
                               else max(1, int(workers)))
        self._shards: list[Any] = []
        self._dispatcher = None
        if self.backend == "evloop":
            from .aio import EventLoopFrontend
            if self.fabric_workers > 1:
                from .fabric import FabricDispatcher, RouteTable
                # N internal shard frontends on private ports; the
                # public frontend only routes + proxies
                self._shards = [
                    EventLoopFrontend(self.workers, host=host, port=0,
                                      lanes=lanes)
                    for _ in range(self.fabric_workers)]
                table = RouteTable({i: (host, fe.port)
                                    for i, fe in enumerate(self._shards)})
                self._dispatcher = FabricDispatcher(table)
                self._frontend = EventLoopFrontend(
                    [], host=host, port=port, lanes=lanes,
                    dispatcher=self._dispatcher)
            else:
                self._frontend = EventLoopFrontend(self.workers, host=host,
                                                   port=port, lanes=lanes)
        elif self.backend == "threaded":
            self.fabric_workers = 1
            self._frontend = _ThreadedFrontend(self.workers, host, port)
        else:
            raise ValueError(f"unknown frontend backend {self.backend!r} "
                             "(expected 'evloop' or 'threaded')")
        self.host, self.port = self._frontend.host, self._frontend.port

    def start(self) -> "HttpServiceRunner":
        for fe in self._shards:
            fe.start()
        self._frontend.start()
        return self

    def stop(self) -> None:
        self._frontend.stop()
        if self._dispatcher is not None:
            self._dispatcher.close()
        for fe in self._shards:
            fe.stop()
        # durability: no acknowledged mutation may ride only in an OS
        # buffer once the frontend is gone (workers usually share one
        # storage object — flush each distinct one once)
        for storage in {id(w.storage): w.storage for w in self.workers}.values():
            storage.flush()

    def frontend_stats(self) -> dict[str, Any]:
        """Frontend-level counters (lane count, cache hits, ...).

        In fabric mode the public frontend proxies instead of serving, so
        worker-level counters (inline hits, cache hits, per-lane load)
        are aggregated from the shard frontends."""
        stats = self._frontend.stats()
        if self._shards:
            stats["fabric_workers"] = len(self._shards)
            stats["dispatcher"] = self._dispatcher.stats()
            for key in ("requests", "inline_requests", "cache_hits",
                        "cache_entries"):
                stats[key] = stats.get(key, 0) + sum(
                    fe.stats().get(key, 0) for fe in self._shards)
        return stats

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"


class ShardedHttpTransport(Transport):
    """Client-side shard routing: one connection pool per fabric worker.

    Where ``SO_REUSEPORT`` is unavailable the fabric's workers listen on
    private per-worker ports behind the router's proxy; a client that
    knows those endpoints (``ShardFabric.endpoints``) can skip the proxy
    hop entirely by computing the same consistent-hash placement the
    router uses and sending each request straight to the owning worker.
    Keyless requests go to the first endpoint; misrouted requests are
    still correct (every worker runs the dispatcher and forwards one
    hop), just slower.
    """

    def __init__(self, endpoints: list[tuple[str, int]],
                 timeout: float = 30.0, pool_size: int = 2):
        if not endpoints:
            raise ValueError("ShardedHttpTransport needs >= 1 endpoint")
        from .fabric import HashRing, classify_target
        self._classify = classify_target
        self.endpoints = [(h, int(p)) for h, p in endpoints]
        self._ring = HashRing(range(len(self.endpoints)))
        self._pools = [PooledHttpTransport(h, p, timeout=timeout,
                                           pool_size=pool_size)
                       for h, p in self.endpoints]

    def _pool_for(self, method: str, path: str,
                  body: dict[str, Any] | None) -> PooledHttpTransport:
        kind = self._classify(method, path)
        key: str | None = None
        if kind[0] == "key":
            key = kind[1]
        elif kind[0] == "spec":
            from .fabric import _key_from_spec
            key = _key_from_spec(body)
        elif kind[0] == "uid":
            from .fabric import _key_from_uid
            key = _key_from_uid(body)
        if key is None:
            return self._pools[0]
        return self._pools[self._ring.owner(key)]

    def request_full(self, method, path, body=None, headers=None):
        return self._pool_for(method, path, body).request_full(
            method, path, body, headers)

    def close(self) -> None:
        for pool in self._pools:
            pool.close()


# --------------------------------------------------------------------------- #
# HTTP client side
# --------------------------------------------------------------------------- #

# failure modes of an idle keep-alive socket the server closed between
# requests — the only case where resending is known-safe (the request
# never reached the application).  Timeouts and fresh-connection errors
# must surface: the server may already have processed the (non-
# idempotent) ask/tell, and a blind resend would duplicate it.
_STALE_ERRORS = (http.client.RemoteDisconnected,
                 http.client.BadStatusLine,
                 ConnectionResetError, BrokenPipeError)


class _PersistentConnection:
    """One keep-alive connection with stale-socket recovery.

    Not thread-safe — callers (``HttpTransport``'s lock,
    ``PooledHttpTransport``'s checkout queue) guarantee exclusive use.
    """

    def __init__(self, host: str, port: int, timeout: float):
        self.host, self.port, self.timeout = host, int(port), timeout
        self._conn: http.client.HTTPConnection | None = None

    def _exchange(self, method: str, path: str, payload: str | None,
                  headers: dict[str, str] | None) -> FullResult:
        if self._conn is None:
            self._conn = http.client.HTTPConnection(
                self.host, self.port, timeout=self.timeout)
        send_headers = {"Content-Type": "application/json"}
        if headers:
            send_headers.update(headers)
        self._conn.request(method, path, body=payload, headers=send_headers)
        resp = self._conn.getresponse()
        data = resp.read()
        try:
            parsed = json.loads(data) if data else {}
        except json.JSONDecodeError:
            # a proxy error page / crashing server wrote a non-JSON body;
            # surface it as a structured client error, never a raw
            # JSONDecodeError (satellite: 502-style HopaasError)
            from .client import HopaasError
            snippet = data[:120].decode("utf-8", "replace")
            raise HopaasError(
                f"{method} {path} -> {resp.status}: server returned a "
                f"non-JSON body: {snippet!r}", status=502,
                code="bad_upstream_body")
        return resp.status, parsed, {k: v for k, v in resp.getheaders()}

    def roundtrip(self, method: str, path: str, payload: str | None,
                  headers: dict[str, str] | None) -> FullResult:
        reused = self._conn is not None
        try:
            return self._exchange(method, path, payload, headers)
        except _STALE_ERRORS:
            self.close()
            if not reused:
                raise
            # the keep-alive socket died idle: resending is safe
            try:
                return self._exchange(method, path, payload, headers)
            except (http.client.HTTPException, OSError):
                self.close()
                raise
        except (http.client.HTTPException, OSError):
            self.close()
            raise

    def close(self) -> None:
        if self._conn is not None:
            try:
                self._conn.close()
            finally:
                self._conn = None


class HttpTransport(Transport):
    """Client side of the HTTP transport (stdlib http.client).

    Keeps one persistent connection per transport (HTTP/1.1 keep-alive)
    and transparently reconnects once when the socket has gone stale —
    a dropped keep-alive never surfaces to the caller.  Pass
    ``persistent=False`` for the old connection-per-request behavior
    (kept for the benchmark comparison).  Thread-safe, but concurrent
    callers serialize on the single socket — use ``PooledHttpTransport``
    for multi-threaded workers sharing one transport.
    """

    def __init__(self, host: str, port: int, timeout: float = 30.0,
                 persistent: bool = True):
        self.host, self.port, self.timeout = host, int(port), timeout
        self.persistent = bool(persistent)
        self._box = _PersistentConnection(host, int(port), timeout)
        self._lock = threading.Lock()     # the connection is not thread-safe

    @classmethod
    def from_url(cls, url: str, timeout: float = 30.0,
                 persistent: bool = True) -> "HttpTransport":
        host, port = _split_url(url)
        return cls(host, port, timeout, persistent=persistent)

    def request_full(self, method, path, body=None, headers=None):
        # GET carries no body: unread body bytes would corrupt keep-alive
        # framing on servers that don't drain them.
        payload = None if method == "GET" else json.dumps(body or {})
        with self._lock:
            try:
                return self._box.roundtrip(method, path, payload, headers)
            finally:
                if not self.persistent:
                    self._box.close()

    def close(self) -> None:
        with self._lock:
            self._box.close()


class PooledHttpTransport(Transport):
    """A bounded pool of persistent connections (checkout / checkin).

    One ``PooledHttpTransport`` can be shared by many worker threads:
    each request checks a connection out of the pool (blocking when all
    ``pool_size`` sockets are in flight), so concurrent callers use
    distinct sockets instead of serializing on one.  Checked-in sockets
    stay open — the steady state is ``pool_size`` keep-alive
    connections, matching the event-loop frontend's cheap-connection
    model.  Stale-socket recovery is per connection, identical to
    ``HttpTransport``.
    """

    def __init__(self, host: str, port: int, timeout: float = 30.0,
                 pool_size: int = 4):
        if pool_size < 1:
            raise ValueError(f"pool_size must be >= 1, got {pool_size}")
        self.host, self.port, self.timeout = host, int(port), timeout
        self.pool_size = int(pool_size)
        self._closed = False
        # LIFO: reuse the warmest socket first, idle ones age out server-side
        self._pool: queue.LifoQueue = queue.LifoQueue()
        for _ in range(self.pool_size):
            self._pool.put(_PersistentConnection(host, int(port), timeout))

    @classmethod
    def from_url(cls, url: str, timeout: float = 30.0,
                 pool_size: int = 4) -> "PooledHttpTransport":
        host, port = _split_url(url)
        return cls(host, port, timeout, pool_size=pool_size)

    def request_full(self, method, path, body=None, headers=None):
        payload = None if method == "GET" else json.dumps(body or {})
        box = self._pool.get()
        try:
            return box.roundtrip(method, path, payload, headers)
        finally:
            if self._closed:       # closed mid-flight: don't re-pool open
                box.close()
            self._pool.put(box)

    def close(self) -> None:
        """Close every pooled socket.  Idle boxes close here; a box
        checked out mid-request closes on checkin (its response still
        completes first).  The transport keeps working after close(),
        but in connection-per-request mode — nothing persistent can
        outlive a close()."""
        self._closed = True
        drained = []
        while True:
            try:
                drained.append(self._pool.get_nowait())
            except queue.Empty:
                break
        for box in drained:
            box.close()
            self._pool.put(box)


def _split_url(url: str) -> tuple[str, int]:
    url = url.replace("http://", "")
    host, _, port = url.partition(":")
    return host, int(port or 80)
