"""Transports between HOPAAS clients and the service.

* ``DirectTransport``    — in-process function call (fast path for tests
                           and single-host campaigns).
* ``HttpTransport``      — real HTTP over a socket using only the standard
                           library; the server side (``HttpServiceRunner``)
                           mounts ``HopaasServer.handle_request`` behind a
                           threading HTTP server (the Uvicorn role, sec. 3).
* ``ReverseProxy``       — round-robin fan-out to N backend workers
                           sharing one storage (the NGINX role, sec. 3).

All transports carry request *headers* (the v2 surface authenticates via
``Authorization: Bearer``) and pass query strings through untouched, so
``GET /api/v2/studies/{key}/trials?state=completed&limit=50`` works
identically in-process and over the wire.  ``request_full`` additionally
exposes response headers (e.g. the ``Allow`` list on a 405).
"""
from __future__ import annotations

import http.client
import itertools
import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any

from .server import HopaasServer

# (status, payload) / (status, payload, response headers)
Result = tuple[int, dict[str, Any]]
FullResult = tuple[int, dict[str, Any], dict[str, str]]


class Transport:
    def request(self, method: str, path: str,
                body: dict[str, Any] | None = None,
                headers: dict[str, str] | None = None) -> Result:
        return self.request_full(method, path, body, headers)[:2]

    def request_full(self, method: str, path: str,
                     body: dict[str, Any] | None = None,
                     headers: dict[str, str] | None = None) -> FullResult:
        raise NotImplementedError


class DirectTransport(Transport):
    def __init__(self, server: HopaasServer):
        self.server = server

    def request_full(self, method, path, body=None, headers=None):
        return self.server.handle_request(method, path, body, headers)


class RoundRobinTransport(Transport):
    """Client-side round robin across several in-proc workers (used to test
    the shared-storage consistency of horizontally scaled servers)."""

    def __init__(self, servers: list[HopaasServer]):
        self.servers = servers
        self._cycle = itertools.cycle(range(len(servers)))
        self._lock = threading.Lock()

    def request_full(self, method, path, body=None, headers=None):
        with self._lock:
            i = next(self._cycle)
        return self.servers[i].handle_request(method, path, body, headers)


# --------------------------------------------------------------------------- #
# HTTP server side
# --------------------------------------------------------------------------- #
def _make_handler(target):
    class Handler(BaseHTTPRequestHandler):
        # HTTP/1.1 => persistent connections; every response carries an
        # explicit Content-Length so keep-alive framing is unambiguous.
        protocol_version = "HTTP/1.1"

        def log_message(self, *a):   # quiet
            pass

        def _respond(self, status: int, payload: dict[str, Any],
                     extra_headers: dict[str, str] | None = None) -> None:
            blob = json.dumps(payload).encode()
            self.send_response(status)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(blob)))
            for k, v in (extra_headers or {}).items():
                self.send_header(k, v)
            self.end_headers()
            self.wfile.write(blob)

        def _read_body(self) -> tuple[Any, str | None]:
            """(parsed JSON, parse-error message).  Always drains the
            socket so keep-alive framing survives a bad body."""
            n = int(self.headers.get("Content-Length", 0) or 0)
            raw = self.rfile.read(n) if n else b""
            if not raw:
                return None, None
            try:
                return json.loads(raw), None
            except json.JSONDecodeError as e:
                return None, f"request body is not valid JSON: {e.msg}"

        def _dispatch(self, method: str, body: Any,
                      body_error: str | None) -> None:
            self._respond(*target(self.path, method, body,
                                  dict(self.headers), body_error))

        def do_GET(self):
            self._read_body()    # drain any body; GET bodies are ignored
            self._dispatch("GET", None, None)

        def do_POST(self):
            body, err = self._read_body()
            self._dispatch("POST", body, err)

    return Handler


class HttpServiceRunner:
    """Hosts one or more HopaasServer workers behind a threaded HTTP server.

    With ``n_workers > 1`` requests round-robin across worker instances that
    share one storage — the paper's Uvicorn×N + PostgreSQL deployment shape.
    """

    def __init__(self, server: HopaasServer | list[HopaasServer], host: str = "127.0.0.1",
                 port: int = 0):
        self.workers = server if isinstance(server, list) else [server]
        self._cycle = itertools.cycle(range(len(self.workers)))
        self._lock = threading.Lock()
        self.httpd = ThreadingHTTPServer((host, port), _make_handler(
            lambda path, method, body, headers, body_error:
                self._pick().handle_request(method, path, body, headers,
                                            body_error)))
        self.host, self.port = self.httpd.server_address[:2]
        self._thread = threading.Thread(target=self.httpd.serve_forever, daemon=True)

    def _pick(self) -> HopaasServer:
        with self._lock:
            return self.workers[next(self._cycle)]

    def start(self) -> "HttpServiceRunner":
        self._thread.start()
        return self

    def stop(self) -> None:
        self.httpd.shutdown()
        self.httpd.server_close()
        # durability: no acknowledged mutation may ride only in an OS
        # buffer once the frontend is gone (workers usually share one
        # storage object — flush each distinct one once)
        for storage in {id(w.storage): w.storage for w in self.workers}.values():
            storage.flush()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"


class HttpTransport(Transport):
    """Client side of the HTTP transport (stdlib http.client).

    Keeps one persistent connection per transport (HTTP/1.1 keep-alive)
    and transparently reconnects once when the socket has gone stale —
    a dropped keep-alive never surfaces to the caller.  Pass
    ``persistent=False`` for the old connection-per-request behavior
    (kept for the benchmark comparison).
    """

    def __init__(self, host: str, port: int, timeout: float = 30.0,
                 persistent: bool = True):
        self.host, self.port, self.timeout = host, int(port), timeout
        self.persistent = bool(persistent)
        self._conn: http.client.HTTPConnection | None = None
        self._lock = threading.Lock()     # the connection is not thread-safe

    @classmethod
    def from_url(cls, url: str, timeout: float = 30.0,
                 persistent: bool = True) -> "HttpTransport":
        url = url.replace("http://", "")
        host, _, port = url.partition(":")
        return cls(host, int(port or 80), timeout, persistent=persistent)

    def _exchange(self, method: str, path: str, payload: str | None,
                  headers: dict[str, str] | None) -> FullResult:
        if self._conn is None:
            self._conn = http.client.HTTPConnection(
                self.host, self.port, timeout=self.timeout)
        send_headers = {"Content-Type": "application/json"}
        if headers:
            send_headers.update(headers)
        self._conn.request(method, path, body=payload, headers=send_headers)
        resp = self._conn.getresponse()
        data = resp.read()
        return (resp.status, json.loads(data or b"{}"),
                {k: v for k, v in resp.getheaders()})

    # failure modes of an idle keep-alive socket the server closed between
    # requests — the only case where resending is known-safe (the request
    # never reached the application).  Timeouts and fresh-connection errors
    # must surface: the server may already have processed the (non-
    # idempotent) ask/tell, and a blind resend would duplicate it.
    _STALE_ERRORS = (http.client.RemoteDisconnected,
                     http.client.BadStatusLine,
                     ConnectionResetError, BrokenPipeError)

    def request_full(self, method, path, body=None, headers=None):
        # GET carries no body: unread body bytes would corrupt keep-alive
        # framing on servers that don't drain them.
        payload = None if method == "GET" else json.dumps(body or {})
        with self._lock:
            reused = self._conn is not None
            try:
                try:
                    return self._exchange(method, path, payload, headers)
                except self._STALE_ERRORS:
                    self._close_conn()
                    if not reused:
                        raise
                    try:
                        return self._exchange(method, path, payload, headers)
                    except (http.client.HTTPException, OSError):
                        self._close_conn()
                        raise
                except (http.client.HTTPException, OSError):
                    self._close_conn()
                    raise
            finally:
                if not self.persistent:
                    self._close_conn()

    def _close_conn(self) -> None:
        if self._conn is not None:
            try:
                self._conn.close()
            finally:
                self._conn = None

    def close(self) -> None:
        with self._lock:
            self._close_conn()
