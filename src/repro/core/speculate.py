"""Off-lock speculative proposal precompute — the ask-dequeue pipeline.

At high parallelism the sampler itself becomes the ask bottleneck:
every proposal runs under the study's shard lock, so N contended
workers serialize on KDE/GP compute and (being blind to each other)
get near-identical points.  The constant-liar pending view in
``ObservationCache`` fixes the blindness; this module takes the compute
off the hot path:

* ``SpeculativeQueue`` — per-study buffers of precomputed proposals,
  each tagged with the storage ``version`` it was computed against.
  There is a single background writer per server (CAS-publish: an
  older compute can never land above a newer buffer; same-age rounds
  merge, newer rounds stack on top of the previous round's leftovers)
  and many foreground drainers (``op_ask`` under the shard lock).
  Draining serves newest-first under a staleness policy: an
  exact-version proposal is a *hit* (zero sampler compute on the ask
  path), one within the staleness bound is a *stale hit* (acceptable —
  the liar rows already anticipated the in-flight trials that bumped
  the version), and anything older is dropped and counted as a *miss*
  (the ask falls back to inline sampling; it never blocks on the
  precompute thread).

* ``SpeculativeWorker`` — one daemon thread per server that owns the
  precompute loop.  Request handlers mark studies dirty via
  ``notify()`` (after a tell/prune/drain bumped the version); the
  worker snapshots the study's cache *under* the shard lock (cheap:
  copies of memoized buffers), releases it, runs the sampler's batched
  constant-liar proposal against the frozen snapshot entirely off-lock,
  and CAS-publishes the result.

Correctness: the queue holds only *parameter dicts* — draining one
registers it through the exact same journaled ``add_trial`` as an
inline proposal, so no study state is ever mutated off-WAL and
``state_digest()`` is identical across a crash/recovery mid-speculation
(the queue is a cache; it simply restarts empty).

Locking: the queue has its own mutex, only ever taken *after* the shard
lock (drain path) or with no other lock held (publish path); the worker
takes the shard lock only for the snapshot and never while holding its
own condition — the lock graph stays acyclic.
"""
from __future__ import annotations

import logging
import threading
from typing import Any, Callable

logger = logging.getLogger(__name__)


class _Buffer:
    __slots__ = ("version", "proposals")

    def __init__(self, version: int, proposals: list[dict[str, Any]]):
        self.version = version
        self.proposals = proposals


class SpeculativeQueue:
    """Version-tagged proposal buffers for one study on one server.

    Buffers are kept oldest-first; a publish *appends* rather than
    replacing, so the leftovers of the previous round stay drainable
    until they age past the staleness bound (under a contended fleet
    the request path consumes proposals while the next round is still
    computing — clobbering the remainder would waste most of the
    supply).  ``take`` serves from the newest acceptable buffer and
    lazily evicts anything older than the bound."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._bufs: list[_Buffer] = []       # version-ascending
        self.hits = 0          # drained at the exact computed version
        self.stale_hits = 0    # drained within the staleness bound
        self.misses = 0        # empty / too stale -> inline fallback
        self.published = 0     # buffers the precompute worker landed
        self.rejected = 0      # CAS losses (stale compute vs newer buffer)
        self.discarded = 0     # proposals dropped as too stale

    def publish(self, version: int,
                proposals: list[dict[str, Any]]) -> bool:
        """CAS-publish a freshly computed buffer.  Returns False (and
        keeps the current buffers) when a newer compute already landed —
        the precompute races the request path for the version counter,
        never the other way around.  Same-version publishes merge."""
        version = int(version)
        with self._lock:
            if self._bufs and self._bufs[-1].version > version:
                self.rejected += 1
                return False
            if self._bufs and self._bufs[-1].version == version:
                self._bufs[-1].proposals.extend(proposals)
            else:
                self._bufs.append(_Buffer(version, list(proposals)))
            self.published += 1
            return True

    def take(self, current_version: int,
             max_staleness: int) -> dict[str, Any] | None:
        """Pop one proposal under the staleness policy, or None (miss).
        Caller holds the shard lock, so ``current_version`` is stable
        for the duration of its ask."""
        with self._lock:
            while self._bufs:
                buf = self._bufs[-1]
                age = current_version - buf.version
                if age < 0 or not buf.proposals:
                    # future-versioned (rolled-back storage) or drained
                    self.discarded += len(buf.proposals)
                    self._bufs.pop()
                    continue
                if age > max_staleness:
                    # newest is already too old -> everything below is
                    for b in self._bufs:
                        self.discarded += len(b.proposals)
                    self._bufs.clear()
                    break
                params = buf.proposals.pop()
                if not buf.proposals:
                    self._bufs.pop()
                if age == 0:
                    self.hits += 1
                else:
                    self.stale_hits += 1
                return params
            self.misses += 1
            return None

    def depth(self) -> int:
        with self._lock:
            return sum(len(b.proposals) for b in self._bufs)

    def stats(self) -> dict[str, int]:
        with self._lock:
            queued = sum(len(b.proposals) for b in self._bufs)
            return {"hits": self.hits, "stale_hits": self.stale_hits,
                    "misses": self.misses, "published": self.published,
                    "rejected": self.rejected,
                    "discarded": self.discarded, "queued": queued}


class SpeculativeWorker:
    """Background precompute loop: one daemon thread per server.

    Not a ``threading.Thread`` subclass on purpose — the thread object
    is an implementation detail, and the public surface (``notify`` /
    ``stop`` / ``stats``) is what request handlers touch.  All shared
    fields are guarded by the condition's lock.
    """

    def __init__(self, precompute: Callable[[str], None],
                 name: str = "speculate") -> None:
        self._precompute = precompute
        self._cond = threading.Condition()
        self._dirty: set[str] = set()
        self._stopped = False
        self._rounds = 0
        self._errors = 0
        self._thread = threading.Thread(target=self._run, name=name,
                                        daemon=True)
        self._thread.start()

    def notify(self, study_key: str) -> None:
        """Mark a study's proposal buffer stale (tell/prune/drain landed).
        Cheap and idempotent — the dirty set dedups bursts."""
        with self._cond:
            self._dirty.add(study_key)
            self._cond.notify()

    def stop(self, timeout: float = 5.0) -> None:
        with self._cond:
            self._stopped = True
            self._cond.notify()
        self._thread.join(timeout=timeout)

    def stats(self) -> dict[str, int]:
        with self._cond:
            return {"rounds": self._rounds, "errors": self._errors,
                    "dirty": len(self._dirty)}

    def _run(self) -> None:
        while True:
            with self._cond:
                while not self._dirty and not self._stopped:
                    self._cond.wait()
                if self._stopped:
                    return
                key = self._dirty.pop()
            # compute outside the condition: notify() must never block
            # behind a sampler evaluation
            try:
                self._precompute(key)
            except Exception:
                logger.exception("speculative precompute failed for "
                                 "study %s", key)
                with self._cond:
                    self._errors += 1
                continue
            with self._cond:
                self._rounds += 1
