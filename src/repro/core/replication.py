"""Shard replication: WAL shipping from a leader to follower workers.

The paper's deployment delegates durability *and* availability to a
managed PostgreSQL instance; PR 4 rebuilt the durability half (snapshots
+ segmented WAL), PR 6 the horizontal half (the shard fabric).  This
module closes the gap to the availability half: every durable fabric
worker publishes its WAL stream to a **replication hub**, and follower
workers subscribe with a **replication client** that continuously
replays the stream into their own journaled store.  When the fabric
monitor declares a leader dead, the most-caught-up follower already
holds a byte-respecting replica and can be promoted in milliseconds
(see ``fabric.ShardFabric``).

Protocol (length-prefixed JSON frames, one TCP connection per follower):

* hub -> ``{"t": "welcome", "session": <nonce>}`` — the session nonce
  identifies one hub *process lifetime*; stream positions are only
  meaningful within a session, so a follower that sees a new nonce
  resets to position 0 and takes a fresh baseline.
* follower -> ``{"t": "hello", "follower": id, "pos": N}`` — resume
  point: the last position this follower applied.
* hub -> ``{"t": "baseline", "pos", "covers", "snapshot",
  "snapshot_sha", "segments": [{"text", "sha"}, ...]}`` — the leader's
  immutable files (snapshot + sealed segments, exactly what compaction
  reads) captured atomically with the stream position ``pos``.  Sent
  when the follower is fresh or has fallen off the in-memory tail.
* hub -> ``{"t": "rec", "pos", "line", "crc"}`` — one WAL record,
  published under the leader's journal lock so stream order equals file
  order.
* follower -> ``{"t": "ack", "pos": N}`` — cumulative; drives both the
  hub's lag accounting and semisync ``wait_ack``.

Everything shipped is verified before it is applied: baselines by
per-artifact SHA-256, records by CRC-32 and position contiguity.  A
payload that fails verification is *never* applied — the follower drops
the connection and reconnects at its last good position, which makes
the hub re-ship the lost range (the retry is the re-request).  The
``torn_ship`` fault-injection point corrupts hub sends in flight to
prove exactly that path.

``recover_dir_state`` and ``reconcile_with`` are the promotion helpers:
read a dead leader's WAL directory without mutating it, then bring the
follower's journaled store to that exact state through journaled
drop/adopt operations (digest-verified).
"""
from __future__ import annotations

import hashlib
import json
import logging
import os
import socket
import struct
import threading
import time
import zlib
from collections import deque
from typing import Any

from . import faults
from .aio import open_server_socket
from .durable import _SEG_RE, _SNAP_RE
from .storage import InMemoryStorage, load_journal_file

logger = logging.getLogger("repro.replication")

_HEADER = struct.Struct(">I")
MAX_FRAME = 1 << 30              # a baseline carries whole snapshots
_BATCH = 256                     # records shipped per cv wakeup


class ReplicationError(RuntimeError):
    """Protocol violation on the replication stream."""


class _Rejected(ReplicationError):
    """A shipped payload failed checksum/digest verification — it must
    not be applied; the connection is dropped so the hub re-ships."""


class _Disconnect(Exception):
    """Deliberately sever this connection (fault injection)."""


# ---------------------------------------------------------------------- #
# framing
# ---------------------------------------------------------------------- #
def _recv_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    remaining = n
    while remaining:
        chunk = sock.recv(min(65536, remaining))
        if not chunk:
            raise ConnectionError("replication peer closed mid-frame")
        chunks.append(chunk)
        remaining -= len(chunk)
    return b"".join(chunks)


def recv_frame(sock: socket.socket) -> dict[str, Any]:
    (size,) = _HEADER.unpack(_recv_exact(sock, _HEADER.size))
    if size > MAX_FRAME:
        raise ReplicationError(f"oversized replication frame ({size} bytes)")
    return json.loads(_recv_exact(sock, size).decode())


def send_frame(sock: socket.socket, obj: dict[str, Any]) -> None:
    payload = json.dumps(obj, allow_nan=False).encode()
    sock.sendall(_HEADER.pack(len(payload)) + payload)


def _sha(text: str) -> str:
    return hashlib.sha256(text.encode()).hexdigest()


class _Follower:
    """Hub-side view of one subscribed follower connection."""

    __slots__ = ("id", "sock", "acked", "alive")

    def __init__(self, follower_id: str, sock: socket.socket):
        self.id = follower_id
        self.sock = sock
        self.acked = 0
        self.alive = True


class ReplicationHub:
    """Leader side: publish the WAL stream, serve baselines, track acks.

    ``publish`` is called by ``DurableStorage._log`` *under the journal
    lock*, so stream position order is exactly file order.  It only
    appends to an in-memory tail and notifies — never blocks on I/O or
    followers.  Per-connection sender threads drain the tail; when a
    follower's resume point has fallen off the tail (or it is fresh),
    the sender ships a baseline captured by
    ``storage.replication_baseline()`` instead.

    ``wait_ack(pos)`` is the semisync hook: true once *any* live
    follower has acknowledged ``pos``.  With no follower connected it
    degrades to async immediately (counted in ``semisync_degraded``) —
    replication must never deadlock a single-process deployment.
    """

    def __init__(self, storage, *, host: str = "127.0.0.1", port: int = 0,
                 tail_records: int = 8192, ack_timeout: float = 2.0):
        self.storage = storage
        self.session = os.urandom(8).hex()
        self.ack_timeout = float(ack_timeout)
        self.tail_records = max(16, int(tail_records))
        self._cv = threading.Condition()
        self._pos = 0
        self._bytes = 0
        # (pos, line, cumulative bytes incl. this record), contiguous
        self._tail: deque[tuple[int, str, int]] = deque()
        self._followers: dict[str, _Follower] = {}
        self._stopped = threading.Event()
        self.baselines_shipped = 0
        self.semisync_degraded = 0
        self._sock = open_server_socket(host, port, blocking=True)
        self.port = self._sock.getsockname()[1]
        self._accept_thread = threading.Thread(
            target=self._accept_loop, daemon=True, name="repl-hub-accept")
        self._accept_thread.start()

    # -- publishing (leader write path) ----------------------------------
    def publish(self, line: str) -> int:
        """Append one WAL record to the stream; returns its position.
        Called under the storage's journal lock — O(1), no I/O."""
        with self._cv:
            self._pos += 1
            self._bytes += len(line) + 1
            self._tail.append((self._pos, line, self._bytes))
            while len(self._tail) > self.tail_records:
                self._tail.popleft()
            self._cv.notify_all()
            return self._pos

    def position(self) -> int:
        with self._cv:
            return self._pos

    def wait_ack(self, pos: int, timeout: float | None = None) -> bool:
        """Semisync: block until a live follower acknowledges ``pos``.
        True immediately when no follower is connected (degraded to
        async rather than wedging writes); False on timeout."""
        deadline = time.monotonic() + (self.ack_timeout if timeout is None
                                       else timeout)
        with self._cv:
            while True:
                live = [f for f in self._followers.values() if f.alive]
                if not live:
                    return True
                if any(f.acked >= pos for f in live):
                    return True
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    self.semisync_degraded += 1
                    return False
                self._cv.wait(remaining)

    # -- serving followers ------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._stopped.is_set():
            try:
                sock, _ = self._sock.accept()
            except OSError:
                return
            threading.Thread(target=self._serve, args=(sock,), daemon=True,
                             name="repl-hub-serve").start()

    def _ship(self, sock: socket.socket, obj: dict[str, Any]) -> None:
        """Frame + send, routed through the ``torn_ship`` injection point
        for data frames.  The length header is always computed from the
        *original* payload, so a torn mangle leaves the follower short —
        severing the connection afterwards turns that into the partial
        send a real network fault would produce."""
        payload = json.dumps(obj, allow_nan=False).encode()
        wire = payload
        if obj.get("t") in ("baseline", "rec"):
            wire = faults.mangle("torn_ship", payload)
        sock.sendall(_HEADER.pack(len(payload)) + wire)
        if wire != payload:
            raise _Disconnect()

    def _serve(self, sock: socket.socket) -> None:
        fol: _Follower | None = None
        try:
            self._ship(sock, {"t": "welcome", "session": self.session})
            hello = recv_frame(sock)
            if hello.get("t") != "hello":
                raise ReplicationError("expected hello frame")
            fol = _Follower(str(hello.get("follower", "?")), sock)
            with self._cv:
                stale = self._followers.get(fol.id)
                if stale is not None:            # reconnect supersedes
                    stale.alive = False
                    try:
                        stale.sock.close()
                    except OSError:
                        pass
                self._followers[fol.id] = fol
                self._cv.notify_all()
            threading.Thread(target=self._ack_loop, args=(fol,), daemon=True,
                             name=f"repl-hub-ack-{fol.id}").start()
            cursor = int(hello.get("pos", 0))
            shipped_baseline = False
            while not self._stopped.is_set() and fol.alive:
                with self._cv:
                    pos = self._pos
                    tail_start = self._tail[0][0] if self._tail else pos + 1
                if ((cursor == 0 and not shipped_baseline)
                        or (cursor < pos and cursor + 1 < tail_start)):
                    # fresh follower, or its resume point fell off the
                    # tail: ship the leader's immutable files wholesale.
                    # The flag matters on an idle leader: with pos still 0
                    # the baseline leaves cursor at 0, and without it this
                    # branch refires forever, busy-shipping empty baselines
                    base = self.storage.replication_baseline()
                    self._ship(sock, {
                        "t": "baseline", "pos": base["pos"],
                        "covers": base["covers"],
                        "snapshot": base["snapshot"],
                        "snapshot_sha": (None if base["snapshot"] is None
                                         else _sha(base["snapshot"])),
                        "segments": [{"text": s, "sha": _sha(s)}
                                     for s in base["segments"]],
                    })
                    with self._cv:
                        self.baselines_shipped += 1
                    cursor = base["pos"]
                    shipped_baseline = True
                    continue
                batch: list[tuple[int, str]] = []
                with self._cv:
                    while (self._pos <= cursor and fol.alive
                           and not self._stopped.is_set()):
                        self._cv.wait(0.5)
                    if self._stopped.is_set() or not fol.alive:
                        return
                    tail_start = (self._tail[0][0] if self._tail
                                  else self._pos + 1)
                    if cursor + 1 >= tail_start:
                        start = cursor + 1 - tail_start
                        batch = [(p, line) for p, line, _ in
                                 list(self._tail)[start:start + _BATCH]]
                for p, line in batch:
                    self._ship(sock, {"t": "rec", "pos": p, "line": line,
                                      "crc": zlib.crc32(line.encode())})
                    cursor = p
        except (_Disconnect, ReplicationError, ConnectionError, OSError,
                json.JSONDecodeError, struct.error):
            pass
        finally:
            if fol is not None:
                with self._cv:
                    fol.alive = False
                    self._cv.notify_all()
            try:
                sock.close()
            except OSError:
                pass

    def _ack_loop(self, fol: _Follower) -> None:
        try:
            while fol.alive:
                msg = recv_frame(fol.sock)
                if msg.get("t") == "ack":
                    with self._cv:
                        fol.acked = max(fol.acked, int(msg["pos"]))
                        self._cv.notify_all()
        except (ReplicationError, ConnectionError, OSError,
                json.JSONDecodeError, struct.error):
            pass
        finally:
            with self._cv:
                fol.alive = False
                self._cv.notify_all()
            try:
                fol.sock.close()
            except OSError:
                pass

    # -- observability ----------------------------------------------------
    def _bytes_behind_locked(self, acked: int) -> int:
        if acked >= self._pos:
            return 0
        for p, _, cum in self._tail:
            if p == acked:
                return self._bytes - cum
        return self._bytes          # beyond the tail: bound by the total

    def status(self) -> dict[str, Any]:
        with self._cv:
            followers = [
                {"id": f.id, "connected": f.alive, "acked": f.acked,
                 "lag_records": self._pos - f.acked,
                 "lag_bytes": self._bytes_behind_locked(f.acked)}
                for f in self._followers.values()]
            return {"session": self.session, "port": self.port,
                    "pos": self._pos, "bytes": self._bytes,
                    "followers": followers,
                    "baselines_shipped": self.baselines_shipped,
                    "semisync_degraded": self.semisync_degraded}

    def stop(self) -> None:
        self._stopped.set()
        try:
            # close() alone does not wake a thread blocked in accept();
            # shutdown() does, so the listener actually leaves LISTEN and
            # a restarted hub can rebind the port immediately
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass
        self._accept_thread.join(timeout=2.0)
        with self._cv:
            fols = list(self._followers.values())
            for f in fols:
                f.alive = False
            self._cv.notify_all()
        for f in fols:
            try:
                f.sock.close()
            except OSError:
                pass


class ReplicationClient:
    """Follower side: subscribe to a leader hub and replay its stream
    into the local (journaled) store via ``storage.apply_replicated``.

    Runs a single daemon thread that reconnects forever with a short
    backoff; every disconnect — network fault, verification failure,
    injected partition — resumes from the last *applied* position, so a
    corrupt shipped payload is simply shipped again.  ``status()``
    exposes position, baseline/reject/resync counters, and the last
    error for the health endpoint.
    """

    def __init__(self, storage, leader: tuple[str, int], *,
                 follower_id: str = "follower-0",
                 retry_interval: float = 0.05):
        self.storage = storage
        self.leader = (leader[0], int(leader[1]))
        self.follower_id = follower_id
        self.retry_interval = float(retry_interval)
        # Session/progress fields below follow a single-writer discipline:
        # only the client thread (_run) mutates them.  status()/position()
        # read them lock-free for observability — GIL-atomic loads whose
        # staleness is bounded by one poll interval, and failover
        # re-verifies actual state by digest before serving.
        self._session: str | None = None  # repro-check: allow(shared-state)
        self._pos = 0  # repro-check: allow(shared-state)
        # threading.Event is internally synchronized and never rebound
        self._connected = threading.Event()  # repro-check: allow(shared-state)
        self._stopped = threading.Event()
        # single-writer; stop() snapshots the reference only to interrupt
        # a blocking recv — a missed swap just waits out the socket timeout
        self._sock: socket.socket | None = None  # repro-check: allow(shared-state)
        self.baselines = 0  # repro-check: allow(shared-state)
        self.rejects = 0  # repro-check: allow(shared-state)
        self.resyncs = 0  # repro-check: allow(shared-state)
        self.records_applied = 0  # repro-check: allow(shared-state)
        self.last_error: str | None = None  # repro-check: allow(shared-state)
        self._thread = threading.Thread(
            target=self._run, daemon=True,
            name=f"repl-client-{follower_id}")

    def start(self) -> "ReplicationClient":
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stopped.set()
        sock = self._sock
        if sock is not None:
            try:
                sock.close()
            except OSError:
                pass
        if self._thread.is_alive():
            self._thread.join(timeout=5.0)

    # -- observability / test hooks --------------------------------------
    def position(self) -> int:
        return self._pos

    def connected(self) -> bool:
        return self._connected.is_set()

    def wait_connected(self, timeout: float = 10.0) -> bool:
        return self._connected.wait(timeout)

    def wait_position(self, pos: int, timeout: float = 10.0) -> bool:
        deadline = time.monotonic() + timeout
        while self._pos < pos and time.monotonic() < deadline:
            time.sleep(0.005)
        return self._pos >= pos

    def status(self) -> dict[str, Any]:
        return {"follower": self.follower_id,
                "connected": self._connected.is_set(),
                "leader": list(self.leader), "pos": self._pos,
                "session": self._session, "baselines": self.baselines,
                "rejects": self.rejects, "resyncs": self.resyncs,
                "records_applied": self.records_applied,
                "last_error": self.last_error}

    # -- sync loop --------------------------------------------------------
    def _run(self) -> None:
        while not self._stopped.is_set():
            try:
                self._sync_once()
            except _Rejected as e:
                self.rejects += 1
                self.last_error = str(e)
            except (ReplicationError, ConnectionError, OSError,
                    json.JSONDecodeError, struct.error) as e:
                self.last_error = f"{type(e).__name__}: {e}"
            finally:
                self._connected.clear()
            self._stopped.wait(self.retry_interval)

    def _sync_once(self) -> None:
        if faults.fire("partition_follower"):
            raise ConnectionError("injected follower partition")
        sock = socket.create_connection(self.leader, timeout=10.0)
        if sock.getsockname() == sock.getpeername():
            # TCP simultaneous-open: reconnecting to a dead leader's
            # ephemeral port can self-connect (source port == destination
            # port), which both wedges this loop and squats the port the
            # restarted hub needs to rebind
            sock.close()
            raise ConnectionError("self-connect (leader not listening)")
        self._sock = sock
        try:
            welcome = recv_frame(sock)
            if welcome.get("t") != "welcome":
                raise ReplicationError("expected welcome frame")
            if welcome.get("session") != self._session:
                # a new hub process: positions from the old session are
                # meaningless, so restart from a fresh baseline
                if self._session is not None:
                    self.resyncs += 1
                self._session = welcome.get("session")
                self._pos = 0
            send_frame(sock, {"t": "hello", "follower": self.follower_id,
                              "pos": self._pos})
            self._connected.set()
            while not self._stopped.is_set():
                frame = recv_frame(sock)
                t = frame.get("t")
                if t == "baseline":
                    self._apply_baseline(frame)
                elif t == "rec":
                    self._apply_rec(frame)
                else:
                    raise ReplicationError(f"unknown frame type {t!r}")
                send_frame(sock, {"t": "ack", "pos": self._pos})
        finally:
            self._sock = None
            try:
                sock.close()
            except OSError:
                pass

    def _apply_baseline(self, frame: dict[str, Any]) -> None:
        """Verify *everything* before touching local state: a baseline
        is adopted whole or not at all."""
        snap = frame.get("snapshot")
        if snap is not None and _sha(snap) != frame.get("snapshot_sha"):
            raise _Rejected("shipped snapshot failed checksum verification")
        segments = frame.get("segments", [])
        for seg in segments:
            if _sha(seg["text"]) != seg.get("sha"):
                raise _Rejected("shipped segment failed checksum verification")
        for key in [s.key for s in self.storage.studies()]:
            self.storage.drop_shard(key)
        if snap is not None:
            for srec in json.loads(snap)["state"]["studies"]:
                self.storage.apply_replicated(
                    {"op": "adopt_shard", "key": srec["key"], "shard": srec})
        for seg in segments:
            for line in seg["text"].splitlines():
                line = line.strip()
                if line:
                    self.storage.apply_replicated(json.loads(line))
        self._pos = int(frame["pos"])
        self.baselines += 1

    def _apply_rec(self, frame: dict[str, Any]) -> None:
        pos = int(frame["pos"])
        line = frame["line"]
        if zlib.crc32(line.encode()) != frame.get("crc"):
            raise _Rejected(f"record {pos} failed crc verification")
        if pos <= self._pos:
            return                   # duplicate after a reconnect race
        if pos != self._pos + 1:
            self.resyncs += 1
            raise ReplicationError(
                f"gap in replication stream: have {self._pos}, got {pos}")
        self.storage.apply_replicated(json.loads(line))
        self._pos = pos
        self.records_applied += 1


# ---------------------------------------------------------------------- #
# promotion helpers
# ---------------------------------------------------------------------- #
def recover_dir_state(root: str) -> tuple[InMemoryStorage, dict[str, Any]]:
    """Read-only recovery of a WAL directory: newest snapshot + segment
    tail replayed into a fresh in-memory store, *without* repairing or
    deleting anything (the directory may belong to a dead process whose
    page cache the kernel is still flushing; promotion only needs to
    *read* the authoritative state, never to own the directory)."""
    t0 = time.perf_counter()
    names = os.listdir(root)
    snaps = sorted(int(m.group(1)) for name in names
                   if (m := _SNAP_RE.fullmatch(name)))
    covers = snaps[-1] if snaps else 0
    store = InMemoryStorage()
    if covers:
        with open(os.path.join(root, f"snapshot-{covers:08d}.json"),
                  "rb") as f:
            store.load_state(json.load(f)["state"])
    segments = sorted(int(m.group(1)) for name in names
                      if (m := _SEG_RE.fullmatch(name)))
    tail = [i for i in segments if i > covers]
    replayed, torn = 0, False
    store._replaying = True
    try:
        for j, index in enumerate(tail):
            n, t = load_journal_file(
                os.path.join(root, f"wal-{index:08d}.jsonl"), store._apply,
                # only the final (active-at-death) segment may be torn
                tolerate_torn_tail=(j == len(tail) - 1), repair=False)
            replayed += n
            torn = torn or t
    finally:
        store._replaying = False
    meta = {"snapshot_covers": covers, "segments_replayed": len(tail),
            "records_replayed": replayed, "torn_tail": torn,
            "seconds": round(time.perf_counter() - t0, 6)}
    return store, meta


def reconcile_with(storage: InMemoryStorage,
                   authority: InMemoryStorage) -> dict[str, Any]:
    """Bring ``storage`` to the exact logical state of ``authority``
    through *journaled* per-shard drop/adopt operations, so the result
    both matches the authority now and recovers to the same state later.
    Shards whose digests already match are left untouched (the common
    case for a caught-up follower).  Returns counters plus the final
    whole-store ``digest_match`` witness."""
    want = {s.key for s in authority.studies()}
    have = {s.key for s in storage.studies()}
    dropped = adopted = 0
    for key in sorted(have - want):
        storage.drop_shard(key)
        dropped += 1
    for key in sorted(want):
        if key in have:
            if storage.shard_digest(key) == authority.shard_digest(key):
                continue
            storage.drop_shard(key)
            dropped += 1
        storage.adopt_shard(authority.shard_record(key))
        adopted += 1
    return {"dropped": dropped, "adopted": adopted,
            "digest_match": storage.state_digest() == authority.state_digest()}
