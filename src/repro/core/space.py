"""Hyperparameter search-space specification.

Spaces are JSON-serializable (they travel in the body of `ask` requests,
paper sec. 2) and support an internal mapping to the unit hypercube, which
is what the numeric samplers (TPE / GP / CMA-ES) operate on.

Spec grammar (the ``properties`` dict of a study):
    {"lr":     {"type": "loguniform", "low": 1e-5, "high": 1e-1},
     "layers": {"type": "int", "low": 1, "high": 8},
     "act":    {"type": "categorical", "choices": ["relu", "gelu"]},
     "dropout":{"type": "uniform", "low": 0.0, "high": 0.5}}
Plain scalars (int/float/str/bool) are passed through as constants, which
lets a client pin some properties while scanning others.

The unit-cube codec is vectorized: ``SearchSpace`` precomputes per-dim
``low/high/log/kind`` arrays at construction so that featurizing or
decoding k points (``to_unit_matrix`` / ``from_unit_matrix``) is one
batched numpy expression per dimension instead of k*D scalar Python calls
with per-element ``math.log``.  The scalar ``Param.to_unit``/``from_unit``
are kept as the per-kind reference implementation.

Categoricals map to the unit interval with equal-width bins: choice ``i``
of ``n`` encodes to the bin center ``(i + 0.5) / n`` and ``u`` decodes to
``min(floor(u * n), n - 1)``, so uniformly drawn candidates weight every
choice equally (the previous ``round(u * (n - 1))`` binning gave the two
edge choices half-width bins).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import numpy as np


@dataclasses.dataclass(frozen=True)
class Param:
    """One dimension of the search space."""

    name: str
    kind: str                      # uniform | loguniform | int | logint | categorical | const
    low: float = 0.0
    high: float = 1.0
    choices: tuple = ()
    value: Any = None              # for const

    # ---- unit-cube mapping (used by TPE/GP/CMA-ES) -------------------
    def to_unit(self, v: Any) -> float:
        if self.kind == "uniform":
            return (float(v) - self.low) / (self.high - self.low)
        if self.kind == "loguniform":
            return (math.log(float(v)) - math.log(self.low)) / (
                math.log(self.high) - math.log(self.low))
        if self.kind == "int":
            return (float(v) - self.low) / max(self.high - self.low, 1e-12)
        if self.kind == "logint":
            return (math.log(float(v)) - math.log(self.low)) / (
                math.log(self.high) - math.log(self.low))
        if self.kind == "categorical":
            # inverse of the equal-width binning below: the bin center
            return (self.choices.index(v) + 0.5) / len(self.choices)
        return 0.0  # const

    def from_unit(self, u: float) -> Any:
        u = min(max(float(u), 0.0), 1.0)
        if self.kind == "uniform":
            return self.low + u * (self.high - self.low)
        if self.kind == "loguniform":
            return math.exp(math.log(self.low) + u * (math.log(self.high) - math.log(self.low)))
        if self.kind == "int":
            return int(round(self.low + u * (self.high - self.low)))
        if self.kind == "logint":
            return int(round(math.exp(
                math.log(self.low) + u * (math.log(self.high) - math.log(self.low)))))
        if self.kind == "categorical":
            # equal-width bins: every choice owns a 1/n slice of [0, 1)
            n = len(self.choices)
            return self.choices[min(int(u * n), n - 1)]
        return self.value  # const

    @property
    def n_categories(self) -> int:
        return len(self.choices) if self.kind == "categorical" else 0

    @property
    def is_searchable(self) -> bool:
        return self.kind != "const"

    # ---- (de)serialization -------------------------------------------
    def to_spec(self) -> Any:
        if self.kind == "const":
            return self.value
        d: dict[str, Any] = {"type": self.kind}
        if self.kind == "categorical":
            d["choices"] = list(self.choices)
        else:
            d["low"], d["high"] = self.low, self.high
        return d

    @classmethod
    def from_spec(cls, name: str, spec: Any) -> "Param":
        if not isinstance(spec, dict) or "type" not in spec:
            return cls(name=name, kind="const", value=spec)
        kind = spec["type"]
        if kind == "categorical":
            return cls(name=name, kind=kind, choices=tuple(spec["choices"]))
        if kind not in ("uniform", "loguniform", "int", "logint"):
            raise ValueError(f"unknown space type {kind!r} for {name!r}")
        return cls(name=name, kind=kind, low=float(spec["low"]), high=float(spec["high"]))


class SearchSpace:
    """An ordered collection of ``Param``s with unit-cube vectorization."""

    def __init__(self, params: list[Param]):
        self.params = params
        self.searchable = [p for p in params if p.is_searchable]
        self._build_codec()

    def _build_codec(self) -> None:
        """Precompute per-dim codec arrays so batch (en/de)coding is pure
        numpy — one array expression per dimension, no per-point Python."""
        d = len(self.searchable)
        self._log_mask = np.zeros(d, dtype=bool)
        self._int_mask = np.zeros(d, dtype=bool)
        self._cat_mask = np.zeros(d, dtype=bool)
        self._lo_t = np.zeros(d)          # low in the (log-)transformed domain
        self._enc_span = np.ones(d)       # divisor used by to_unit (guarded)
        self._dec_span = np.ones(d)       # multiplier used by from_unit
        self._n_cat = np.ones(d, dtype=np.int64)
        self._cat_index: list[dict[Any, int] | None] = []
        for i, p in enumerate(self.searchable):
            if p.kind == "categorical":
                self._cat_mask[i] = True
                self._n_cat[i] = len(p.choices)
                self._cat_index.append({c: j for j, c in enumerate(p.choices)})
                continue
            self._cat_index.append(None)
            self._log_mask[i] = p.kind in ("loguniform", "logint")
            self._int_mask[i] = p.kind in ("int", "logint")
            if self._log_mask[i]:
                self._lo_t[i] = math.log(p.low)
                span = math.log(p.high) - math.log(p.low)
                self._enc_span[i] = self._dec_span[i] = span
            else:
                self._lo_t[i] = p.low
                self._dec_span[i] = p.high - p.low
                # to_unit guards the int divisor (degenerate low == high)
                self._enc_span[i] = (max(p.high - p.low, 1e-12)
                                     if p.kind == "int" else p.high - p.low)

    @classmethod
    def from_properties(cls, properties: dict[str, Any]) -> "SearchSpace":
        return cls([Param.from_spec(k, v) for k, v in sorted(properties.items())])

    @property
    def dim(self) -> int:
        return len(self.searchable)

    def names(self) -> list[str]:
        return [p.name for p in self.searchable]

    def sample_uniform(self, rng: np.random.Generator) -> dict[str, Any]:
        u = rng.uniform(size=self.dim)
        return self.from_unit_vector(u)

    # ---- batched codec ------------------------------------------------
    def to_unit_matrix(self, params_list: list[dict[str, Any]]) -> np.ndarray:
        """Featurize k parameter dicts into a (k, dim) unit-cube matrix."""
        k = len(params_list)
        U = np.empty((k, self.dim), dtype=np.float64)
        for i, p in enumerate(self.searchable):
            col = [ps[p.name] for ps in params_list]
            if self._cat_mask[i]:
                index = self._cat_index[i]
                idx = np.fromiter((index[v] for v in col),
                                  dtype=np.float64, count=k)
                U[:, i] = (idx + 0.5) / self._n_cat[i]
            else:
                v = np.asarray(col, dtype=np.float64)
                if self._log_mask[i]:
                    v = np.log(v)
                U[:, i] = (v - self._lo_t[i]) / self._enc_span[i]
        return U

    def from_unit_matrix(self, U: np.ndarray) -> list[dict[str, Any]]:
        """Decode a (k, dim) unit-cube matrix into k parameter dicts."""
        U = np.clip(np.asarray(U, dtype=np.float64), 0.0, 1.0)
        if U.ndim != 2:                  # a single point (incl. dim == 0)
            U = U.reshape(1, self.dim)
        k = len(U)
        const = {p.name: p.value for p in self.params if not p.is_searchable}
        out = [dict(const) for _ in range(k)]
        for i, p in enumerate(self.searchable):
            u = U[:, i]
            if self._cat_mask[i]:
                n = int(self._n_cat[i])
                idx = np.minimum((u * n).astype(np.int64), n - 1)
                for row, j in zip(out, idx):
                    row[p.name] = p.choices[j]
            else:
                v = self._lo_t[i] + u * self._dec_span[i]
                if self._log_mask[i]:
                    v = np.exp(v)
                if self._int_mask[i]:
                    for row, x in zip(out, np.rint(v)):
                        row[p.name] = int(x)
                else:
                    for row, x in zip(out, v):
                        row[p.name] = float(x)
        return out

    def to_unit_vector(self, params: dict[str, Any]) -> np.ndarray:
        return self.to_unit_matrix([params])[0]

    def from_unit_vector(self, u: np.ndarray) -> dict[str, Any]:
        return self.from_unit_matrix(np.asarray(u, dtype=np.float64)[None])[0]

    def grid(self, points_per_dim: int = 5) -> list[dict[str, Any]]:
        """Full-factorial lattice (categoricals enumerate all choices)."""
        axes = []
        for p in self.searchable:
            if p.kind == "categorical":
                # bin centers: one per choice under equal-width binning
                axes.append((np.arange(p.n_categories) + 0.5) / p.n_categories)
            elif p.kind in ("int", "logint"):
                n = min(points_per_dim, int(p.high - p.low) + 1)
                axes.append(np.linspace(0.0, 1.0, max(n, 1)))
            else:
                axes.append(np.linspace(0.0, 1.0, points_per_dim))
        mesh = np.meshgrid(*axes, indexing="ij") if axes else []
        if not mesh:
            return [self.from_unit_vector(np.zeros(0))]
        flat = np.stack([m.ravel() for m in mesh], axis=-1)
        return self.from_unit_matrix(flat)
