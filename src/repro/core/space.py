"""Hyperparameter search-space specification.

Spaces are JSON-serializable (they travel in the body of `ask` requests,
paper sec. 2) and support an internal mapping to the unit hypercube, which
is what the numeric samplers (TPE / GP / CMA-ES) operate on.

Spec grammar (the ``properties`` dict of a study):
    {"lr":     {"type": "loguniform", "low": 1e-5, "high": 1e-1},
     "layers": {"type": "int", "low": 1, "high": 8},
     "act":    {"type": "categorical", "choices": ["relu", "gelu"]},
     "dropout":{"type": "uniform", "low": 0.0, "high": 0.5}}
Plain scalars (int/float/str/bool) are passed through as constants, which
lets a client pin some properties while scanning others.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import numpy as np


@dataclasses.dataclass(frozen=True)
class Param:
    """One dimension of the search space."""

    name: str
    kind: str                      # uniform | loguniform | int | logint | categorical | const
    low: float = 0.0
    high: float = 1.0
    choices: tuple = ()
    value: Any = None              # for const

    # ---- unit-cube mapping (used by TPE/GP/CMA-ES) -------------------
    def to_unit(self, v: Any) -> float:
        if self.kind == "uniform":
            return (float(v) - self.low) / (self.high - self.low)
        if self.kind == "loguniform":
            return (math.log(float(v)) - math.log(self.low)) / (
                math.log(self.high) - math.log(self.low))
        if self.kind == "int":
            return (float(v) - self.low) / max(self.high - self.low, 1e-12)
        if self.kind == "logint":
            return (math.log(float(v)) - math.log(self.low)) / (
                math.log(self.high) - math.log(self.low))
        if self.kind == "categorical":
            return self.choices.index(v) / max(len(self.choices) - 1, 1)
        return 0.0  # const

    def from_unit(self, u: float) -> Any:
        u = min(max(float(u), 0.0), 1.0)
        if self.kind == "uniform":
            return self.low + u * (self.high - self.low)
        if self.kind == "loguniform":
            return math.exp(math.log(self.low) + u * (math.log(self.high) - math.log(self.low)))
        if self.kind == "int":
            return int(round(self.low + u * (self.high - self.low)))
        if self.kind == "logint":
            return int(round(math.exp(
                math.log(self.low) + u * (math.log(self.high) - math.log(self.low)))))
        if self.kind == "categorical":
            idx = int(round(u * (len(self.choices) - 1)))
            return self.choices[idx]
        return self.value  # const

    @property
    def n_categories(self) -> int:
        return len(self.choices) if self.kind == "categorical" else 0

    @property
    def is_searchable(self) -> bool:
        return self.kind != "const"

    # ---- (de)serialization -------------------------------------------
    def to_spec(self) -> Any:
        if self.kind == "const":
            return self.value
        d: dict[str, Any] = {"type": self.kind}
        if self.kind == "categorical":
            d["choices"] = list(self.choices)
        else:
            d["low"], d["high"] = self.low, self.high
        return d

    @classmethod
    def from_spec(cls, name: str, spec: Any) -> "Param":
        if not isinstance(spec, dict) or "type" not in spec:
            return cls(name=name, kind="const", value=spec)
        kind = spec["type"]
        if kind == "categorical":
            return cls(name=name, kind=kind, choices=tuple(spec["choices"]))
        if kind not in ("uniform", "loguniform", "int", "logint"):
            raise ValueError(f"unknown space type {kind!r} for {name!r}")
        return cls(name=name, kind=kind, low=float(spec["low"]), high=float(spec["high"]))


class SearchSpace:
    """An ordered collection of ``Param``s with unit-cube vectorization."""

    def __init__(self, params: list[Param]):
        self.params = params
        self.searchable = [p for p in params if p.is_searchable]

    @classmethod
    def from_properties(cls, properties: dict[str, Any]) -> "SearchSpace":
        return cls([Param.from_spec(k, v) for k, v in sorted(properties.items())])

    @property
    def dim(self) -> int:
        return len(self.searchable)

    def names(self) -> list[str]:
        return [p.name for p in self.searchable]

    def sample_uniform(self, rng: np.random.Generator) -> dict[str, Any]:
        u = rng.uniform(size=self.dim)
        return self.from_unit_vector(u)

    def to_unit_vector(self, params: dict[str, Any]) -> np.ndarray:
        return np.array([p.to_unit(params[p.name]) for p in self.searchable], dtype=np.float64)

    def from_unit_vector(self, u: np.ndarray) -> dict[str, Any]:
        out = {p.name: p.value for p in self.params if not p.is_searchable}
        for p, ui in zip(self.searchable, np.asarray(u, dtype=np.float64)):
            out[p.name] = p.from_unit(ui)
        return out

    def grid(self, points_per_dim: int = 5) -> list[dict[str, Any]]:
        """Full-factorial lattice (categoricals enumerate all choices)."""
        axes = []
        for p in self.searchable:
            if p.kind == "categorical":
                axes.append(np.linspace(0.0, 1.0, p.n_categories))
            elif p.kind in ("int", "logint"):
                n = min(points_per_dim, int(p.high - p.low) + 1)
                axes.append(np.linspace(0.0, 1.0, max(n, 1)))
            else:
                axes.append(np.linspace(0.0, 1.0, points_per_dim))
        mesh = np.meshgrid(*axes, indexing="ij") if axes else []
        if not mesh:
            return [self.from_unit_vector(np.zeros(0))]
        flat = np.stack([m.ravel() for m in mesh], axis=-1)
        return [self.from_unit_vector(row) for row in flat]
