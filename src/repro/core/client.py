"""Python frontend for the HOPAAS service (the Zenodo ``hopaas_client`` role).

The client speaks the typed v2 surface: the token travels in an
``Authorization: Bearer`` header (never the URL path), studies are
first-class resources (``POST /api/v2/studies`` once, then
``…/trials:ask`` against the returned key), and failures carry the
structured error envelope — ``HopaasError`` exposes ``status``, ``code``
and the offending ``field``.

Idempotent calls retry transparently on connection resets, fabric 502s
(``bad_upstream``), 503s (overload, ``shard_migrating``) and retryable
error *codes* (``shard_failover`` while the fabric promotes a replica)
with exponential backoff + full jitter (``RetryPolicy``).  ``ask`` is
idempotent per lease (a duplicate suggestion is just another leased
trial the sweeper reclaims); ``tell``/``tell_batch`` attach a
client-generated idempotency key, constant across retries, so a resend
after a lost response makes the server replay the original result —
exactly-once, with no guessing about whether the first attempt landed.

    client = Client(transport, token)
    study = Study(name="opt", properties={"lr": space.loguniform(1e-5, 1e-1)},
                  direction="minimize", sampler={"name": "tpe"},
                  pruner={"name": "median"}, client=client)
    with study.trial() as trial:
        for step in range(epochs):
            loss = train_one_epoch(lr=trial.lr)
            if trial.should_prune(step, loss):
                break
        trial.loss = loss          # -> tell on context exit
"""
from __future__ import annotations

import contextlib
import dataclasses
import http.client
import random
import time
import urllib.parse
import uuid
from typing import Any, Iterator

from .transport import Transport


class HopaasError(RuntimeError):
    """A failed service call, carrying the structured error envelope."""

    def __init__(self, message: str, *, status: int | None = None,
                 code: str | None = None, field: str | None = None,
                 payload: dict[str, Any] | None = None):
        super().__init__(message)
        self.status = status
        self.code = code
        self.field = field
        self.payload = payload or {}


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff with full jitter for transient failures."""

    max_attempts: int = 3            # total tries, including the first
    base_delay: float = 0.05         # seconds; doubles per retry
    max_delay: float = 2.0
    # 503 = refused before processing (overload / shard_migrating);
    # 502 = the fabric router lost its worker mid-request (bad_upstream)
    retry_statuses: tuple[int, ...] = (502, 503)
    # error codes retried regardless of status: a fenced/deposed leader
    # answers 409 shard_failover while the fabric finishes promoting its
    # replica — the request is safe to replay against the new leader
    retry_codes: tuple[str, ...] = ("shard_failover",)

    def delay(self, attempt: int) -> float:
        """Backoff before retry #``attempt`` (1-based), with full jitter so
        a thundering herd of workers doesn't resynchronize."""
        cap = min(self.max_delay, self.base_delay * (2 ** (attempt - 1)))
        return cap * (0.5 + 0.5 * random.random())


# transport failures where the connection died underneath us — retryable
# for idempotent calls (the request may or may not have been processed)
_RETRYABLE_ERRORS = (ConnectionError, http.client.RemoteDisconnected,
                     http.client.BadStatusLine, http.client.CannotSendRequest)


# -- ergonomic space constructors (mirror hopaas_client.suggestions) -----
class suggestions:
    @staticmethod
    def uniform(low: float, high: float) -> dict:
        return {"type": "uniform", "low": low, "high": high}

    @staticmethod
    def loguniform(low: float, high: float) -> dict:
        return {"type": "loguniform", "low": low, "high": high}

    @staticmethod
    def int(low: int, high: int) -> dict:       # noqa: A003
        return {"type": "int", "low": low, "high": high}

    @staticmethod
    def logint(low: int, high: int) -> dict:
        return {"type": "logint", "low": low, "high": high}

    @staticmethod
    def categorical(choices: list) -> dict:
        return {"type": "categorical", "choices": choices}


class Client:
    def __init__(self, transport: Transport, token: str,
                 worker_id: str = "client",
                 retry: RetryPolicy | None = None):
        self.transport = transport
        self.token = token
        self.worker_id = worker_id
        self.retry = retry or RetryPolicy()

    # ------------------------------------------------------------------ #
    # request plumbing: header auth + retry with backoff
    # ------------------------------------------------------------------ #
    def _headers(self) -> dict[str, str]:
        return {"Authorization": f"Bearer {self.token}"}

    def _request(self, method: str, path: str,
                 body: dict[str, Any] | None = None, *,
                 idempotent: bool = True, op: str = ""
                 ) -> tuple[int, dict[str, Any]]:
        """One logical call -> (status, payload), retrying idempotent
        requests on transport failures, retryable statuses (fabric 502
        ``bad_upstream`` / 503 overload) and retryable error codes
        (``shard_failover`` during a fabric promotion).  A resend is
        always safe: operations that mutate state carry idempotency
        keys, so the server replays rather than re-applies."""
        attempt = 0
        while True:
            try:
                status, payload = self.transport.request(
                    method, path, body, headers=self._headers())
            except _RETRYABLE_ERRORS as e:
                if not idempotent or attempt + 1 >= self.retry.max_attempts:
                    raise HopaasError(
                        f"{op or path} transport failure after "
                        f"{attempt + 1} attempts: {e!r}") from e
                attempt += 1
                time.sleep(self.retry.delay(attempt))
                continue
            code = ((payload.get("error") or {}).get("code")
                    if isinstance(payload, dict) else None)
            if ((status in self.retry.retry_statuses
                 or code in self.retry.retry_codes)
                    and idempotent
                    and attempt + 1 < self.retry.max_attempts):
                attempt += 1
                time.sleep(self.retry.delay(attempt))
                continue
            return status, payload

    @staticmethod
    def _raise_for(op: str, status: int, payload: dict[str, Any]) -> None:
        err = payload.get("error") or {}
        message = err.get("message") or payload.get("detail")
        raise HopaasError(f"{op} -> {status}: {message}", status=status,
                          code=err.get("code"), field=err.get("field"),
                          payload=payload)

    def _call(self, method: str, path: str,
              body: dict[str, Any] | None = None, *, op: str,
              ok: tuple[int, ...] = (200,), idempotent: bool = True
              ) -> dict[str, Any]:
        status, payload = self._request(method, path, body,
                                        idempotent=idempotent, op=op)
        if status not in ok:
            self._raise_for(op, status, payload)
        return payload

    @staticmethod
    def _qs(**params: Any) -> str:
        clean = {k: v for k, v in params.items() if v is not None}
        return f"?{urllib.parse.urlencode(clean)}" if clean else ""

    # ------------------------------------------------------------------ #
    # v2 surface
    # ------------------------------------------------------------------ #
    def version(self) -> str:
        return self._call("GET", "/api/v2/version", op="version")["version"]

    def ensure_study(self, spec: dict[str, Any]) -> tuple[str, bool]:
        """Create-or-get the study ``spec`` describes -> (key, created)."""
        payload = self._call("POST", "/api/v2/studies", spec,
                             op="create_study", ok=(200, 201))
        return payload["study"]["key"], payload["created"]

    def ask(self, study_key: str, worker_id: str | None = None,
            parallelism: int | None = None) -> dict[str, Any]:
        # parallelism = how many workers share this study; the server's
        # speculative precompute sizes its proposal buffer to cover one
        # wave of that many concurrent asks
        body: dict[str, Any] = {"worker_id": worker_id or self.worker_id}
        if parallelism is not None:
            body["parallelism"] = parallelism
        return self._call(
            "POST", f"/api/v2/studies/{study_key}/trials:ask",
            body, op="ask")

    def ask_batch(self, study_key: str, n: int,
                  worker_id: str | None = None,
                  parallelism: int | None = None) -> list[dict[str, Any]]:
        body: dict[str, Any] = {"n": n,
                                "worker_id": worker_id or self.worker_id}
        if parallelism is not None:
            body["parallelism"] = parallelism
        payload = self._call(
            "POST", f"/api/v2/studies/{study_key}/trials:ask_batch",
            body, op="ask_batch")
        return payload["trials"]

    def tell(self, trial_uid: str, value: Any = None,
             state: str = "completed") -> dict[str, Any]:
        # the key is constant across every retry of this logical tell:
        # a resend after a lost response (or a failover replay) makes
        # the server return the original result instead of a 409
        return self._call(
            "POST", f"/api/v2/trials/{trial_uid}:tell",
            {"value": value, "state": state,
             "idempotency_key": uuid.uuid4().hex}, op="tell")

    def tell_batch(self, tells: list[dict[str, Any]]
                   ) -> list[dict[str, Any]]:
        items = [dict(t) for t in tells]
        for item in items:
            item.setdefault("idempotency_key", uuid.uuid4().hex)
        payload = self._call("POST", "/api/v2/trials:tell_batch",
                             {"tells": items}, op="tell_batch")
        return payload["results"]

    def report(self, trial_uid: str, step: int, value: float
               ) -> dict[str, Any]:
        return self._call("POST", f"/api/v2/trials/{trial_uid}:report",
                          {"step": step, "value": value}, op="report")

    def study(self, study_key: str) -> dict[str, Any]:
        return self._call("GET", f"/api/v2/studies/{study_key}",
                          op="study")["study"]

    def trial(self, trial_uid: str) -> dict[str, Any]:
        return self._call("GET", f"/api/v2/trials/{trial_uid}",
                          op="trial")["trial"]

    def trials_page(self, study_key: str, *, state: str | None = None,
                    limit: int = 100, cursor: int | None = None
                    ) -> dict[str, Any]:
        """One page: {"trials": [...], "next_cursor": int | None}."""
        qs = self._qs(state=state, limit=limit, cursor=cursor)
        return self._call("GET",
                          f"/api/v2/studies/{study_key}/trials{qs}",
                          op="trials")

    def iter_trials(self, study_key: str, *, state: str | None = None,
                    page_size: int = 200) -> Iterator[dict[str, Any]]:
        """All trials of a study, transparently paginating."""
        cursor: int | None = None
        while True:
            page = self.trials_page(study_key, state=state,
                                    limit=page_size, cursor=cursor)
            yield from page["trials"]
            cursor = page["next_cursor"]
            if cursor is None:
                return

    def studies(self) -> list[dict[str, Any]]:
        """All study resources (paginating under the hood)."""
        out: list[dict[str, Any]] = []
        cursor: int | None = None
        while True:
            qs = self._qs(limit=200, cursor=cursor)
            payload = self._call("GET", f"/api/v2/studies{qs}", op="studies")
            out.extend(payload["studies"])
            cursor = payload["next_cursor"]
            if cursor is None:
                return out

    def openapi(self) -> dict[str, Any]:
        return self._call("GET", "/api/v2/openapi", op="openapi")

    # ------------------------------------------------------------------ #
    # v1 compat helper (token in path) — kept for legacy callers/tests;
    # exercises the shim end to end
    # ------------------------------------------------------------------ #
    def _post(self, endpoint: str, body: dict[str, Any]) -> dict[str, Any]:
        status, payload = self._request(
            "POST", f"/api/{endpoint}/{self.token}", body,
            op=endpoint, idempotent=False)
        if status != 200:
            raise HopaasError(
                f"{endpoint} -> {status}: {payload.get('detail')}",
                status=status,
                code=(payload.get("error") or {}).get("code"),
                field=(payload.get("error") or {}).get("field"),
                payload=payload)
        return payload


class Trial:
    """A live trial.  Suggested hyperparameters are exposed as attributes
    (``trial.lr``) and via ``trial.params``."""

    def __init__(self, study: "Study", payload: dict[str, Any]):
        self._study = study
        # accepts both the v2 trial resource and the v1 ask payload
        self.uid: str = payload.get("uid") or payload["trial_uid"]
        self.id: int = payload["trial_id"]
        self.params: dict[str, Any] = (payload.get("params")
                                       if "params" in payload
                                       else payload["properties"])
        self.loss: float | None = None      # set by user code before exit
        self.pruned = False
        self.failed = False

    def __getattr__(self, name: str) -> Any:
        params = object.__getattribute__(self, "params")
        if name in params:
            return params[name]
        raise AttributeError(name)

    def should_prune(self, step: int, value: float) -> bool:
        payload = self._study._client.report(self.uid, step, value)
        if payload["should_prune"]:
            self.pruned = True
        return self.pruned


class Study:
    def __init__(self, name: str, properties: dict[str, Any],
                 direction: str = "minimize",
                 sampler: dict[str, Any] | None = None,
                 pruner: dict[str, Any] | None = None,
                 client: Client | None = None,
                 directions: list[str] | None = None):
        if client is None:
            raise ValueError("a Client is required")
        self.name = name
        self.properties = properties
        self.direction = direction
        self.directions = directions        # multi-objective when set
        self.sampler = sampler or {"name": "tpe"}
        self.pruner = pruner or {"name": "none"}
        self._client = client
        self.study_key: str | None = None

    def _spec_body(self) -> dict[str, Any]:
        body = {
            "name": self.name, "properties": self.properties,
            "direction": self.direction, "sampler": self.sampler,
            "pruner": self.pruner, "worker_id": self._client.worker_id,
        }
        if self.directions:
            body["directions"] = self.directions
        return body

    def _ensure_key(self) -> str:
        if self.study_key is None:
            self.study_key, _ = self._client.ensure_study(self._spec_body())
        return self.study_key

    def ask(self) -> Trial:
        return Trial(self, self._ask_payloads(1)[0])

    def ask_batch(self, n: int) -> list[Trial]:
        """Suggest ``n`` trials in one round trip; the server-side sampler
        sees the whole batch at once."""
        return [Trial(self, p) for p in self._ask_payloads(n)]

    def _ask_payloads(self, n: int) -> list[dict[str, Any]]:
        key = self._ensure_key()
        try:
            if n == 1:
                return [self._client.ask(key)]
            return self._client.ask_batch(key, n)
        except HopaasError as e:
            if e.code != "study_not_found":
                raise
            # the service restarted without its journal: re-create the
            # study (content-addressed, so the key is identical) and retry
            self.study_key = None
            key = self._ensure_key()
            if n == 1:
                return [self._client.ask(key)]
            return self._client.ask_batch(key, n)

    def tell_batch(self, results: list[tuple]) -> list[dict[str, Any]]:
        """Finalize many trials in one round trip.

        ``results`` holds ``(trial, value)`` or ``(trial, value, state)``
        tuples.  Returns per-trial outcomes; an already-finalized trial
        (straggler conflict, item status 409) never fails the batch.
        """
        tells = []
        for item in results:
            trial, value = item[0], item[1]
            state = item[2] if len(item) > 2 else None
            if state is None:
                state = ("pruned" if trial.pruned else
                         "failed" if trial.failed else "completed")
            tells.append({"trial_uid": trial.uid,
                          "value": trial.loss if value is None else value,
                          "state": state})
        return self._client.tell_batch(tells)

    def tell(self, trial: Trial, value: float | None = None,
             state: str | None = None) -> None:
        if state is None:
            state = ("pruned" if trial.pruned else
                     "failed" if trial.failed else "completed")
        self._client.tell(trial.uid,
                          value=trial.loss if value is None else value,
                          state=state)

    @contextlib.contextmanager
    def trial(self) -> Iterator[Trial]:
        t = self.ask()
        try:
            yield t
        except Exception:
            t.failed = True
            self.tell(t, state="failed")
            raise
        else:
            self.tell(t)
