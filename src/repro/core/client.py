"""Python frontend for the HOPAAS service (the Zenodo ``hopaas_client`` role).

The client is a thin wrapper over the REST APIs (paper sec. 2): the
protocol is language-agnostic; this class hierarchy only adds convenience.

    client = Client(transport, token)
    study = Study(name="opt", properties={"lr": space.loguniform(1e-5, 1e-1)},
                  direction="minimize", sampler={"name": "tpe"},
                  pruner={"name": "median"}, client=client)
    with study.trial() as trial:
        for step in range(epochs):
            loss = train_one_epoch(lr=trial.lr)
            if trial.should_prune(step, loss):
                break
        trial.loss = loss          # -> tell on context exit
"""
from __future__ import annotations

import contextlib
from typing import Any, Iterator

from .transport import Transport


class HopaasError(RuntimeError):
    pass


# -- ergonomic space constructors (mirror hopaas_client.suggestions) -----
class suggestions:
    @staticmethod
    def uniform(low: float, high: float) -> dict:
        return {"type": "uniform", "low": low, "high": high}

    @staticmethod
    def loguniform(low: float, high: float) -> dict:
        return {"type": "loguniform", "low": low, "high": high}

    @staticmethod
    def int(low: int, high: int) -> dict:       # noqa: A003
        return {"type": "int", "low": low, "high": high}

    @staticmethod
    def logint(low: int, high: int) -> dict:
        return {"type": "logint", "low": low, "high": high}

    @staticmethod
    def categorical(choices: list) -> dict:
        return {"type": "categorical", "choices": choices}


class Client:
    def __init__(self, transport: Transport, token: str, worker_id: str = "client"):
        self.transport = transport
        self.token = token
        self.worker_id = worker_id

    def _post(self, endpoint: str, body: dict[str, Any]) -> dict[str, Any]:
        status, payload = self.transport.request(
            "POST", f"/api/{endpoint}/{self.token}", body)
        if status != 200:
            raise HopaasError(f"{endpoint} -> {status}: {payload.get('detail')}")
        return payload

    def version(self) -> str:
        status, payload = self.transport.request("GET", "/api/version")
        if status != 200:
            raise HopaasError(f"version -> {status}")
        return payload["version"]

    def studies(self) -> list[dict[str, Any]]:
        status, payload = self.transport.request(
            "GET", f"/api/studies/{self.token}")
        if status != 200:
            raise HopaasError(f"studies -> {status}: {payload.get('detail')}")
        return payload["studies"]


class Trial:
    """A live trial.  Suggested hyperparameters are exposed as attributes
    (``trial.lr``) and via ``trial.params``."""

    def __init__(self, study: "Study", payload: dict[str, Any]):
        self._study = study
        self.uid: str = payload["trial_uid"]
        self.id: int = payload["trial_id"]
        self.params: dict[str, Any] = payload["properties"]
        self.loss: float | None = None      # set by user code before exit
        self.pruned = False
        self.failed = False

    def __getattr__(self, name: str) -> Any:
        params = object.__getattribute__(self, "params")
        if name in params:
            return params[name]
        raise AttributeError(name)

    def should_prune(self, step: int, value: float) -> bool:
        payload = self._study._client._post(
            "should_prune", {"trial_uid": self.uid, "step": step, "value": value})
        if payload["should_prune"]:
            self.pruned = True
        return self.pruned


class Study:
    def __init__(self, name: str, properties: dict[str, Any],
                 direction: str = "minimize",
                 sampler: dict[str, Any] | None = None,
                 pruner: dict[str, Any] | None = None,
                 client: Client | None = None,
                 directions: list[str] | None = None):
        if client is None:
            raise ValueError("a Client is required")
        self.name = name
        self.properties = properties
        self.direction = direction
        self.directions = directions        # multi-objective when set
        self.sampler = sampler or {"name": "tpe"}
        self.pruner = pruner or {"name": "none"}
        self._client = client
        self.study_key: str | None = None

    def _spec_body(self) -> dict[str, Any]:
        body = {
            "name": self.name, "properties": self.properties,
            "direction": self.direction, "sampler": self.sampler,
            "pruner": self.pruner, "worker_id": self._client.worker_id,
        }
        if self.directions:
            body["directions"] = self.directions
        return body

    def ask(self) -> Trial:
        payload = self._client._post("ask", self._spec_body())
        self.study_key = payload["study_key"]
        return Trial(self, payload)

    def ask_batch(self, n: int) -> list[Trial]:
        """Suggest ``n`` trials in one round trip (`POST /api/ask_batch`);
        the server-side sampler sees the whole batch at once."""
        payload = self._client._post("ask_batch", {**self._spec_body(), "n": n})
        self.study_key = payload["study_key"]
        return [Trial(self, p) for p in payload["trials"]]

    def tell_batch(self, results: list[tuple]) -> list[dict[str, Any]]:
        """Finalize many trials in one round trip (`POST /api/tell_batch`).

        ``results`` holds ``(trial, value)`` or ``(trial, value, state)``
        tuples.  Returns per-trial outcomes; an already-finalized trial
        (straggler conflict, item status 409) never fails the batch.
        """
        tells = []
        for item in results:
            trial, value = item[0], item[1]
            state = item[2] if len(item) > 2 else None
            if state is None:
                state = ("pruned" if trial.pruned else
                         "failed" if trial.failed else "completed")
            tells.append({"trial_uid": trial.uid,
                          "value": trial.loss if value is None else value,
                          "state": state})
        payload = self._client._post("tell_batch", {"tells": tells})
        return payload["results"]

    def tell(self, trial: Trial, value: float | None = None,
             state: str | None = None) -> None:
        if state is None:
            state = ("pruned" if trial.pruned else
                     "failed" if trial.failed else "completed")
        self._client._post("tell", {
            "trial_uid": trial.uid,
            "value": trial.loss if value is None else value,
            "state": state,
        })

    @contextlib.contextmanager
    def trial(self) -> Iterator[Trial]:
        t = self.ask()
        try:
            yield t
        except Exception:
            t.failed = True
            self.tell(t, state="failed")
            raise
        else:
            self.tell(t)
