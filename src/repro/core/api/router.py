"""Declarative request router for the HOPAAS service.

Routes are registered as ``(method, path template, handler)`` triples with
typed path/query parameters and an optional request ``Schema`` — the
if-chain dispatch of the old ``HopaasServer.handle`` becomes data:

    Route("POST", "/api/v2/studies/{key}/trials:ask", handler,
          auth="bearer", request_schema=AskRequest)

Templates support ``{param}`` placeholders and Google-style custom verbs
(``resource:action``, including ``{uid}:tell`` — a placeholder with a
literal suffix).  Dispatch semantics:

  * unknown path                    -> 404 ``not_found``
  * known path, wrong method        -> 405 with an ``Allow`` header
  * auth failure (bearer or v1 path token) -> 401 ``unauthorized``
  * malformed JSON body             -> 400 ``invalid_json``
  * schema/query violations         -> 422 naming the offending field
  * handler ``ApiError``            -> its status + structured envelope
  * anything else                   -> 500 (a server never drops the socket)

All error payloads use the structured envelope (``errors.error_payload``).
The router is transport-independent: both the stdlib HTTP frontend and
``DirectTransport`` feed ``dispatch()``.
"""
from __future__ import annotations

import dataclasses
import re
import urllib.parse
from typing import Any, Callable

from .errors import ApiError, error_payload
from ..auth import AuthError, TokenManager, bearer_token

_SEGMENT_RE = re.compile(r"\{(\w+)\}(.*)")

# dispatch() result: (status, payload, response headers)
Response = tuple[int, dict[str, Any], dict[str, str]]


@dataclasses.dataclass(frozen=True)
class QueryParam:
    """A typed query-string parameter (``?limit=50&state=completed``)."""

    name: str
    kind: str = "str"                  # "str" | "int"
    default: Any = None
    choices: tuple | None = None
    min_value: int | None = None
    max_value: int | None = None
    doc: str = ""

    def parse(self, raw: dict[str, list[str]]) -> Any:
        if self.name not in raw:
            return self.default
        text = raw[self.name][-1]
        if self.kind == "int":
            try:
                value: Any = int(text)
            except ValueError:
                raise ApiError(422, "invalid_query",
                               f"query parameter {self.name!r} must be an "
                               f"integer, got {text!r}", field=self.name)
        else:
            value = text
        if self.choices is not None and value not in self.choices:
            raise ApiError(422, "invalid_query",
                           f"query parameter {self.name!r} must be one of "
                           f"{list(self.choices)}, got {value!r}",
                           field=self.name)
        if self.min_value is not None and isinstance(value, int) \
                and value < self.min_value:
            raise ApiError(422, "invalid_query",
                           f"query parameter {self.name!r} must be >= "
                           f"{self.min_value}", field=self.name)
        if self.max_value is not None and isinstance(value, int) \
                and value > self.max_value:
            raise ApiError(422, "invalid_query",
                           f"query parameter {self.name!r} must be <= "
                           f"{self.max_value}", field=self.name)
        return value


@dataclasses.dataclass
class Request:
    """Everything a handler sees — already authenticated and validated."""

    method: str
    path: str
    path_params: dict[str, str]
    query: dict[str, Any]
    headers: dict[str, str]
    body: dict[str, Any]
    identity: dict[str, Any] | None    # token payload (user, exp, jti)


class Route:
    """One (method, path template) -> handler binding."""

    def __init__(self, method: str, template: str,
                 handler: Callable[[Request], Any], *,
                 name: str = "", summary: str = "",
                 auth: str | None = "bearer",      # "bearer" | "path" | None
                 request_schema: type | None = None,
                 response_schema: type | None = None,
                 query_params: tuple[QueryParam, ...] = (),
                 tags: tuple[str, ...] = (),
                 ok_statuses: tuple[int, ...] = (200,)):
        assert auth in ("bearer", "path", None), auth
        self.method = method.upper()
        self.template = template
        self.handler = handler
        self.name = name or handler.__name__
        self.summary = summary
        self.auth = auth
        self.request_schema = request_schema
        self.response_schema = response_schema
        self.query_params = query_params
        self.tags = tags
        self.ok_statuses = ok_statuses
        self._segments: list[tuple[str | None, str]] = []
        for seg in (s for s in template.split("/") if s):
            m = _SEGMENT_RE.fullmatch(seg)
            if m:
                self._segments.append((m.group(1), m.group(2)))
            else:
                self._segments.append((None, seg))

    def path_param_names(self) -> list[str]:
        return [p for p, _ in self._segments if p is not None]

    def match(self, segments: list[str]) -> dict[str, str] | None:
        """Path params when ``segments`` matches this template, else None."""
        if len(segments) != len(self._segments):
            return None
        params: dict[str, str] = {}
        for actual, (param, literal) in zip(segments, self._segments):
            if param is None:
                if actual != literal:
                    return None
            elif literal:                  # "{uid}:tell" — literal suffix
                if not actual.endswith(literal) or len(actual) <= len(literal):
                    return None
                params[param] = actual[: -len(literal)]
            else:
                params[param] = actual
        return params


class Router:
    def __init__(self, tokens: TokenManager):
        self.tokens = tokens
        self.routes: list[Route] = []
        # hot-path index: only routes with the right segment count can
        # match, so dispatch scans a handful of candidates instead of
        # the whole route table
        self._by_length: dict[int, list[Route]] = {}

    def add(self, route: Route) -> Route:
        self.routes.append(route)
        self._by_length.setdefault(len(route._segments), []).append(route)
        return route

    # ------------------------------------------------------------------ #
    def dispatch(self, method: str, path: str,
                 body: Any = None, headers: dict[str, str] | None = None,
                 body_error: str | None = None) -> Response:
        clean_path, _, qs = (path or "").partition("?")
        segments = [s for s in clean_path.split("/") if s]
        matched: tuple[Route, dict[str, str]] | None = None
        allowed: set[str] = set()
        for route in self._by_length.get(len(segments), ()):
            params = route.match(segments)
            if params is None:
                continue
            allowed.add(route.method)
            if route.method == method.upper() and matched is None:
                matched = (route, params)
        if matched is None:
            if allowed:
                allow = ", ".join(sorted(allowed))
                return (405, error_payload(
                    "method_not_allowed",
                    f"{method.upper()} not allowed for {clean_path}; "
                    f"allowed: {allow}"), {"Allow": allow})
            return 404, error_payload("not_found",
                                      f"no route for {clean_path!r}"), {}
        route, path_params = matched
        if body_error is not None:
            return 400, error_payload("invalid_json", body_error), {}
        try:
            identity = self._authenticate(route, path_params, headers or {})
            query = {qp.name: qp.parse(urllib.parse.parse_qs(
                qs, keep_blank_values=True)) for qp in route.query_params}
            if route.request_schema is not None:
                body = route.request_schema.validate(body)
            elif body is not None and not isinstance(body, dict):
                raise ApiError(422, "invalid_body",
                               f"request body must be a JSON object, got "
                               f"{type(body).__name__}", field="$")
            req = Request(method=method.upper(), path=clean_path,
                          path_params=path_params, query=query,
                          headers=headers or {}, body=body or {},
                          identity=identity)
            return self._normalize(route.handler(req))
        except AuthError as e:
            return 401, error_payload("unauthorized", str(e)), {}
        except ApiError as e:
            return e.status, e.payload(), {}
        except Exception as e:   # a production server never drops the socket
            return 500, error_payload(
                "internal", f"{type(e).__name__}: {e}"), {}

    # ------------------------------------------------------------------ #
    def _authenticate(self, route: Route, path_params: dict[str, str],
                      headers: dict[str, str]) -> dict[str, Any] | None:
        if route.auth is None:
            return None
        if route.auth == "path":
            return self.tokens.verify(path_params.pop("token", ""))
        token = bearer_token(headers)
        if token is None:
            present = any(k.lower() == "authorization" for k in headers)
            raise AuthError(
                ("malformed" if present else "missing")
                + " Authorization header (expected 'Bearer <token>')")
        return self.tokens.verify(token)

    @staticmethod
    def _normalize(out: Any) -> Response:
        if isinstance(out, tuple):
            if len(out) == 3:
                return out
            status, payload = out
            return status, payload, {}
        return 200, out, {}
