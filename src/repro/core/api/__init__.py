"""HOPAAS wire layer: declarative router, typed schemas, versioned routes.

``build_router(server)`` assembles the full dispatch table — the v2
resource surface plus the v1 compat shim — for one ``HopaasServer``.
"""
from __future__ import annotations

from typing import Any

from .errors import ApiError, error_payload
from .openapi import build_openapi
from .router import QueryParam, Request, Response, Route, Router
from .schemas import Field, Schema
from .v1 import register_v1
from .v2 import register_v2


def build_router(server: Any) -> Router:
    router = Router(server.tokens)
    register_v2(router, server)
    register_v1(router, server)
    return router


__all__ = ["ApiError", "error_payload", "build_openapi", "build_router",
           "QueryParam", "Request", "Response", "Route", "Router",
           "Field", "Schema", "register_v1", "register_v2"]
