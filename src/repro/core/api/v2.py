"""The v2 resource-oriented surface.

Studies and trials are first-class resources with stable URLs; actions
on them use Google-style custom verbs (``:ask``, ``:tell``, ``:report``).
Auth is an ``Authorization: Bearer <token>`` header checked by the router
— tokens no longer ride in the URL path, so they stay out of access logs
and proxies.  Monitoring endpoints paginate with ``limit``/``cursor``
and answer from the storage's per-state indices (never a trial-list
scan).

    GET  /api/v2/version
    GET  /api/v2/health
    GET  /api/v2/openapi
    POST /api/v2/studies                        create-or-get (201 on create)
    GET  /api/v2/studies?limit&cursor
    GET  /api/v2/studies/{key}
    GET  /api/v2/studies/{key}/trials?state&limit&cursor
    POST /api/v2/studies/{key}/trials:ask
    POST /api/v2/studies/{key}/trials:ask_batch
    GET  /api/v2/trials/{uid}
    POST /api/v2/trials/{uid}:tell
    POST /api/v2/trials/{uid}:report
    POST /api/v2/trials:tell_batch
"""
from __future__ import annotations

from typing import Any

from . import schemas
from .router import QueryParam, Request, Route, Router

_PAGE = (
    QueryParam("limit", "int", default=100, min_value=1, max_value=500,
               doc="page size"),
    QueryParam("cursor", "int", default=None, min_value=0,
               doc="resume after this position (from next_cursor)"),
)
_STATE = QueryParam(
    "state", "str", default=None,
    choices=("running", "completed", "pruned", "failed"),
    doc="filter trials by state (served from the state-bucket index)")


def _worker_id(req: Request) -> str | None:
    return req.body.get("worker_id") or (req.identity or {}).get("user")


def register_v2(router: Router, server: Any) -> None:
    """Mount the v2 surface for ``server`` (a ``HopaasServer``)."""

    def version(req: Request):
        return server.op_version_v2()

    def openapi(req: Request):
        return server.openapi_document()

    def create_study(req: Request):
        created, resource = server.op_create_study(req.body)
        return (201 if created else 200), {"study": resource,
                                           "created": created}

    def list_studies(req: Request):
        studies, next_cursor = server.op_list_studies(
            cursor=req.query["cursor"], limit=req.query["limit"])
        return {"studies": studies, "next_cursor": next_cursor}

    def get_study(req: Request):
        return {"study": server.op_get_study(req.path_params["key"])}

    def list_trials(req: Request):
        trials, next_cursor = server.op_list_trials(
            req.path_params["key"], state=req.query["state"],
            cursor=req.query["cursor"], limit=req.query["limit"])
        return {"trials": trials, "next_cursor": next_cursor}

    def ask(req: Request):
        (trial,) = server.op_ask(req.path_params["key"], _worker_id(req), 1,
                                 parallelism=req.body.get("parallelism"))
        return trial

    def ask_batch(req: Request):
        trials = server.op_ask(req.path_params["key"], _worker_id(req),
                               req.body["n"],
                               parallelism=req.body.get("parallelism"))
        return {"trials": trials, "study_key": req.path_params["key"]}

    def get_trial(req: Request):
        return {"trial": server.op_get_trial(req.path_params["uid"])}

    def health(req: Request):
        return server.op_health()

    def tell(req: Request):
        return server.op_tell(req.path_params["uid"], req.body["value"],
                              req.body["state"],
                              req.body.get("idempotency_key"))

    def tell_batch(req: Request):
        return {"results": server.op_tell_batch(req.body["tells"])}

    def report(req: Request):
        return server.op_report(req.path_params["uid"], req.body["step"],
                                req.body["value"])

    v2 = ("v2",)
    for route in (
        Route("GET", "/api/v2/version", version, auth=None, tags=v2,
              summary="service version + storage/durability stats",
              response_schema=schemas.VersionResponse),
        Route("GET", "/api/v2/openapi", openapi, auth=None, tags=v2,
              summary="this document, generated from the route table"),
        Route("GET", "/api/v2/health", health, auth=None, tags=v2,
              summary="machine-readable readiness: role, lease epoch, "
                      "replication lag, WAL/fsync stats",
              response_schema=schemas.HealthResponse),
        Route("POST", "/api/v2/studies", create_study, tags=v2,
              summary="create a study (or return the existing one with "
                      "the same content key); 201 on creation",
              request_schema=schemas.StudySpec,
              response_schema=schemas.StudyEnvelope,
              ok_statuses=(200, 201)),
        Route("GET", "/api/v2/studies", list_studies, tags=v2,
              summary="paginated study list (monitoring)",
              query_params=_PAGE, response_schema=schemas.StudyPage),
        Route("GET", "/api/v2/studies/{key}", get_study, tags=v2,
              summary="one study resource",
              response_schema=schemas.StudyEnvelope),
        Route("GET", "/api/v2/studies/{key}/trials", list_trials, tags=v2,
              summary="paginated trial list; ?state= answers from the "
                      "per-state index, never a trial scan",
              query_params=(_STATE,) + _PAGE,
              response_schema=schemas.TrialPage),
        Route("POST", "/api/v2/studies/{key}/trials:ask", ask, tags=v2,
              summary="suggest one trial (idempotent per lease)",
              request_schema=schemas.AskRequest,
              response_schema=schemas.TrialResource),
        Route("POST", "/api/v2/studies/{key}/trials:ask_batch", ask_batch,
              tags=v2, summary="suggest k trials in one round trip",
              request_schema=schemas.AskBatchRequest,
              response_schema=schemas.AskBatchResponse),
        Route("GET", "/api/v2/trials/{uid}", get_trial, tags=v2,
              summary="one trial resource",
              response_schema=schemas.TrialEnvelope),
        Route("POST", "/api/v2/trials/{uid}:tell", tell, tags=v2,
              summary="finalize a trial (409 if already finalized)",
              request_schema=schemas.TellBody,
              response_schema=schemas.TellResponse),
        Route("POST", "/api/v2/trials/{uid}:report", report, tags=v2,
              summary="report an intermediate value; doubles as the lease "
                      "heartbeat and returns the pruning verdict",
              request_schema=schemas.ReportBody,
              response_schema=schemas.ReportResponse),
        Route("POST", "/api/v2/trials:tell_batch", tell_batch, tags=v2,
              summary="finalize k trials; per-item statuses, a straggler "
                      "conflict never fails the batch",
              request_schema=schemas.TellBatchRequest,
              response_schema=schemas.TellBatchResponse),
    ):
        router.add(route)
