"""Structured API errors.

Every client-visible failure is an ``ApiError`` carrying an HTTP status,
a stable machine-readable ``code``, a human message, and (for validation
failures) the offending ``field``.  The wire shape is the v2 envelope

    {"error": {"code": ..., "message": ..., "field": ...}, "detail": ...}

``detail`` mirrors ``error.message`` so pre-v2 consumers that only read
``payload["detail"]`` keep working through the compat shim.
"""
from __future__ import annotations

from typing import Any


def error_payload(code: str, message: str, field: str | None = None
                  ) -> dict[str, Any]:
    err: dict[str, Any] = {"code": code, "message": message}
    if field is not None:
        err["field"] = field
    return {"detail": message, "error": err}


class ApiError(Exception):
    """A client-visible request failure (4xx) — never a dropped socket."""

    def __init__(self, status: int, code: str, message: str,
                 *, field: str | None = None):
        super().__init__(message)
        self.status = int(status)
        self.code = code
        self.message = message
        self.field = field

    def payload(self) -> dict[str, Any]:
        return error_payload(self.code, self.message, self.field)
