"""The v1 compat shim: the paper's RPC endpoints (Table 1) re-mounted as
thin adapters over the v2 core.

Paths, token-in-path auth, and success payloads are byte-compatible with
the pre-router service, so existing clients keep working unchanged:

    GET  /api/version
    POST /api/ask/{token}            body = study spec
    POST /api/ask_batch/{token}      body = study spec + n
    POST /api/tell/{token}           body = {trial_uid, value, state}
    POST /api/tell_batch/{token}     body = {tells: [...]}
    POST /api/should_prune/{token}   body = {trial_uid, step, value}
    GET  /api/studies/{token}

The only intentional behavior changes are fixes: a wrong method on a
known path is now 405 (with ``Allow``) instead of 404, and malformed
bodies are structured 400/422 errors instead of 500s.
"""
from __future__ import annotations

from typing import Any

from . import schemas
from .router import Request, Route, Router


def register_v1(router: Router, server: Any) -> None:
    """Mount the v1 shim for ``server`` (a ``HopaasServer``)."""

    def version(req: Request):
        return server.op_version()

    def ask(req: Request):
        return server._ask(req.body, req.identity or {})

    def ask_batch(req: Request):
        return server._ask_batch(req.body, req.identity or {})

    def tell(req: Request):
        return server._tell(req.body)

    def tell_batch(req: Request):
        return server._tell_batch(req.body)

    def should_prune(req: Request):
        return server._should_prune(req.body)

    def studies(req: Request):
        return server._studies()

    v1 = ("v1-compat",)
    for route in (
        Route("GET", "/api/version", version, auth=None, tags=v1,
              name="v1_version", summary="service version (v1)",
              response_schema=schemas.VersionResponse),
        Route("POST", "/api/ask/{token}", ask, auth="path", tags=v1,
              name="v1_ask", summary="suggest one trial (v1: study spec "
                                     "inline, token in path)",
              request_schema=schemas.V1AskRequest),
        Route("POST", "/api/ask_batch/{token}", ask_batch, auth="path",
              tags=v1, name="v1_ask_batch",
              summary="suggest k trials in one round trip (v1)",
              request_schema=schemas.V1AskBatchRequest),
        Route("POST", "/api/tell/{token}", tell, auth="path", tags=v1,
              name="v1_tell", summary="finalize a trial (v1)",
              request_schema=schemas.V1TellRequest),
        Route("POST", "/api/tell_batch/{token}", tell_batch, auth="path",
              tags=v1, name="v1_tell_batch",
              summary="finalize k trials (v1)",
              request_schema=schemas.TellBatchRequest),
        Route("POST", "/api/should_prune/{token}", should_prune, auth="path",
              tags=v1, name="v1_should_prune",
              summary="intermediate report + pruning verdict (v1)",
              request_schema=schemas.V1ReportRequest),
        Route("GET", "/api/studies/{token}", studies, auth="path", tags=v1,
              name="v1_studies", summary="study summaries (v1 monitoring)"),
    ):
        router.add(route)
