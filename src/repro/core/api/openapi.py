"""OpenAPI 3 document generated from the registered routes + schemas.

The document is *derived*, never hand-written: every ``Route`` on the
router contributes one operation, with parameters taken from its path
template and typed query params, and request/response bodies taken from
its ``Schema`` classes.  The route-consistency test asserts the
bijection (every registered route appears in the document and vice
versa), so the spec cannot drift from the dispatch table.
"""
from __future__ import annotations

from typing import Any

from .errors import ApiError  # noqa: F401  (documented error source)
from .router import Route, Router
from .schemas import ErrorEnvelope, Schema


def _ref(schema: type[Schema]) -> dict[str, Any]:
    return {"$ref": f"#/components/schemas/{schema.NAME}"}


def _operation(route: Route) -> dict[str, Any]:
    op: dict[str, Any] = {
        "operationId": route.name,
        "summary": route.summary or route.name,
    }
    if route.tags:
        op["tags"] = list(route.tags)
    params: list[dict[str, Any]] = []
    for name in route.path_param_names():
        desc = ("API token (v1 path-carried auth)" if name == "token"
                else "")
        params.append({"name": name, "in": "path", "required": True,
                       "schema": {"type": "string"},
                       **({"description": desc} if desc else {})})
    for qp in route.query_params:
        schema: dict[str, Any] = {
            "type": "integer" if qp.kind == "int" else "string"}
        if qp.choices is not None:
            schema["enum"] = list(qp.choices)
        if qp.default is not None:
            schema["default"] = qp.default
        if qp.min_value is not None:
            schema["minimum"] = qp.min_value
        if qp.max_value is not None:
            schema["maximum"] = qp.max_value
        params.append({"name": qp.name, "in": "query", "required": False,
                       "schema": schema,
                       **({"description": qp.doc} if qp.doc else {})})
    if params:
        op["parameters"] = params
    if route.request_schema is not None:
        op["requestBody"] = {
            "required": True,
            "content": {"application/json": {
                "schema": _ref(route.request_schema)}},
        }
    responses: dict[str, Any] = {}
    for status in route.ok_statuses:
        ok: dict[str, Any] = {
            "description": "created" if status == 201 else "success"}
        if route.response_schema is not None:
            ok["content"] = {"application/json": {
                "schema": _ref(route.response_schema)}}
        responses[str(status)] = ok
    responses["4XX"] = {
        "description": "structured error envelope "
                       "{error: {code, message, field?}}",
        "content": {"application/json": {"schema": _ref(ErrorEnvelope)}},
    }
    op["responses"] = responses
    if route.auth == "bearer":
        op["security"] = [{"bearerAuth": []}]
    return op


def build_openapi(router: Router, version: str) -> dict[str, Any]:
    paths: dict[str, dict[str, Any]] = {}
    components: dict[str, Any] = {ErrorEnvelope.NAME:
                                  ErrorEnvelope.json_schema()}
    for route in router.routes:
        paths.setdefault(route.template, {})[route.method.lower()] = \
            _operation(route)
        for schema in (route.request_schema, route.response_schema):
            if schema is not None:
                components.setdefault(schema.NAME, schema.json_schema())
    return {
        "openapi": "3.0.3",
        "info": {
            "title": "HOPAAS service API",
            "version": version,
            "description": "Hyperparameter optimization as a service: "
                           "resource-oriented v2 surface plus the v1 "
                           "compat shim (token-in-path RPC endpoints).",
        },
        "paths": paths,
        "components": {
            "schemas": components,
            "securitySchemes": {
                "bearerAuth": {"type": "http", "scheme": "bearer",
                               "description": "HMAC-signed HOPAAS token in "
                                              "the Authorization header"},
            },
        },
    }
