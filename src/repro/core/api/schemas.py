"""Typed request/response schemas for the HOPAAS wire protocol.

Every request body is validated at the boundary by a ``Schema``: a named
set of ``Field`` specs (JSON kind, required/default, choices, bounds).
Validation failures raise ``ApiError(422, ...)`` naming the offending
field — malformed input never reaches a handler and never surfaces as a
500.  The same field specs drive the generated OpenAPI document
(``api.openapi``), so the docs cannot drift from the enforcement.

Schemas are intentionally *lenient about unknown keys* (ignored, for
forward compatibility) and *strict about known ones* (a wrong JSON type
is a 422, not a best-effort coercion).
"""
from __future__ import annotations

import copy
import math
from typing import Any

from .errors import ApiError
from ..pruners import known_pruners
from ..samplers import known_samplers

_MISSING = object()

# JSON-kind -> (python check, OpenAPI schema)
_KINDS = {
    "str": "string",
    "int": "integer",
    "number": "number",
    "bool": "boolean",
    "dict": "object",
    "list": "array",
    "any": None,
    "number_or_list": None,
}


def _is_number(v: Any) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def _require_finite(v: Any, field: str) -> None:
    """Reject NaN/±inf objective values at the boundary: bare ``NaN`` /
    ``Infinity`` literals are not valid strict JSON (the WAL refuses to
    serialize them) and NaN silently corrupts incumbent comparisons."""
    if _is_number(v) and not math.isfinite(v):
        raise ApiError(422, "invalid_value",
                       f"field {field!r} must be finite, got {v!r}",
                       field=field)


def _require_finite_tree(obj: Any, field: str) -> None:
    """Recursively reject non-finite numbers anywhere in a spec subtree —
    stdlib ``json.loads`` accepts bare ``NaN`` on the wire, but the WAL's
    strict serializer (rightly) refuses to write it back out."""
    if isinstance(obj, dict):
        for k, v in obj.items():
            _require_finite_tree(v, f"{field}.{k}")
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            _require_finite_tree(v, f"{field}[{i}]")
    else:
        _require_finite(obj, field)


class Field:
    """One validated key of a JSON object body."""

    __slots__ = ("name", "kind", "required", "default", "nullable",
                 "choices", "min_value", "max_value", "item_kind", "doc")

    def __init__(self, name: str, kind: str, *, required: bool = False,
                 default: Any = None, nullable: bool = False,
                 choices: list | None = None, min_value: float | None = None,
                 max_value: float | None = None, item_kind: str | None = None,
                 doc: str = ""):
        assert kind in _KINDS, kind
        self.name, self.kind = name, kind
        self.required, self.default, self.nullable = required, default, nullable
        self.choices, self.min_value, self.max_value = choices, min_value, max_value
        self.item_kind, self.doc = item_kind, doc

    # -- validation -------------------------------------------------------
    def validate(self, body: dict[str, Any]) -> Any:
        if self.name not in body:
            if self.required:
                raise ApiError(422, "missing_field",
                               f"missing required field {self.name!r}",
                               field=self.name)
            # mutable defaults (sampler/pruner specs) must not be shared
            return copy.deepcopy(self.default)
        v = body[self.name]
        if v is None:
            if self.nullable or (not self.required and self.default is None):
                return None
            self._fail(v)
        self._check_kind(v, self.kind, self.name)
        if self.kind == "list" and self.item_kind is not None:
            for i, item in enumerate(v):
                self._check_kind(item, self.item_kind, f"{self.name}[{i}]")
                if self.choices is not None and item not in self.choices:
                    raise ApiError(
                        422, "invalid_value",
                        f"field {self.name!r}[{i}] must be one of "
                        f"{self.choices}, got {item!r}",
                        field=f"{self.name}[{i}]")
        elif self.choices is not None and v not in self.choices:
            raise ApiError(422, "invalid_value",
                           f"field {self.name!r} must be one of "
                           f"{self.choices}, got {v!r}", field=self.name)
        if self.min_value is not None and _is_number(v) and v < self.min_value:
            raise ApiError(422, "invalid_value",
                           f"field {self.name!r} must be >= {self.min_value}, "
                           f"got {v!r}", field=self.name)
        if self.max_value is not None and _is_number(v) and v > self.max_value:
            raise ApiError(422, "invalid_value",
                           f"field {self.name!r} must be <= {self.max_value}, "
                           f"got {v!r}", field=self.name)
        return v

    def _check_kind(self, v: Any, kind: str, label: str) -> None:
        ok = {
            "str": lambda: isinstance(v, str),
            "int": lambda: isinstance(v, int) and not isinstance(v, bool),
            "number": lambda: _is_number(v),
            "bool": lambda: isinstance(v, bool),
            "dict": lambda: isinstance(v, dict),
            "list": lambda: isinstance(v, list),
            "any": lambda: True,
            "number_or_list": lambda: _is_number(v) or (
                isinstance(v, list) and all(_is_number(x) for x in v)),
        }[kind]()
        if not ok:
            self._fail(v, label)

    def _fail(self, v: Any, label: str | None = None) -> None:
        label = label or self.name
        raise ApiError(422, "invalid_type",
                       f"field {label!r} must be {self.kind}, "
                       f"got {type(v).__name__}", field=label)

    # -- OpenAPI ----------------------------------------------------------
    def json_schema(self) -> dict[str, Any]:
        if self.kind == "number_or_list":
            out: dict[str, Any] = {"oneOf": [
                {"type": "number"},
                {"type": "array", "items": {"type": "number"}}]}
        elif self.kind == "any":
            out = {}
        else:
            out = {"type": _KINDS[self.kind]}
            if self.kind == "list" and self.item_kind in _KINDS \
                    and _KINDS[self.item_kind]:
                out["items"] = {"type": _KINDS[self.item_kind]}
        if self.choices is not None:
            out["enum"] = list(self.choices)
        if self.nullable:
            out["nullable"] = True
        if self.default is not None:
            out["default"] = self.default
        if self.doc:
            out["description"] = self.doc
        return out


class Schema:
    """A validated JSON-object body: ``validate`` returns the cleaned dict
    (defaults filled, unknown keys dropped) or raises ``ApiError(422)``."""

    NAME = "Schema"
    FIELDS: tuple[Field, ...] = ()

    @classmethod
    def validate(cls, body: Any) -> dict[str, Any]:
        if body is None:
            body = {}
        if not isinstance(body, dict):
            raise ApiError(422, "invalid_body",
                           f"request body must be a JSON object, got "
                           f"{type(body).__name__}", field="$")
        out = {f.name: f.validate(body) for f in cls.FIELDS}
        cls.post_validate(out)
        return out

    @classmethod
    def post_validate(cls, out: dict[str, Any]) -> None:
        """Cross-field checks; override in subclasses."""

    @classmethod
    def json_schema(cls) -> dict[str, Any]:
        required = [f.name for f in cls.FIELDS if f.required]
        schema: dict[str, Any] = {
            "type": "object",
            "properties": {f.name: f.json_schema() for f in cls.FIELDS},
        }
        if required:
            schema["required"] = required
        return schema


_DIRECTIONS = ["minimize", "maximize"]
_TELL_STATES = ["completed", "pruned", "failed"]


def _check_registry_name(spec: dict[str, Any], field: str, default: str,
                         known: list[str], code: str) -> None:
    name = spec.get("name", default)
    if not isinstance(name, str) or name not in known:
        raise ApiError(422, code,
                       f"unknown {field} {name!r}; known: {known}",
                       field=f"{field}.name")


class StudySpec(Schema):
    """Everything that unambiguously defines a study (paper sec. 2)."""

    NAME = "StudySpec"
    FIELDS = (
        Field("name", "str", default="unnamed", doc="study display name"),
        Field("properties", "dict", default={},
              doc="hyperparameter name -> space spec (or constant)"),
        Field("direction", "str", default="minimize", choices=_DIRECTIONS),
        Field("sampler", "dict", default={"name": "tpe"},
              doc="sampler spec, e.g. {'name': 'tpe'}"),
        Field("pruner", "dict", default={"name": "none"},
              doc="pruner spec, e.g. {'name': 'median'}"),
        Field("directions", "list", nullable=True, item_kind="str",
              choices=_DIRECTIONS,
              doc="per-objective directions (multi-objective studies)"),
        Field("worker_id", "str", nullable=True,
              doc="identity of the asking worker (defaults to the token user)"),
    )

    @classmethod
    def post_validate(cls, out: dict[str, Any]) -> None:
        _check_registry_name(out["sampler"], "sampler", "tpe",
                             known_samplers(), "unknown_sampler")
        _check_registry_name(out["pruner"], "pruner", "none",
                             known_pruners(), "unknown_pruner")
        for key in ("properties", "sampler", "pruner"):
            _require_finite_tree(out[key], key)


class AskRequest(Schema):
    """Body of ``POST /api/v2/studies/{key}/trials:ask``."""

    NAME = "AskRequest"
    FIELDS = (
        Field("worker_id", "str", nullable=True),
        Field("parallelism", "int", nullable=True, min_value=1,
              max_value=4096,
              doc="worker-fleet size hint: the speculative precompute "
                  "sizes its proposal buffer to cover one wave of this "
                  "many concurrent asks (ignored when speculation is "
                  "disabled)"),
    )


class AskBatchRequest(Schema):
    """Body of ``POST /api/v2/studies/{key}/trials:ask_batch``."""

    NAME = "AskBatchRequest"
    FIELDS = (
        Field("n", "int", default=1, min_value=1, max_value=4096,
              doc="number of trials to suggest in one round trip"),
        Field("worker_id", "str", nullable=True),
        Field("parallelism", "int", nullable=True, min_value=1,
              max_value=4096,
              doc="worker-fleet size hint: the speculative precompute "
                  "sizes its proposal buffer to cover one wave of this "
                  "many concurrent asks (ignored when speculation is "
                  "disabled)"),
    )


class TellBody(Schema):
    """Body of ``POST /api/v2/trials/{uid}:tell`` (uid in the path)."""

    NAME = "TellBody"
    FIELDS = (
        Field("value", "number_or_list", nullable=True,
              doc="final objective value (list = one per objective)"),
        Field("state", "str", default="completed", choices=_TELL_STATES),
        Field("idempotency_key", "str", nullable=True,
              doc="client-generated key, constant across retries of the "
                  "same logical tell; the server replays the original "
                  "result instead of double-applying (exactly-once)"),
    )

    @classmethod
    def post_validate(cls, out: dict[str, Any]) -> None:
        value = out.get("value")
        if isinstance(value, list):
            if not value:
                raise ApiError(422, "invalid_value",
                               "field 'value' must not be an empty list",
                               field="value")
            for i, item in enumerate(value):
                _require_finite(item, f"value[{i}]")
        else:
            _require_finite(value, "value")


class ReportBody(Schema):
    """Body of ``POST /api/v2/trials/{uid}:report`` — an intermediate
    value report doubling as the lease heartbeat (v1 ``should_prune``)."""

    NAME = "ReportBody"
    FIELDS = (
        Field("step", "int", default=0, min_value=0),
        Field("value", "number", default=0.0),
    )

    @classmethod
    def post_validate(cls, out: dict[str, Any]) -> None:
        _require_finite(out.get("value"), "value")


class TellItem(TellBody):
    """One element of a batched tell (uid carried inline)."""

    NAME = "TellItem"
    FIELDS = (Field("trial_uid", "str", required=True),) + TellBody.FIELDS


class TellBatchRequest(Schema):
    """Body of ``POST /api/v2/trials:tell_batch`` (and v1 tell_batch)."""

    NAME = "TellBatchRequest"
    FIELDS = (
        Field("tells", "list", required=True, item_kind="dict"),
    )

    @classmethod
    def post_validate(cls, out: dict[str, Any]) -> None:
        cleaned = []
        for i, item in enumerate(out["tells"]):
            try:
                cleaned.append(TellItem.validate(item))
            except ApiError as e:
                raise ApiError(e.status, e.code, f"tells[{i}]: {e.message}",
                               field=f"tells[{i}].{e.field or '$'}")
        out["tells"] = cleaned


# -- v1 request bodies (token in path, spec inline) -----------------------
class V1AskRequest(StudySpec):
    NAME = "V1AskRequest"


class V1AskBatchRequest(StudySpec):
    NAME = "V1AskBatchRequest"
    FIELDS = StudySpec.FIELDS + (
        Field("n", "int", default=1, min_value=1, max_value=4096),
    )


class V1TellRequest(TellItem):
    NAME = "V1TellRequest"


class V1ReportRequest(ReportBody):
    """v1 ``should_prune`` body — inherits the finite-value check."""
    NAME = "V1ReportRequest"
    FIELDS = (Field("trial_uid", "str", required=True),) + ReportBody.FIELDS


# -- response shapes (documentation only; emitted, never parsed) ----------
class TrialResource(Schema):
    NAME = "TrialResource"
    FIELDS = (
        Field("uid", "str", required=True),
        Field("trial_id", "int", required=True),
        Field("study_key", "str", required=True),
        Field("params", "dict", required=True),
        Field("state", "str", required=True,
              choices=["running", "completed", "pruned", "failed"]),
        Field("value", "number", nullable=True),
        Field("values", "list", nullable=True, item_kind="number"),
        Field("worker_id", "str", nullable=True),
        Field("retries", "int"),
        Field("last_step", "int"),
        Field("created_at", "number"),
        Field("finished_at", "number", nullable=True),
    )


class StudyResource(Schema):
    NAME = "StudyResource"
    FIELDS = (
        Field("key", "str", required=True),
        Field("name", "str", required=True),
        Field("n_trials", "int", required=True),
        Field("n_completed", "int", required=True),
        Field("n_pruned", "int", required=True),
        Field("n_failed", "int", required=True),
        Field("best_value", "number", nullable=True),
        Field("best_params", "dict", nullable=True),
        Field("n_running", "int"),
        Field("direction", "str", choices=_DIRECTIONS),
        Field("directions", "list", nullable=True, item_kind="str"),
        Field("sampler", "str"),
        Field("pruner", "str"),
        Field("data_version", "int",
              doc="storage shard mutation counter — equal versions mean "
                  "nothing changed; replayed identically across recovery"),
        Field("pareto_front", "list", nullable=True, item_kind="dict",
              doc="multi-objective studies only"),
    )


class StudyEnvelope(Schema):
    NAME = "StudyEnvelope"
    FIELDS = (
        Field("study", "dict", required=True, doc="a StudyResource"),
        Field("created", "bool"),
    )


class TrialEnvelope(Schema):
    NAME = "TrialEnvelope"
    FIELDS = (Field("trial", "dict", required=True, doc="a TrialResource"),)


class TrialPage(Schema):
    NAME = "TrialPage"
    FIELDS = (
        Field("trials", "list", required=True, item_kind="dict"),
        Field("next_cursor", "int", nullable=True,
              doc="pass as ?cursor= to fetch the next page; null = done"),
    )


class StudyPage(Schema):
    NAME = "StudyPage"
    FIELDS = (
        Field("studies", "list", required=True, item_kind="dict"),
        Field("next_cursor", "int", nullable=True),
    )


class AskBatchResponse(Schema):
    NAME = "AskBatchResponse"
    FIELDS = (
        Field("trials", "list", required=True, item_kind="dict"),
        Field("study_key", "str", required=True),
    )


class TellResponse(Schema):
    NAME = "TellResponse"
    FIELDS = (
        Field("uid", "str", required=True),
        Field("state", "str", required=True),
    )


class TellBatchResponse(Schema):
    NAME = "TellBatchResponse"
    FIELDS = (
        Field("results", "list", required=True, item_kind="dict",
              doc="per-item {status, uid, state|error}; one bad item never "
                  "fails the batch"),
    )


class ReportResponse(Schema):
    NAME = "ReportResponse"
    FIELDS = (
        Field("uid", "str", required=True),
        Field("should_prune", "bool", required=True),
        Field("note", "str", nullable=True,
              doc="set when the verdict comes from a revoked lease"),
    )


class VersionResponse(Schema):
    NAME = "VersionResponse"
    FIELDS = (
        Field("version", "str", required=True),
        Field("storage", "dict", nullable=True,
              doc="storage backend + durability stats (v2 only): backend, "
                  "fsync mode, snapshot/segment layout, WAL counters, "
                  "last recovery summary"),
    )


class HealthResponse(Schema):
    NAME = "HealthResponse"
    FIELDS = (
        Field("status", "str", required=True,
              choices=["ok", "follower", "fenced"],
              doc="ok = accepting writes; follower/fenced = redirect "
                  "(the fabric routes around non-leaders automatically)"),
        Field("version", "str", required=True),
        Field("worker", "str", required=True),
        Field("role", "str", required=True, choices=["leader", "follower"]),
        Field("epoch", "int", required=True,
              doc="leadership lease epoch (0 = never replicated)"),
        Field("replication", "dict", nullable=True,
              doc="mode, stream position, per-follower lag in "
                  "records/bytes (leaders) or sync status (followers)"),
        Field("storage", "dict", nullable=True,
              doc="WAL/fsync stats subset (backend, fsync mode, wal "
                  "records/bytes, fsyncs, group commits)"),
        Field("speculation", "dict", nullable=True,
              doc="speculative ask pipeline counters: queue hit/stale/"
                  "miss, published buffers, pending-trial count, "
                  "precompute rounds/errors"),
        Field("workers", "list", nullable=True, item_kind="dict",
              doc="fabric router only: per-worker health"),
    )


class ErrorEnvelope(Schema):
    NAME = "ErrorEnvelope"
    FIELDS = (
        Field("error", "dict", required=True,
              doc="{code, message, field?} — stable machine-readable shape"),
        Field("detail", "str", doc="mirror of error.message (v1 consumers)"),
    )
