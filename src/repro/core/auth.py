"""API-token authentication.

The paper (sec. 3) authenticates API calls with user-generated tokens
carried in the request path (``/api/ask/<token>``); each token has a
validity period defined at generation and can be revoked at any time.
Tokens here are HMAC-signed, self-describing strings so that stateless
server workers can verify them with only the shared secret, while
revocation is tracked in shared state.
"""
from __future__ import annotations

import base64
import hashlib
import hmac
import json
import threading
import time
import uuid


class AuthError(Exception):
    pass


class TokenManager:
    def __init__(self, secret: str = "hopaas-secret"):
        self._secret = secret.encode()
        self._revoked: set[str] = set()
        self._lock = threading.Lock()

    # -- issue ------------------------------------------------------------
    def issue(self, user: str, ttl_seconds: float = 30 * 24 * 3600.0) -> str:
        payload = {"user": user, "exp": time.time() + ttl_seconds,
                   "jti": uuid.uuid4().hex[:12]}
        body = base64.urlsafe_b64encode(json.dumps(payload).encode()).decode().rstrip("=")
        sig = self._sign(body)
        return f"{body}.{sig}"

    def _sign(self, body: str) -> str:
        return hmac.new(self._secret, body.encode(), hashlib.sha256).hexdigest()[:24]

    # -- verify -------------------------------------------------------------
    def verify(self, token: str) -> dict:
        try:
            body, sig = token.rsplit(".", 1)
        except ValueError:
            raise AuthError("malformed token")
        if not hmac.compare_digest(sig, self._sign(body)):
            raise AuthError("bad signature")
        pad = "=" * (-len(body) % 4)
        payload = json.loads(base64.urlsafe_b64decode(body + pad))
        if payload["exp"] < time.time():
            raise AuthError("token expired")
        with self._lock:
            if payload["jti"] in self._revoked:
                raise AuthError("token revoked")
        return payload

    def revoke(self, token: str) -> None:
        body, _ = token.rsplit(".", 1)
        pad = "=" * (-len(body) % 4)
        payload = json.loads(base64.urlsafe_b64decode(body + pad))
        with self._lock:
            self._revoked.add(payload["jti"])
