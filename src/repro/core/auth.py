"""API-token authentication.

The paper (sec. 3) authenticates API calls with user-generated tokens
carried in the request path (``/api/ask/<token>``); each token has a
validity period defined at generation and can be revoked at any time.
Tokens here are HMAC-signed, self-describing strings so that stateless
server workers can verify them with only the shared secret, while
revocation is tracked in shared state.
"""
from __future__ import annotations

import base64
import binascii
import hashlib
import hmac
import json
import threading
import time
import uuid


class AuthError(Exception):
    pass


def bearer_token(headers: dict) -> str | None:
    """The token of an ``Authorization: Bearer <token>`` header, else
    None (header missing, non-bearer scheme, or empty token).

    The single bearer-parsing policy: both the router's auth step and
    the event-loop frontend's response-cache probe go through this, so
    they can never drift apart.
    """
    header = next((v for k, v in headers.items()
                   if k.lower() == "authorization"), None)
    if header is None:
        return None
    scheme, _, token = header.partition(" ")
    if scheme.lower() != "bearer" or not token.strip():
        return None
    return token.strip()


class TokenManager:
    # verified-signature memo cap: a service sees few distinct tokens
    _VERIFY_CACHE_MAX = 1024

    def __init__(self, secret: str = "hopaas-secret"):
        self._secret = secret.encode()
        self._revoked: set[str] = set()
        self._lock = threading.Lock()
        # token -> payload for tokens whose signature already checked
        # out; expiry and revocation are still enforced on every call
        # (only the HMAC + base64/JSON decode are amortized)
        self._verified: dict[str, dict] = {}

    # -- issue ------------------------------------------------------------
    def issue(self, user: str, ttl_seconds: float = 30 * 24 * 3600.0) -> str:
        payload = {"user": user, "exp": time.time() + ttl_seconds,
                   "jti": uuid.uuid4().hex[:12]}
        body = base64.urlsafe_b64encode(json.dumps(payload).encode()).decode().rstrip("=")
        sig = self._sign(body)
        return f"{body}.{sig}"

    def _sign(self, body: str) -> str:
        return hmac.new(self._secret, body.encode(), hashlib.sha256).hexdigest()[:24]

    @staticmethod
    def _split(token: str) -> tuple[str, str]:
        try:
            body, sig = token.rsplit(".", 1)
        except (ValueError, AttributeError):
            raise AuthError("malformed token")
        return body, sig

    @staticmethod
    def _decode_payload(body: str) -> dict:
        """Decode a token body -> payload dict.  Every decode failure —
        bad base64, bad JSON, non-object payload, missing/ill-typed
        claims — surfaces as ``AuthError``, never a raw ``ValueError`` /
        ``binascii.Error`` (which the wire layer would turn into a 500
        instead of a 401)."""
        pad = "=" * (-len(body) % 4)
        try:
            payload = json.loads(base64.urlsafe_b64decode(body + pad))
        except (ValueError, binascii.Error):
            raise AuthError("malformed token body")
        if not isinstance(payload, dict) \
                or not isinstance(payload.get("exp"), (int, float)) \
                or not isinstance(payload.get("jti"), str):
            raise AuthError("malformed token body")
        return payload

    # -- verify -------------------------------------------------------------
    def verify(self, token: str) -> dict:
        payload = self._verified.get(token)
        if payload is None:
            body, sig = self._split(token)
            if not hmac.compare_digest(sig, self._sign(body)):
                raise AuthError("bad signature")
            payload = self._decode_payload(body)
            with self._lock:
                if len(self._verified) >= self._VERIFY_CACHE_MAX:
                    self._verified.pop(next(iter(self._verified)))
                self._verified[token] = payload
        if payload["exp"] < time.time():
            raise AuthError("token expired")
        with self._lock:
            if payload["jti"] in self._revoked:
                raise AuthError("token revoked")
        return payload

    def revoke(self, token: str) -> None:
        body, _sig = self._split(token)
        payload = self._decode_payload(body)
        with self._lock:
            self._revoked.add(payload["jti"])
