"""HOPAAS core — the paper's primary contribution.

Hyperparameter OPtimization As A Service: a client/server protocol
(`ask` / `tell` / `should_prune` / `version`, plus the batched
`ask_batch` / `tell_batch` extension) coordinating gradient-less
optimization studies across heterogeneous, elastic compute.  The service
core is sharded per study (see ``server.StudyContext``): requests for
different studies never contend on a common lock.
"""
from .auth import AuthError, TokenManager
from .client import Client, HopaasError, Study as ClientStudy, Trial as ClientTrial, suggestions
from .obs_cache import ObservationCache
from .campaign import CampaignResult, run_campaign
from .pruners import make_pruner
from .report import convergence_trace, format_report, study_summary
from .samplers import make_sampler
from .server import HOPAAS_VERSION, HopaasServer, StudyContext
from .space import Param, SearchSpace
from .storage import InMemoryStorage, JournalStorage
from .transport import (DirectTransport, HttpServiceRunner, HttpTransport,
                        RoundRobinTransport, Transport)
from .types import Direction, Study, StudyConfig, Trial, TrialState

__all__ = [
    "AuthError", "TokenManager", "Client", "HopaasError", "ClientStudy",
    "ClientTrial", "suggestions", "CampaignResult", "run_campaign",
    "make_pruner", "convergence_trace", "format_report", "study_summary",
    "make_sampler", "HOPAAS_VERSION", "HopaasServer", "StudyContext",
    "ObservationCache", "Param", "SearchSpace",
    "InMemoryStorage", "JournalStorage", "DirectTransport",
    "HttpServiceRunner", "HttpTransport", "RoundRobinTransport", "Transport",
    "Direction", "Study", "StudyConfig", "Trial", "TrialState",
]
