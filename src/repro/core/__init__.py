"""HOPAAS core — the paper's primary contribution.

Hyperparameter OPtimization As A Service: a client/server protocol
coordinating gradient-less optimization studies across heterogeneous,
elastic compute.  The wire layer is a versioned, resource-oriented REST
surface (``repro.core.api``): typed schemas validated at the boundary, a
declarative router, bearer-header auth, and paginated monitoring
endpoints — with the paper's original RPC endpoints (`ask` / `tell` /
`should_prune` / `version`, plus the batched `ask_batch` / `tell_batch`
extension) mounted as a byte-compatible v1 shim over the same core.
The service core is sharded per study (see ``server.StudyContext``):
requests for different studies never contend on a common lock.
"""
from .api import ApiError, Route, Router, build_openapi, build_router
from .auth import AuthError, TokenManager
from .client import (Client, HopaasError, RetryPolicy, Study as ClientStudy,
                     Trial as ClientTrial, suggestions)
from .obs_cache import ObservationCache
from .campaign import CampaignResult, run_campaign
from .pruners import known_pruners, make_pruner
from .report import convergence_trace, format_report, study_summary
from .samplers import known_samplers, make_sampler
from .server import HOPAAS_VERSION, HopaasServer, StudyContext
from .space import Param, SearchSpace
from .durable import DurableStorage, FsyncMode, WalDirectoryLockedError
from .fabric import FabricDispatcher, HashRing, ShardFabric
from .faults import FaultInjector
from .replication import (ReplicationClient, ReplicationError,
                          ReplicationHub, recover_dir_state,
                          reconcile_with)
from .storage import CorruptJournalError, InMemoryStorage, JournalStorage
from .transport import (DirectTransport, HttpServiceRunner, HttpTransport,
                        PooledHttpTransport, RoundRobinTransport,
                        ShardedHttpTransport, Transport)
from .types import Direction, Study, StudyConfig, Trial, TrialState

__all__ = [
    "ApiError", "Route", "Router", "build_openapi", "build_router",
    "AuthError", "TokenManager", "Client", "HopaasError", "RetryPolicy",
    "ClientStudy", "ClientTrial", "suggestions", "CampaignResult",
    "run_campaign", "make_pruner", "known_pruners", "convergence_trace",
    "format_report", "study_summary", "make_sampler", "known_samplers",
    "HOPAAS_VERSION", "HopaasServer", "StudyContext",
    "ObservationCache", "Param", "SearchSpace",
    "CorruptJournalError", "DurableStorage", "FsyncMode",
    "WalDirectoryLockedError", "FabricDispatcher", "HashRing",
    "ShardFabric", "FaultInjector", "ReplicationClient",
    "ReplicationError", "ReplicationHub", "recover_dir_state",
    "reconcile_with", "InMemoryStorage", "JournalStorage", "DirectTransport",
    "HttpServiceRunner", "HttpTransport", "PooledHttpTransport",
    "RoundRobinTransport", "ShardedHttpTransport", "Transport",
    "Direction", "Study", "StudyConfig", "Trial", "TrialState",
]
