"""HOPAAS service launcher — the INFN-Cloud deployment in one process.

Starts N stateless server workers behind the threaded HTTP frontend
(Uvicorn x N + NGINX role), backed by a WAL-journaled storage
(PostgreSQL role) that survives restarts, and prints a fresh API token.
Workers share per-study storage shards, so requests for different
studies run in parallel; clients may use the batched `ask_batch` /
`tell_batch` endpoints (see README.md, "Wire protocol").

  PYTHONPATH=src python -m repro.core.service --port 8731 \
      --workers 4 --journal hopaas.wal
"""
from __future__ import annotations

import argparse
import time

from .auth import TokenManager
from .server import HopaasServer
from .storage import InMemoryStorage, JournalStorage
from .transport import HttpServiceRunner


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--port", type=int, default=8731)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--workers", type=int, default=2,
                    help="stateless API workers sharing one storage")
    ap.add_argument("--journal", default=None,
                    help="WAL path for crash-restartable storage")
    ap.add_argument("--lease-seconds", type=float, default=60.0)
    ap.add_argument("--token-ttl-hours", type=float, default=24.0)
    args = ap.parse_args()

    storage = (JournalStorage(args.journal) if args.journal
               else InMemoryStorage())
    tokens = TokenManager()
    workers = [HopaasServer(storage=storage, tokens=tokens,
                            lease_seconds=args.lease_seconds,
                            worker_name=f"api-{i}")
               for i in range(args.workers)]
    runner = HttpServiceRunner(workers, host=args.host,
                               port=args.port).start()
    token = tokens.issue("cli-user", ttl_seconds=args.token_ttl_hours * 3600)
    print(f"HOPAAS service at {runner.url}  ({args.workers} workers, "
          f"storage={'journal:' + args.journal if args.journal else 'memory'})")
    print(f"API token: {token}")
    print("Ctrl-C to stop.")
    try:
        while True:
            time.sleep(3600)
    except KeyboardInterrupt:
        runner.stop()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
