"""HOPAAS service launcher — the INFN-Cloud deployment shape.

Single process by default: N stateless API workers behind the HTTP
frontend (Uvicorn x N + NGINX role) — the selector event loop with
sharded dispatch lanes, ``--frontend threaded`` for the legacy
thread-per-connection server — backed by a durable storage engine
(PostgreSQL role) that survives crashes and restarts, and prints a
fresh API token.

``--workers N`` (N > 1, or ``REPRO_WORKERS=N``) launches the
multi-process shard fabric instead (``repro.core.fabric``): N worker
processes, each owning a consistent-hash slice of the study shards
with a private WAL directory, fronted by the consistent-hash router;
dead workers are respawned on their WAL with digest-verified recovery.

  PYTHONPATH=src python -m repro.core.service --port 8731 \
      --workers 4 --journal-dir hopaas-data --fsync group

``--journal-dir`` selects the snapshot + segmented-WAL engine
(``DurableStorage``); ``--journal FILE`` keeps the legacy single-file
JSONL journal.  ``--fsync`` picks the durability/latency trade-off:
``always`` (ack after fsync, group-committed), ``group`` (one fsync per
commit window), ``off`` (no fsync).  The journal is closed cleanly on
Ctrl-C *and* via ``atexit``, so the buffered WAL tail is never dropped
by a normal shutdown path.
"""
from __future__ import annotations

import argparse
import atexit
import os
import time

from .auth import TokenManager
from .durable import DurableStorage
from .server import HopaasServer
from .storage import InMemoryStorage, JournalStorage
from .transport import HttpServiceRunner


def build_storage(args: argparse.Namespace) -> InMemoryStorage:
    if args.journal_dir:
        return DurableStorage(args.journal_dir, fsync=args.fsync,
                              segment_bytes=args.segment_bytes,
                              auto_compact=not args.no_compaction)
    if args.journal:
        return JournalStorage(args.journal)
    return InMemoryStorage()


def _default_workers() -> int:
    try:
        return max(1, int(os.environ.get("REPRO_WORKERS", "1") or 1))
    except ValueError:
        return 1


def _run_fabric(args: argparse.Namespace) -> int:
    from .fabric import ShardFabric
    fabric = ShardFabric(
        workers=args.workers, host=args.host, port=args.port,
        root=args.journal_dir,
        storage="durable" if args.journal_dir else "memory",
        fsync=args.fsync, segment_bytes=args.segment_bytes,
        lease_seconds=args.lease_seconds, lanes=args.lanes,
        replicas=args.replicas, replication=args.replication).start()
    atexit.register(fabric.stop)
    token = fabric.issue_token("cli-user",
                               ttl_seconds=args.token_ttl_hours * 3600)
    eps = ", ".join(f"{h}:{p}" for h, p in fabric.endpoints)
    print(f"HOPAAS fabric at {fabric.url}  ({args.workers} worker "
          f"processes, storage={fabric.storage_kind})")
    print(f"worker endpoints: {eps}")
    if fabric.replicas:
        health = fabric.health()
        roles = ", ".join(
            f"w{w['worker']}:{w.get('role', '?')}@e{w.get('epoch', 0)}"
            for w in health["workers"])
        print(f"replication: {fabric.replicas} follower(s) per shard, "
              f"mode={fabric.replication}  [{roles}]")
        print(f"health: GET {fabric.url}/api/v2/health")
    print(f"API token: {token}")
    print("Ctrl-C to stop.")
    try:
        while True:
            time.sleep(3600)
    except KeyboardInterrupt:
        pass
    finally:
        fabric.stop()
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--port", type=int, default=8731)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--workers", type=int, default=_default_workers(),
                    help="worker processes; > 1 launches the multi-process "
                         "shard fabric (default: $REPRO_WORKERS or 1)")
    ap.add_argument("--api-workers", type=int, default=2,
                    help="stateless API workers sharing one storage "
                         "(single-process mode)")
    ap.add_argument("--journal-dir", default=None,
                    help="storage-engine directory (snapshots + segmented "
                         "WAL + compaction); survives crash-restart")
    ap.add_argument("--journal", default=None,
                    help="legacy single-file JSONL WAL path")
    ap.add_argument("--fsync", choices=("always", "group", "off"),
                    default="group",
                    help="WAL durability: ack-after-fsync / one fsync per "
                         "commit window / never (default: group)")
    ap.add_argument("--segment-bytes", type=int, default=4 * 1024 * 1024,
                    help="rotate the WAL segment past this size")
    ap.add_argument("--no-compaction", action="store_true",
                    help="disable background folding of sealed segments "
                         "into snapshots")
    ap.add_argument("--frontend", choices=("evloop", "threaded"),
                    default=None,
                    help="HTTP frontend: selector event loop with sharded "
                         "dispatch lanes (default) or the legacy "
                         "thread-per-connection server; REPRO_FRONTEND "
                         "overrides the default")
    ap.add_argument("--lanes", type=int, default=None,
                    help="event-loop dispatch lanes (default: 2x cores, "
                         "capped at 8)")
    ap.add_argument("--lease-seconds", type=float, default=60.0)
    ap.add_argument("--token-ttl-hours", type=float, default=24.0)
    ap.add_argument("--replicas", type=int, default=None,
                    help="follower replicas per fabric worker; > 0 "
                         "enables WAL shipping + automatic failover "
                         "(default: $REPRO_REPLICAS or 0; needs "
                         "--journal-dir)")
    ap.add_argument("--replication", choices=("async", "semisync"),
                    default=None,
                    help="async: fsync ack never waits for followers; "
                         "semisync: acks additionally wait for one "
                         "follower ack (default: $REPRO_REPLICATION or "
                         "async)")
    ap.add_argument("--speculate-depth", type=int, default=None,
                    help="proposals to precompute off-lock per study "
                         "(constant-liar speculative ask pipeline); 0 "
                         "disables (default: $REPRO_SPECULATE or 0)")
    args = ap.parse_args(argv)

    if args.speculate_depth is not None:
        if args.speculate_depth < 0:
            ap.error("--speculate-depth must be >= 0")
        # the fabric's worker processes build their own HopaasServer and
        # read the depth from the environment, so export it before any
        # server (in-process or spawned) is constructed
        os.environ["REPRO_SPECULATE"] = str(args.speculate_depth)

    replicas = args.replicas
    if replicas is None:
        try:
            replicas = int(os.environ.get("REPRO_REPLICAS", "0") or 0)
        except ValueError:
            replicas = 0
    if args.workers > 1 or replicas > 0:
        if args.journal:
            ap.error("--journal (legacy single-file WAL) cannot back the "
                     "shard fabric; use --journal-dir")
        if args.frontend == "threaded":
            ap.error("the shard fabric requires the evloop frontend")
        if replicas > 0 and not args.journal_dir:
            ap.error("--replicas needs --journal-dir (only the durable "
                     "engine has a WAL stream to ship)")
        return _run_fabric(args)

    storage = build_storage(args)
    # a missed shutdown path (exception, sys.exit) must still flush the
    # WAL tail; close() is idempotent so the Ctrl-C path below is safe
    atexit.register(storage.close)
    tokens = TokenManager()
    workers = [HopaasServer(storage=storage, tokens=tokens,
                            lease_seconds=args.lease_seconds,
                            worker_name=f"api-{i}")
               for i in range(args.api_workers)]
    runner = HttpServiceRunner(workers, host=args.host, port=args.port,
                               backend=args.frontend,
                               lanes=args.lanes, workers=1).start()
    token = tokens.issue("cli-user", ttl_seconds=args.token_ttl_hours * 3600)
    backend = storage.storage_stats()["backend"]
    print(f"HOPAAS service at {runner.url}  ({args.api_workers} API "
          f"workers, frontend={runner.backend}, storage={backend})")
    print(f"API token: {token}")
    print("Ctrl-C to stop.")
    try:
        while True:
            time.sleep(3600)
    except KeyboardInterrupt:
        pass
    finally:
        runner.stop()            # also flushes the workers' storage
        storage.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
