"""HOPAAS service launcher — the INFN-Cloud deployment in one process.

Starts N stateless server workers behind the HTTP frontend (Uvicorn x N
+ NGINX role) — the selector event loop with sharded dispatch lanes by
default, ``--frontend threaded`` for the legacy thread-per-connection
server — backed by a durable storage engine (PostgreSQL role) that
survives crashes and restarts, and prints a fresh API token.  Workers
share per-study storage shards, so requests for different studies run
in parallel; clients may use the batched `ask_batch` / `tell_batch`
endpoints (see README.md, "Wire protocol").

  PYTHONPATH=src python -m repro.core.service --port 8731 \
      --workers 4 --journal-dir hopaas-data --fsync group

``--journal-dir`` selects the snapshot + segmented-WAL engine
(``DurableStorage``); ``--journal FILE`` keeps the legacy single-file
JSONL journal.  ``--fsync`` picks the durability/latency trade-off:
``always`` (ack after fsync, group-committed), ``group`` (one fsync per
commit window), ``off`` (no fsync).  The journal is closed cleanly on
Ctrl-C *and* via ``atexit``, so the buffered WAL tail is never dropped
by a normal shutdown path.
"""
from __future__ import annotations

import argparse
import atexit
import time

from .auth import TokenManager
from .durable import DurableStorage
from .server import HopaasServer
from .storage import InMemoryStorage, JournalStorage
from .transport import HttpServiceRunner


def build_storage(args: argparse.Namespace) -> InMemoryStorage:
    if args.journal_dir:
        return DurableStorage(args.journal_dir, fsync=args.fsync,
                              segment_bytes=args.segment_bytes,
                              auto_compact=not args.no_compaction)
    if args.journal:
        return JournalStorage(args.journal)
    return InMemoryStorage()


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--port", type=int, default=8731)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--workers", type=int, default=2,
                    help="stateless API workers sharing one storage")
    ap.add_argument("--journal-dir", default=None,
                    help="storage-engine directory (snapshots + segmented "
                         "WAL + compaction); survives crash-restart")
    ap.add_argument("--journal", default=None,
                    help="legacy single-file JSONL WAL path")
    ap.add_argument("--fsync", choices=("always", "group", "off"),
                    default="group",
                    help="WAL durability: ack-after-fsync / one fsync per "
                         "commit window / never (default: group)")
    ap.add_argument("--segment-bytes", type=int, default=4 * 1024 * 1024,
                    help="rotate the WAL segment past this size")
    ap.add_argument("--no-compaction", action="store_true",
                    help="disable background folding of sealed segments "
                         "into snapshots")
    ap.add_argument("--frontend", choices=("evloop", "threaded"),
                    default=None,
                    help="HTTP frontend: selector event loop with sharded "
                         "dispatch lanes (default) or the legacy "
                         "thread-per-connection server; REPRO_FRONTEND "
                         "overrides the default")
    ap.add_argument("--lanes", type=int, default=None,
                    help="event-loop dispatch lanes (default: 2x cores, "
                         "capped at 8)")
    ap.add_argument("--lease-seconds", type=float, default=60.0)
    ap.add_argument("--token-ttl-hours", type=float, default=24.0)
    args = ap.parse_args(argv)

    storage = build_storage(args)
    # a missed shutdown path (exception, sys.exit) must still flush the
    # WAL tail; close() is idempotent so the Ctrl-C path below is safe
    atexit.register(storage.close)
    tokens = TokenManager()
    workers = [HopaasServer(storage=storage, tokens=tokens,
                            lease_seconds=args.lease_seconds,
                            worker_name=f"api-{i}")
               for i in range(args.workers)]
    runner = HttpServiceRunner(workers, host=args.host, port=args.port,
                               backend=args.frontend,
                               lanes=args.lanes).start()
    token = tokens.issue("cli-user", ttl_seconds=args.token_ttl_hours * 3600)
    backend = storage.storage_stats()["backend"]
    print(f"HOPAAS service at {runner.url}  ({args.workers} workers, "
          f"frontend={runner.backend}, storage={backend})")
    print(f"API token: {token}")
    print("Ctrl-C to stop.")
    try:
        while True:
            time.sleep(3600)
    except KeyboardInterrupt:
        pass
    finally:
        runner.stop()            # also flushes the workers' storage
        storage.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
