"""Pruning strategies for early termination of non-promising trials
(the paper's ``should_prune`` API, sec. 2)."""
from __future__ import annotations

from typing import Any

from .base import Pruner, NonePruner
from .median import MedianPruner, PercentilePruner
from .sha import SuccessiveHalvingPruner
from .hyperband import HyperbandPruner
from .patient import PatientPruner

_REGISTRY = {
    "none": NonePruner,
    "median": MedianPruner,
    "percentile": PercentilePruner,
    "sha": SuccessiveHalvingPruner,
    "asha": SuccessiveHalvingPruner,
    "hyperband": HyperbandPruner,
    "patient": PatientPruner,
}


def known_pruners() -> list[str]:
    """Registered pruner names (used by the API schema validation)."""
    return sorted(_REGISTRY)


def make_pruner(spec: dict[str, Any]) -> Pruner:
    spec = dict(spec or {"name": "none"})
    name = spec.pop("name", "none")
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown pruner {name!r}; known: {sorted(_REGISTRY)}")
    return cls(**spec)


__all__ = ["Pruner", "make_pruner", "known_pruners", "NonePruner", "MedianPruner",
           "PercentilePruner", "SuccessiveHalvingPruner", "HyperbandPruner",
           "PatientPruner"]
