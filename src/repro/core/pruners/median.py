from __future__ import annotations

import numpy as np

from ..types import Study, Trial
from .base import Pruner


class PercentilePruner(Pruner):
    """Prune if the trial's intermediate is worse than the given percentile
    of other trials' intermediates at the same step (Optuna semantics)."""

    def __init__(self, percentile: float = 50.0, n_startup_trials: int = 4,
                 n_warmup_steps: int = 0, interval_steps: int = 1):
        self.percentile = float(percentile)
        self.n_startup_trials = int(n_startup_trials)
        self.n_warmup_steps = int(n_warmup_steps)
        self.interval_steps = max(int(interval_steps), 1)

    def should_prune(self, study: Study, trial: Trial, step: int) -> bool:
        if step < self.n_warmup_steps:
            return False
        if (step - self.n_warmup_steps) % self.interval_steps != 0:
            return False
        sign = self._sign(study)
        # competitors: every other trial that reported at `step`, read from
        # the study's incremental per-step report index (maintained on
        # report under the shard lock) — no scan over the trial list
        others = [sign * v for uid, v in study.reports_at(step).items()
                  if uid != trial.uid]
        if len(others) < self.n_startup_trials:
            return False
        threshold = float(np.percentile(others, self.percentile))
        # best value this trial has achieved up to `step` (noise-robust)
        mine = min(sign * v for s, v in trial.intermediates.items() if s <= step)
        return mine > threshold


class MedianPruner(PercentilePruner):
    """Prune if worse than the median of other trials at the same step
    (Optuna's default pruner)."""

    def __init__(self, n_startup_trials: int = 4, n_warmup_steps: int = 0,
                 interval_steps: int = 1):
        super().__init__(percentile=50.0, n_startup_trials=n_startup_trials,
                         n_warmup_steps=n_warmup_steps, interval_steps=interval_steps)
