from __future__ import annotations

import numpy as np

from ..types import Study, Trial, TrialState
from .base import Pruner


class PercentilePruner(Pruner):
    """Prune if the trial's intermediate is worse than the given percentile
    of other trials' intermediates at the same step (Optuna semantics)."""

    def __init__(self, percentile: float = 50.0, n_startup_trials: int = 4,
                 n_warmup_steps: int = 0, interval_steps: int = 1):
        self.percentile = float(percentile)
        self.n_startup_trials = int(n_startup_trials)
        self.n_warmup_steps = int(n_warmup_steps)
        self.interval_steps = max(int(interval_steps), 1)

    def should_prune(self, study: Study, trial: Trial, step: int) -> bool:
        if step < self.n_warmup_steps:
            return False
        if (step - self.n_warmup_steps) % self.interval_steps != 0:
            return False
        sign = self._sign(study)
        # competitors: trials (finished or further along) that reported at `step`
        others = []
        for t in study.trials:
            if t.uid == trial.uid or step not in t.intermediates:
                continue
            if t.state in (TrialState.COMPLETED, TrialState.PRUNED) or t.last_step() >= step:
                others.append(sign * t.intermediates[step])
        if len(others) < self.n_startup_trials:
            return False
        threshold = float(np.percentile(others, self.percentile))
        # best value this trial has achieved up to `step` (noise-robust)
        mine = min(sign * v for s, v in trial.intermediates.items() if s <= step)
        return mine > threshold


class MedianPruner(PercentilePruner):
    """Prune if worse than the median of other trials at the same step
    (Optuna's default pruner)."""

    def __init__(self, n_startup_trials: int = 4, n_warmup_steps: int = 0,
                 interval_steps: int = 1):
        super().__init__(percentile=50.0, n_startup_trials=n_startup_trials,
                         n_warmup_steps=n_warmup_steps, interval_steps=interval_steps)
