from __future__ import annotations

from ..types import Study, Trial
from .base import Pruner


class PatientPruner(Pruner):
    """Prune when a trial hasn't improved its own best intermediate for
    ``patience`` consecutive reports (plateau detection — useful for the
    GAN workloads of paper sec. 4 whose losses are noisy)."""

    def __init__(self, patience: int = 8, min_delta: float = 0.0):
        self.patience = int(patience)
        self.min_delta = float(min_delta)

    def should_prune(self, study: Study, trial: Trial, step: int) -> bool:
        sign = self._sign(study)
        hist = sorted(trial.intermediates.items())
        if len(hist) <= self.patience:
            return False
        vals = [sign * v for _, v in hist]
        best_before = min(vals[: -self.patience])
        recent = min(vals[-self.patience:])
        # no strict improvement over the pre-window best => plateau => prune
        return recent >= best_before - self.min_delta
