from __future__ import annotations

import math

import numpy as np

from ..types import Study, Trial
from .base import Pruner


class SuccessiveHalvingPruner(Pruner):
    """Asynchronous successive halving (ASHA, Li et al. 2018).

    Rungs sit at ``min_resource * reduction_factor**k`` steps.  At each rung
    a trial survives only if its value is within the top ``1/reduction_factor``
    of everything that has reached that rung so far.  Asynchronous: decisions
    never wait for a full cohort — exactly what a multi-site opportunistic
    campaign needs (stragglers can't block promotions).
    """

    def __init__(self, min_resource: int = 1, reduction_factor: int = 3,
                 min_early_stopping_rate: int = 0):
        self.min_resource = max(int(min_resource), 1)
        self.rf = max(int(reduction_factor), 2)
        self.s = int(min_early_stopping_rate)

    def rung_of(self, step: int) -> int | None:
        """Largest rung index k with resource(k) <= step+1, or None."""
        r = self.min_resource * self.rf ** self.s
        if step + 1 < r:
            return None
        return int(math.floor(math.log((step + 1) / r, self.rf)))

    def rung_resource(self, k: int) -> int:
        return self.min_resource * self.rf ** (self.s + k)

    def should_prune(self, study: Study, trial: Trial, step: int) -> bool:
        k = self.rung_of(step)
        if k is None:
            return False
        sign = self._sign(study)
        resource = self.rung_resource(k)
        # value of a trial "at rung k" = best intermediate within the
        # resource, read from the study's incremental rung snapshot
        # (maintained per report under the shard lock) — heartbeats no
        # longer rescan every trial's intermediates
        mine = study.rung_value(trial.uid, resource, sign)
        if mine is None:
            return False
        # competitors: other trials that *reached* the rung
        others = study.rung_competitors(resource, sign, trial.uid)
        if len(others) < self.rf - 1:
            return False         # not enough rung population yet
        cutoff = float(np.percentile(others, 100.0 / self.rf))
        return mine > cutoff
