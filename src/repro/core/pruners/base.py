from __future__ import annotations

import abc

from ..types import Direction, Study, Trial


class Pruner(abc.ABC):
    """Decides whether a RUNNING trial should be early-terminated.

    ``trial.intermediates`` already contains the just-reported (step, value)
    when ``should_prune`` is called.  Values are normalized to minimization
    internally (sign-flip for maximize studies).
    """

    @abc.abstractmethod
    def should_prune(self, study: Study, trial: Trial, step: int) -> bool:
        ...

    @staticmethod
    def _sign(study: Study) -> float:
        return 1.0 if study.config.direction == Direction.MINIMIZE else -1.0


class NonePruner(Pruner):
    def should_prune(self, study: Study, trial: Trial, step: int) -> bool:
        return False
