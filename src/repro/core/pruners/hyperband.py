from __future__ import annotations

import hashlib

from ..types import Study, Trial
from .base import Pruner
from .sha import SuccessiveHalvingPruner


class HyperbandPruner(Pruner):
    """Hyperband (Li et al. 2017): a portfolio of SHA brackets with
    different early-stopping aggressiveness; each trial is deterministically
    hashed to a bracket so all service workers agree without coordination."""

    def __init__(self, min_resource: int = 1, max_resource: int = 81,
                 reduction_factor: int = 3):
        self.brackets: list[SuccessiveHalvingPruner] = []
        s = 0
        r = min_resource
        while r <= max_resource:
            self.brackets.append(SuccessiveHalvingPruner(
                min_resource=min_resource, reduction_factor=reduction_factor,
                min_early_stopping_rate=s))
            s += 1
            r *= reduction_factor

    def bracket_of(self, trial: Trial) -> SuccessiveHalvingPruner:
        h = int(hashlib.sha1(trial.uid.encode()).hexdigest(), 16)
        return self.brackets[h % len(self.brackets)]

    def should_prune(self, study: Study, trial: Trial, step: int) -> bool:
        return self.bracket_of(trial).should_prune(study, trial, step)
