"""Shared persistency layer, sharded per study.

The paper's reference implementation uses a PostgreSQL instance to give
*shared persistency to the multiple instances of the web application
backend* (sec. 3).  Here the same role is played by a storage object that
multiple ``HopaasServer`` workers share.  Internally the store is split
into per-study shards (``_StudyShard``): each shard owns its own lock,
an O(1) ``uid -> Trial`` index, per-state uid buckets, a min-heap of
lease deadlines, and the requeue queue.  Requests touching different
studies therefore never contend on a common lock; only study *creation*
takes the (short) registry lock.

Lease bookkeeping is heap-based: every ``add_trial``/lease renewal pushes
a ``(deadline, uid)`` entry, and ``pop_expired`` pops only entries whose
deadline has lapsed, discarding stale entries lazily (a renewal leaves the
superseded entry in the heap; it is dropped when popped because the
trial's *current* deadline is newer).  Sweeps are O(expired · log n)
instead of a full scan of every trial of every study.

Read-side acceleration: every shard carries a mutation ``version``
counter, an append-only ``completed_log`` of trials that became
observations (consumed incrementally by per-study ``ObservationCache``s
so `ask` never rescans the history), and an incrementally raced
incumbent (``best_trial`` is O(1), no scan).  Intermediate reports feed
the study's per-step / per-rung indices (see ``types.Study``) so pruner
heartbeats aggregate without walking the trial list.

An optional append-only JSONL write-ahead journal (``JournalStorage``)
provides crash-restart recovery: every mutation is journaled under the
owning shard's lock (so per-study order is preserved) before being
acknowledged, and ``replay`` reconstructs the full state — including the
indices, lease heap, completion log, and incumbent — from the log.
Replay tolerates exactly one torn (incomplete) final record — the
signature of a crash mid-append — by truncating it with a warning;
corruption anywhere else raises ``CorruptJournalError``.

``repro.core.durable.DurableStorage`` builds the full storage engine on
these primitives: point-in-time snapshots (``state_record`` /
``load_state``), a segmented WAL with group-commit fsync, and background
compaction.  ``state_digest`` is the shared equality witness: two stores
with the same digest hold index-for-index identical state (trials,
lease deadlines, completion log, incumbent, waiting queue, version
counters).
"""
from __future__ import annotations

import hashlib
import heapq
import json
import logging
import math
import os
import threading
from collections import deque
from typing import Any, Callable

from .types import Direction, Study, StudyConfig, Trial, TrialState

logger = logging.getLogger("repro.storage")


class CorruptJournalError(RuntimeError):
    """A journal/segment holds an unreadable record somewhere other than
    the torn tail of the final append — replay cannot proceed safely."""


def load_journal_file(path: str, apply: Callable[[dict[str, Any]], None], *,
                      tolerate_torn_tail: bool = True,
                      repair: bool = True) -> tuple[int, bool]:
    """Stream one JSONL journal file through ``apply``, one record at a
    time (memory stays O(longest line), never O(file) — legacy journals
    grow without bound).  Returns ``(n_records_applied, torn_tail_found)``.

    A *torn tail* is an unparseable final line with no trailing newline —
    exactly what a crash mid-``write`` leaves behind (records are written
    as single ``line + "\\n"`` appends, so a partial write can never
    contain the newline).  With ``repair`` the torn bytes are truncated
    from the file so the next append starts on a clean boundary; a
    parseable-but-unterminated final record is kept and newline-
    terminated.  An unparseable line anywhere else (or a newline-
    terminated garbage tail) is corruption, not a torn append, and
    raises ``CorruptJournalError``.
    """
    n = 0
    clean = 0            # byte offset of the last good record boundary
    pos = 0
    last_raw = b""
    bad: tuple[int, bytes, str] | None = None    # (offset, line, json msg)
    with open(path, "rb") as f:
        for raw in f:
            if bad is not None:
                # anything after the failed line (even a blank) proves it
                # was newline-terminated — corruption, not a torn append
                raise CorruptJournalError(
                    f"corrupt journal record in {path} at byte "
                    f"{bad[0]}: {bad[2]}")
            line = raw.strip()
            if line:
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError as e:
                    bad = (pos, raw, e.msg)
                    pos += len(raw)
                    continue
                apply(rec)
                n += 1
            pos += len(raw)
            last_raw = raw
            clean = pos
    torn = False
    if bad is not None:
        offset, raw, msg = bad
        if not (tolerate_torn_tail and not raw.endswith(b"\n")):
            raise CorruptJournalError(
                f"corrupt journal record in {path} at byte {offset}: {msg}")
        torn = True
        logger.warning(
            "torn tail in journal %s: truncating %d bytes of incomplete "
            "final record %r", path, len(raw),
            raw.strip()[:60].decode(errors="replace"))
        if repair:
            with open(path, "rb+") as f:
                f.truncate(clean)
    elif repair and last_raw and not last_raw.endswith(b"\n"):
        # complete final record that lost only its newline: terminate it
        # so the next append does not merge into it
        with open(path, "ab") as f:
            f.write(b"\n")
    return n, torn


def record_study_key(rec: dict[str, Any]) -> str | None:
    """The study key a WAL record belongs to, or None for records that
    cannot be attributed (unknown ops).  This is the filter used when a
    shard migrates between fabric workers: the importer replays only the
    records of the moving study out of the exporter's shipped snapshot +
    sealed segments."""
    op = rec.get("op")
    if op == "create_study":
        return StudyConfig.from_record(rec["config"]).key()
    if op == "add_trial":
        return rec["trial"]["study_key"]
    if op == "update_trial":
        return rec["uid"].partition(":")[0]
    if op in ("enqueue", "pop_waiting"):
        return rec["study_key"]
    if op in ("adopt_shard", "drop_shard"):
        return rec["key"]
    if op == "idem":
        return rec["study_key"]
    # "lease" is store-wide (leader epoch), deliberately unattributable:
    # it must not travel with any single study on migration
    return None


# bounded per-shard idempotency window: large enough to cover every
# plausible in-flight retry, small enough to stay O(1) per shard.  FIFO
# eviction is deterministic, so live state and WAL replay agree.
_DEDUP_WINDOW = 512


class _StudyShard:
    """Everything the storage tracks for one study, under one lock."""

    __slots__ = ("study", "lock", "by_uid", "state_uids", "lease_heap",
                 "waiting", "version", "completed_log", "best_uid", "dedup")

    def __init__(self, study: Study):
        self.study = study
        self.lock = threading.RLock()
        self.by_uid: dict[str, Trial] = {}
        self.state_uids: dict[TrialState, set[str]] = {
            s: set() for s in TrialState}
        # (deadline, uid) entries; renewals push fresh entries and stale
        # ones are dropped lazily on pop
        self.lease_heap: list[tuple[float, str]] = []
        self.waiting: deque[dict[str, Any]] = deque()
        # monotonically increasing mutation counter: bumped on every shard
        # mutation, so read-side caches can detect staleness with one int
        # compare instead of scanning
        self.version = 0
        # append-only log of trial uids in the order they became
        # observations (COMPLETED with a value) — consumed incrementally
        # by per-study ObservationCaches
        self.completed_log: list[str] = []
        # incumbent: uid of the best completed trial (strictly-better
        # replacement, so ties keep the earliest completion)
        self.best_uid: str | None = None
        # bounded idempotency-key -> tell-result window (insertion order
        # = FIFO eviction order), journaled so retries stay exactly-once
        # across crash recovery and replication
        self.dedup: dict[str, dict[str, Any]] = {}


class InMemoryStorage:
    """Thread-safe sharded study/trial store (the PostgreSQL stand-in)."""

    def __init__(self):
        self._shards: dict[str, _StudyShard] = {}
        self._registry_lock = threading.RLock()
        # read-path instrumentation: number of full trial-list walks done
        # by storage read helpers.  The indexed monitoring endpoints must
        # keep this at 0 (asserted in tests) — any growth means a read
        # path regressed to scanning.  Lock-free monotonic counter: a
        # dropped concurrent increment only undercounts instrumentation.
        self.trial_scans = 0  # repro-check: allow(shared-state)

    # -- studies --------------------------------------------------------
    def get_or_create_study(self, config: StudyConfig) -> tuple[Study, bool]:
        key = config.key()
        with self._registry_lock:
            shard = self._shards.get(key)
            if shard is not None:
                return shard.study, False
            study = Study(config=config)
            study._managed = True       # mutations route through this store
            # write-ahead: the record is serialized (and, depending on the
            # fsync mode, made durable) *before* the shard is published —
            # a journaling failure never leaves a half-created study
            self._log({"op": "create_study", "config": config.to_record(),
                       "created_at": study.created_at})
            self._shards[key] = _StudyShard(study)
            return study, True

    def get_study(self, key: str) -> Study | None:
        with self._registry_lock:
            shard = self._shards.get(key)
            return None if shard is None else shard.study

    def studies(self) -> list[Study]:
        with self._registry_lock:
            return [s.study for s in self._shards.values()]

    def study_lock(self, key: str) -> threading.RLock:
        """The per-study shard lock — servers serialize per-study request
        handling on this, so different studies never contend."""
        with self._registry_lock:
            return self._shards[key].lock

    # -- trials ---------------------------------------------------------
    def _shard(self, study_key: str) -> _StudyShard | None:
        with self._registry_lock:
            return self._shards.get(study_key)

    def _index_trial(self, shard: _StudyShard, trial: Trial) -> None:
        """Append ``trial`` to the shard and maintain every index."""
        shard.study.trials.append(trial)
        shard.study.note_trial_added()
        shard.by_uid[trial.uid] = trial
        shard.state_uids[trial.state].add(trial.uid)
        if trial.state == TrialState.RUNNING and trial.lease_deadline is not None:
            heapq.heappush(shard.lease_heap, (trial.lease_deadline, trial.uid))
        shard.version += 1
        if trial.state == TrialState.COMPLETED and trial.value is not None:
            self._note_observation(shard, trial)

    @staticmethod
    def _note_observation(shard: _StudyShard, trial: Trial) -> None:
        """A trial just became an observation: log it and race the incumbent.
        Tie-break on equal values by lowest trial_id, matching the
        ``Study.best_trial()`` scan exactly."""
        if not math.isfinite(trial.value):
            # a NaN/inf objective is not a usable observation: it would
            # poison both the incumbent comparison (NaN compares false
            # against everything) and the sampler's observation matrices.
            # The API boundary rejects these with a 422; this guard keeps
            # direct storage writes from corrupting the indices.
            return
        shard.completed_log.append(trial.uid)
        sign = (1.0 if shard.study.config.direction == Direction.MINIMIZE
                else -1.0)
        best = (shard.by_uid.get(shard.best_uid)
                if shard.best_uid is not None else None)
        if (best is None or best.value is None
                or sign * trial.value < sign * best.value
                or (sign * trial.value == sign * best.value
                    and trial.trial_id < best.trial_id)):
            shard.best_uid = trial.uid

    def add_trial(self, study_key: str, params: dict[str, Any],
                  worker_id: str | None, lease_deadline: float | None,
                  retries: int = 0) -> Trial:
        shard = self._shard(study_key)
        if shard is None:
            raise KeyError(study_key)
        with shard.lock:
            tid = len(shard.study.trials)
            trial = Trial(trial_id=tid, uid=f"{study_key}:{tid}",
                          study_key=study_key, params=params,
                          worker_id=worker_id, lease_deadline=lease_deadline,
                          retries=retries)
            # write-ahead: log before indexing, so a serialization failure
            # (e.g. a non-finite param slipping past the boundary) cannot
            # leave live state diverged from what a recovery will rebuild
            self._log({"op": "add_trial", "trial": trial.to_record()})
            self._index_trial(shard, trial)
            return trial

    def get_trial(self, uid: str) -> Trial | None:
        study_key, _, _ = uid.partition(":")
        shard = self._shard(study_key)
        if shard is None:
            return None
        with shard.lock:
            return shard.by_uid.get(uid)

    def update_trial(self, uid: str, *,
                     idem: tuple[str, dict[str, Any]] | list | None = None,
                     **fields: Any) -> Trial:
        shard = self._shard(uid.partition(":")[0])
        if shard is None:
            raise KeyError(uid)
        with shard.lock:
            trial = shard.by_uid.get(uid)
            if trial is None:
                raise KeyError(uid)
            was_observation = (trial.state == TrialState.COMPLETED
                               and trial.value is not None)
            # write-ahead: a record that cannot be journaled (strict JSON
            # rejects NaN/inf) must fail *before* the in-memory apply, or
            # live state would silently diverge from the recovered one
            rec: dict[str, Any] = {
                "op": "update_trial", "uid": uid,
                "fields": {k: (list(v) if k == "intermediate" else
                               (v.value if isinstance(v, TrialState) else v))
                           for k, v in fields.items()}}
            if idem is not None:
                # a finalize and its idempotency-window note must be ONE
                # WAL record: shipped separately, a leader dying between
                # them leaves a replica where the trial is finalized but
                # the retried tell is unrecognizable (bogus 409)
                rec["idem"] = [idem[0], idem[1]]
            self._log(rec)
            for k, v in fields.items():
                if k == "intermediate":            # (step, value) append
                    step, value = v
                    trial.intermediates[int(step)] = float(value)
                    shard.study.record_report(uid, int(step), float(value))
                elif k == "state":
                    if v != trial.state:
                        shard.state_uids[trial.state].discard(uid)
                        shard.state_uids[v].add(uid)
                    trial.state = v
                elif k == "lease_deadline":
                    trial.lease_deadline = v
                    if v is not None and trial.state == TrialState.RUNNING:
                        heapq.heappush(shard.lease_heap, (float(v), uid))
                else:
                    setattr(trial, k, v)
            shard.version += 1
            if (not was_observation and trial.state == TrialState.COMPLETED
                    and trial.value is not None):
                self._note_observation(shard, trial)
            if idem is not None:
                self._remember_idem(shard, idem[0], dict(idem[1]))
            return trial

    # -- indexed views ---------------------------------------------------
    def counts(self, study_key: str) -> dict[TrialState, int]:
        """Per-state trial counts from the shard index (no trial scan)."""
        shard = self._shard(study_key)
        if shard is None:
            return {s: 0 for s in TrialState}
        with shard.lock:
            return {s: len(uids) for s, uids in shard.state_uids.items()}

    def trials_in_state(self, study_key: str, state: TrialState) -> list[Trial]:
        shard = self._shard(study_key)
        if shard is None:
            return []
        with shard.lock:
            return [shard.by_uid[u] for u in shard.state_uids[state]]

    def data_version(self, study_key: str) -> int:
        """Shard mutation counter — equal versions mean nothing changed."""
        shard = self._shard(study_key)
        if shard is None:
            return -1
        with shard.lock:
            return shard.version

    def completed_since(self, study_key: str, position: int) -> list[Trial]:
        """Observations (COMPLETED trials with a value) appended to the
        shard's completion log at index >= ``position``, in completion
        order.  O(new) — the incremental feed for ObservationCache."""
        shard = self._shard(study_key)
        if shard is None:
            return []
        with shard.lock:
            return [shard.by_uid[u]
                    for u in shard.completed_log[position:]]

    def _scan_trials(self, shard: _StudyShard) -> list[Trial]:
        """Full walk of a shard's trial list — the instrumented slow path.
        No serving read uses it today (every endpoint answers from an
        index); any future read that cannot must go through here so
        ``trial_scans`` stays honest."""
        self.trial_scans += 1
        return list(shard.study.trials)

    def trials_page(self, study_key: str, *, state: TrialState | None = None,
                    cursor: int | None = None, limit: int = 100
                    ) -> tuple[list[Trial], int | None] | None:
        """One page of a study's trials in ``trial_id`` order.

        ``cursor`` is the last ``trial_id`` of the previous page (None =
        start).  Returns ``(trials, next_cursor)`` where ``next_cursor``
        is None once the page is not full, or None if the study is
        unknown.  Unfiltered pages slice the trial list directly (ids are
        list indices, O(limit)); state-filtered pages are served from the
        per-state uid buckets — O(bucket) worst case, never a walk of the
        full trial list.
        """
        shard = self._shard(study_key)
        if shard is None:
            return None
        start = 0 if cursor is None else int(cursor) + 1
        limit = max(1, int(limit))
        with shard.lock:
            if state is None:
                trials = list(shard.study.trials[start:start + limit])
            else:
                bucket = shard.state_uids[state]
                ids = sorted(
                    tid for tid in (shard.by_uid[u].trial_id
                                    for u in bucket) if tid >= start)
                trials = [shard.by_uid[f"{study_key}:{tid}"]
                          for tid in ids[:limit]]
            next_cursor = (trials[-1].trial_id
                           if len(trials) == limit else None)
            return trials, next_cursor

    def n_trials(self, study_key: str) -> int:
        shard = self._shard(study_key)
        if shard is None:
            return 0
        with shard.lock:
            return len(shard.study.trials)

    def best_trial(self, study_key: str) -> Trial | None:
        """The incumbent, maintained incrementally on completion — O(1),
        no trial scan (ties keep the earliest completion)."""
        shard = self._shard(study_key)
        if shard is None:
            return None
        with shard.lock:
            return (None if shard.best_uid is None
                    else shard.by_uid.get(shard.best_uid))

    # -- lease heap ------------------------------------------------------
    def pop_expired(self, study_key: str, now: float) -> list[Trial]:
        """Pop trials whose lease lapsed, in deadline order.

        Touches only expired heap entries (plus stale ones superseded by a
        renewal, which are discarded).  The caller is expected to finalize
        the returned trials — they are *not* mutated here.
        """
        shard = self._shard(study_key)
        if shard is None:
            return []
        expired: list[Trial] = []
        seen: set[str] = set()
        with shard.lock:
            heap = shard.lease_heap
            while heap and heap[0][0] <= now:
                deadline, uid = heapq.heappop(heap)
                trial = shard.by_uid.get(uid)
                if trial is None or trial.state != TrialState.RUNNING:
                    continue                     # already finalized
                if trial.lease_deadline is None or trial.lease_deadline > now:
                    continue                     # renewed: stale entry
                if trial.lease_deadline != deadline or uid in seen:
                    continue                     # superseded / duplicate entry
                seen.add(uid)
                expired.append(trial)
        return expired

    def lease_heap_size(self, study_key: str) -> int:
        shard = self._shard(study_key)
        if shard is None:
            return 0
        with shard.lock:
            return len(shard.lease_heap)

    # -- fault tolerance: requeue params of expired/failed trials --------
    def enqueue_params(self, study_key: str, params: dict[str, Any],
                       retries: int) -> None:
        shard = self._shard(study_key)
        if shard is None:
            raise KeyError(study_key)
        with shard.lock:
            self._log({"op": "enqueue", "study_key": study_key,
                       "params": params, "retries": retries})
            shard.waiting.append({"params": params, "retries": retries})
            shard.version += 1

    def pop_waiting(self, study_key: str) -> dict[str, Any] | None:
        shard = self._shard(study_key)
        if shard is None:
            return None
        with shard.lock:
            if shard.waiting:
                self._log({"op": "pop_waiting", "study_key": study_key})
                item = shard.waiting.popleft()
                shard.version += 1
                return item
            return None

    # -- exactly-once tells (idempotency window) --------------------------
    def idempotent_result(self, study_key: str, key: str
                          ) -> dict[str, Any] | None:
        """The recorded result of a previously applied tell carrying
        idempotency key ``key``, or None if unseen (or evicted)."""
        shard = self._shard(study_key)
        if shard is None:
            return None
        with shard.lock:
            return shard.dedup.get(key)

    def note_idempotency(self, study_key: str, key: str,
                         result: dict[str, Any]) -> None:
        """Record a tell's result under its idempotency key (journaled,
        bounded FIFO window) so a retried request replays the original
        outcome instead of double-applying."""
        shard = self._shard(study_key)
        if shard is None:
            raise KeyError(study_key)
        with shard.lock:
            self._log({"op": "idem", "study_key": study_key,
                       "key": key, "result": result})
            self._remember_idem(shard, key, result)

    @staticmethod
    def _remember_idem(shard: _StudyShard, key: str,
                       result: dict[str, Any]) -> None:
        shard.dedup[key] = result
        while len(shard.dedup) > _DEDUP_WINDOW:
            shard.dedup.pop(next(iter(shard.dedup)))
        shard.version += 1

    # -- leader leases -----------------------------------------------------
    # Store-wide leadership epoch (replication): 0 = never replicated.
    # Persisted in the WAL on *change only*, so unreplicated deployments
    # write no lease records at all.  GIL-atomic int: fencing reads
    # tolerate staleness because every write is re-checked against the
    # journaled epoch, and replay-path stores happen on a single thread.
    lease_epoch = 0  # repro-check: allow(shared-state)

    def note_lease(self, epoch: int) -> int:
        """Persist an epoch-numbered leadership lease.  A restarted
        leader replays its WAL and sees the highest epoch it ever held —
        if the fabric has moved on to a higher epoch, its writes stay
        fenced (stale-epoch 409)."""
        epoch = int(epoch)
        with self._registry_lock:
            if epoch != self.lease_epoch:
                self._log({"op": "lease", "epoch": epoch})
                self.lease_epoch = epoch
            return self.lease_epoch

    # -- WAL record replay ------------------------------------------------
    # Shared by JournalStorage, the DurableStorage recovery path, and the
    # compactor's shadow replayer (a plain InMemoryStorage that records
    # are folded into).  ``_replaying`` suppresses re-journaling while a
    # journaled subclass applies its own log.  Toggled only by the single
    # WAL-applier thread (recovery or the replication client) on stores
    # that take no concurrent foreground writes.
    _replaying = False  # repro-check: allow(shared-state)

    def _insert_trial(self, trial: Trial) -> None:
        """Replay path: insert preserving ``trial_id``, padding journal gaps
        with explicit failed tombstones so uid->trial lookups stay aligned."""
        shard = self._shard(trial.study_key)
        if shard is None:
            raise KeyError(trial.study_key)
        with shard.lock:
            while len(shard.study.trials) < trial.trial_id:
                self._index_trial(shard, Trial.tombstone(
                    trial.study_key, len(shard.study.trials)))
            self._index_trial(shard, trial)

    def _apply(self, rec: dict[str, Any]) -> None:
        """Apply one WAL record to this store (replay/compaction path)."""
        op = rec["op"]
        if op == "create_study":
            study, created = self.get_or_create_study(
                StudyConfig.from_record(rec["config"]))
            if created and "created_at" in rec:
                study.created_at = rec["created_at"]
        elif op == "add_trial":
            self._insert_trial(Trial.from_record(rec["trial"]))
        elif op == "update_trial":
            fields = dict(rec["fields"])
            if "state" in fields:
                fields["state"] = TrialState(fields["state"])
            if "intermediate" in fields:
                fields["intermediate"] = tuple(fields["intermediate"])
            self.update_trial(rec["uid"], idem=rec.get("idem"), **fields)
        elif op == "enqueue":
            self.enqueue_params(rec["study_key"], rec["params"], rec["retries"])
        elif op == "pop_waiting":
            self.pop_waiting(rec["study_key"])
        elif op == "adopt_shard":
            self._restore_shard(rec["shard"])
        elif op == "drop_shard":
            with self._registry_lock:
                self._shards.pop(rec["key"], None)
        elif op == "idem":
            shard = self._shard(rec["study_key"])
            if shard is not None:
                with shard.lock:
                    self._remember_idem(shard, rec["key"], rec["result"])
        elif op == "lease":
            self.lease_epoch = int(rec["epoch"])

    def apply_replicated(self, rec: dict[str, Any]) -> None:
        """Apply one record arriving over the replication stream: journal
        it verbatim first (write-ahead, exactly like a locally originated
        mutation), then apply with re-journaling suppressed —
        ``_apply``'s branches journal inconsistently on their own
        (``add_trial`` replay does not log, ``update_trial`` replay
        would double-log), so replication always persists the original
        record and replays it."""
        self._log(rec)
        prev = self._replaying
        self._replaying = True
        try:
            self._apply(rec)
        finally:
            self._replaying = prev

    # -- snapshots + state digest -----------------------------------------
    @staticmethod
    def _shard_state_locked(shard: _StudyShard) -> dict[str, Any]:
        """Serialize one shard (caller holds the shard lock)."""
        return {
            "key": shard.study.key,
            "study": shard.study.to_record(),
            "waiting": [dict(w) for w in shard.waiting],
            "completed_log": list(shard.completed_log),
            "best_uid": shard.best_uid,
            "version": shard.version,
            "dedup": dict(shard.dedup),
        }

    def state_record(self) -> dict[str, Any]:
        """Point-in-time serialization of the full store: per shard, the
        study (config, trials — see ``types.Study.to_record``), waiting
        queue, completion log, incumbent, and version counter.  The
        derived indices (uid map, state buckets, lease heap) are rebuilt
        on ``load_state``.  Each shard is serialized under its own lock;
        callers needing a cross-shard-atomic cut must quiesce writers
        (the compactor reads only sealed, immutable files instead)."""
        with self._registry_lock:
            shards = list(self._shards.values())
        studies = []
        for shard in shards:
            with shard.lock:
                studies.append(self._shard_state_locked(shard))
        return {"studies": studies}

    def shard_record(self, study_key: str) -> dict[str, Any] | None:
        """Point-in-time serialization of one shard (the handoff unit for
        fabric shard migration), or None if the study is unknown."""
        shard = self._shard(study_key)
        if shard is None:
            return None
        with shard.lock:
            return self._shard_state_locked(shard)

    def _restore_shard(self, rec: dict[str, Any]) -> None:
        """Rebuild one shard (and every derived index) from its snapshot
        record.  The completion log and incumbent are restored verbatim —
        they carry *completion order*, which trial order cannot recover.

        The shard is assembled fully in private and published into the
        registry as the last step: no thread can observe (or lock) a
        half-restored shard, and the registry lock never nests a shard
        lock — the request path nests them the other way around."""
        study = Study.from_record(rec["study"])
        study._managed = True
        key = study.key
        shard = _StudyShard(study)
        for t in study.trials:
            shard.by_uid[t.uid] = t
            shard.state_uids[t.state].add(t.uid)
            if (t.state == TrialState.RUNNING
                    and t.lease_deadline is not None):
                heapq.heappush(shard.lease_heap,
                               (t.lease_deadline, t.uid))
        shard.waiting = deque(rec["waiting"])
        shard.completed_log = list(rec["completed_log"])
        shard.best_uid = rec["best_uid"]
        shard.version = rec["version"]
        # absent in pre-replication snapshots
        shard.dedup = dict(rec.get("dedup", {}))
        with self._registry_lock:
            if key in self._shards:
                raise ValueError(f"shard {key!r} already loaded")
            self._shards[key] = shard

    def load_state(self, record: dict[str, Any]) -> None:
        """Restore a ``state_record`` snapshot into this (empty) store."""
        for shard_rec in record["studies"]:
            self._restore_shard(shard_rec)

    @staticmethod
    def _digest_shard_rec(srec: dict[str, Any]) -> dict[str, Any]:
        """Augment one serialized shard with an explicit lease view (uid ->
        deadline of RUNNING trials — the information the lease heap is
        built from) so the digest also witnesses future expiries."""
        out = dict(srec)
        out["leases"] = {
            t["uid"]: t["lease_deadline"]
            for t in srec["study"]["trials"]
            if t["state"] == TrialState.RUNNING.value
            and t["lease_deadline"] is not None}
        return out

    def state_digest(self) -> str:
        """Order-independent content hash of the full logical state.

        Covers everything ``state_record`` covers plus an explicit view
        of the live leases, so digest equality proves a recovered store
        is index-for-index identical to the original: same trials, same
        incumbent, same completion order, same waiting queue, same
        future expiries."""
        record = self.state_record()
        record["studies"] = [self._digest_shard_rec(s)
                             for s in record["studies"]]
        record["studies"].sort(key=lambda s: s["key"])
        blob = json.dumps(record, sort_keys=True, allow_nan=False)
        return hashlib.sha256(blob.encode()).hexdigest()

    def shard_digest(self, study_key: str) -> str | None:
        """Content hash of one shard's logical state (same coverage as
        ``state_digest`` restricted to the shard).  Equality across two
        stores proves the migrated shard is index-for-index identical —
        the pre-cutover witness for fabric shard handoff."""
        srec = self.shard_record(study_key)
        if srec is None:
            return None
        blob = json.dumps(self._digest_shard_rec(srec), sort_keys=True,
                          allow_nan=False)
        return hashlib.sha256(blob.encode()).hexdigest()

    # -- shard ownership (fabric handoff) ---------------------------------
    def adopt_shard(self, record: dict[str, Any]) -> None:
        """Take ownership of a migrated shard: journal the adoption (the
        full shard record is the WAL payload, so recovery replays it) and
        rebuild the shard + indices.  Raises ValueError if a shard with
        the same key is already loaded."""
        key = record["key"]
        with self._registry_lock:
            if key in self._shards:
                raise ValueError(f"shard {key!r} already loaded")
            self._log({"op": "adopt_shard", "key": key, "shard": record})
            self._restore_shard(record)

    def drop_shard(self, study_key: str) -> bool:
        """Release ownership of a shard after it migrated away.  The drop
        is journaled, so recovery of this store does not resurrect the
        moved study.  Returns False if the study is unknown."""
        with self._registry_lock:
            if study_key not in self._shards:
                return False
            self._log({"op": "drop_shard", "key": study_key})
            del self._shards[study_key]
            return True

    # -- durability hooks --------------------------------------------------
    def flush(self) -> None:
        """Make every acknowledged mutation durable (no-op in memory)."""

    def close(self) -> None:
        """Flush and release any backing files (no-op in memory)."""

    def storage_stats(self) -> dict[str, Any]:
        """Backend + durability statistics (exposed on /api/v2/version)."""
        with self._registry_lock:
            n_studies = len(self._shards)
        return {"backend": "memory", "n_studies": n_studies,
                "trial_scans": self.trial_scans}

    # -- journal hook -----------------------------------------------------
    def _log(self, record: dict[str, Any]) -> None:  # overridden by JournalStorage
        pass

    def atomically(self, fn: Callable[[], Any]) -> Any:
        """Run ``fn`` under the registry lock (cross-study invariants only;
        per-study work should use ``study_lock`` instead)."""
        with self._registry_lock:
            return fn()


class JournalStorage(InMemoryStorage):
    """InMemoryStorage + append-only JSONL journal with replay.

    Every mutation is journaled before being acknowledged; a freshly
    constructed ``JournalStorage`` pointed at an existing journal replays it
    to reconstruct the full service state (crash-restart of the service,
    paper sec. 3 'shared persistency').  Journal appends are serialized on
    a dedicated lock because shards write concurrently.  Replay tolerates
    a torn final record (crash mid-append) by truncating it with a
    warning; see ``DurableStorage`` for the segmented engine with
    snapshots, group-commit fsync, and compaction.
    """

    def __init__(self, path: str):
        self._journal_lock = threading.Lock()
        # serializes fsync/close against each other only — appenders
        # contend on _journal_lock alone and never wait for the disk
        self._fsync_lock = threading.Lock()
        super().__init__()
        self._path = path
        self._file = None
        if os.path.exists(path):
            self.replay(path)
        self._file = open(path, "a", buffering=1)

    def _log(self, record: dict[str, Any]) -> None:
        if self._file is not None and not self._replaying:
            # strict JSON: NaN/Infinity are not valid JSON and would make
            # the journal unreadable by a strict parser on replay
            line = json.dumps(record, allow_nan=False) + "\n"
            with self._journal_lock:
                self._file.write(line)

    def replay(self, path: str) -> int:
        """Reconstruct state from the journal.  Returns #records applied.
        A torn final record (crash mid-append) is truncated with a
        warning; corruption elsewhere raises ``CorruptJournalError``."""
        self._replaying = True
        try:
            n, _ = load_journal_file(path, self._apply,
                                     tolerate_torn_tail=True, repair=True)
        finally:
            self._replaying = False
        return n

    def flush(self) -> None:
        """Force journaled records to disk.  The buffer flush happens
        under the append lock; the fsync happens on a dedicated lock so
        concurrent appends are never stalled behind the disk."""
        with self._journal_lock:
            f = self._file
            if f is None:
                return
            f.flush()
        with self._fsync_lock:
            if self._file is not None:
                # repro-check: allow(blocking-under-lock) -- _fsync_lock
                # exists to serialize fsyncers; appenders never take it
                os.fsync(f.fileno())

    def storage_stats(self) -> dict[str, Any]:
        stats = super().storage_stats()
        stats.update({"backend": "journal", "path": self._path})
        return stats

    def close(self) -> None:
        with self._journal_lock:
            f, self._file = self._file, None
            if f is None:
                return
            f.flush()
        with self._fsync_lock:
            # repro-check: allow(blocking-under-lock) -- final fsync on
            # the fsync-serialization lock; no appender can contend
            os.fsync(f.fileno())
            f.close()
