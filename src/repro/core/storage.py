"""Shared persistency layer.

The paper's reference implementation uses a PostgreSQL instance to give
*shared persistency to the multiple instances of the web application
backend* (sec. 3).  Here the same role is played by a thread-safe storage
object that multiple ``HopaasServer`` workers share, with an optional
append-only JSONL write-ahead journal providing crash-restart recovery
(``JournalStorage.replay``).
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Callable, Iterable

from .types import Study, StudyConfig, Trial, TrialState


class InMemoryStorage:
    """Thread-safe in-memory study/trial store (the PostgreSQL stand-in)."""

    def __init__(self):
        self._studies: dict[str, Study] = {}
        self._lock = threading.RLock()
        self._waiting: dict[str, list[dict[str, Any]]] = {}  # requeued params

    # -- studies --------------------------------------------------------
    def get_or_create_study(self, config: StudyConfig) -> tuple[Study, bool]:
        with self._lock:
            key = config.key()
            if key in self._studies:
                return self._studies[key], False
            study = Study(config=config)
            self._studies[key] = study
            self._log({"op": "create_study", "config": config.to_record()})
            return study, True

    def get_study(self, key: str) -> Study | None:
        with self._lock:
            return self._studies.get(key)

    def studies(self) -> list[Study]:
        with self._lock:
            return list(self._studies.values())

    # -- trials ---------------------------------------------------------
    def add_trial(self, study_key: str, params: dict[str, Any], worker_id: str | None,
                  lease_deadline: float | None, retries: int = 0) -> Trial:
        with self._lock:
            study = self._studies[study_key]
            tid = len(study.trials)
            trial = Trial(trial_id=tid, uid=f"{study_key}:{tid}", study_key=study_key,
                          params=params, worker_id=worker_id,
                          lease_deadline=lease_deadline, retries=retries)
            study.trials.append(trial)
            self._log({"op": "add_trial", "trial": trial.to_record()})
            return trial

    def get_trial(self, uid: str) -> Trial | None:
        with self._lock:
            study_key, _, tid = uid.partition(":")
            study = self._studies.get(study_key)
            if study is None:
                return None
            tid = int(tid)
            return study.trials[tid] if tid < len(study.trials) else None

    def update_trial(self, uid: str, **fields: Any) -> Trial:
        with self._lock:
            trial = self.get_trial(uid)
            if trial is None:
                raise KeyError(uid)
            for k, v in fields.items():
                if k == "intermediate":            # (step, value) append
                    step, value = v
                    trial.intermediates[int(step)] = float(value)
                else:
                    setattr(trial, k, v)
            self._log({"op": "update_trial", "uid": uid,
                       "fields": {k: (list(v) if k == "intermediate" else
                                      (v.value if isinstance(v, TrialState) else v))
                                  for k, v in fields.items()}})
            return trial

    # -- fault tolerance: requeue params of expired/failed trials --------
    def enqueue_params(self, study_key: str, params: dict[str, Any], retries: int) -> None:
        with self._lock:
            self._waiting.setdefault(study_key, []).append(
                {"params": params, "retries": retries})
            self._log({"op": "enqueue", "study_key": study_key,
                       "params": params, "retries": retries})

    def pop_waiting(self, study_key: str) -> dict[str, Any] | None:
        with self._lock:
            q = self._waiting.get(study_key)
            if q:
                item = q.pop(0)
                self._log({"op": "pop_waiting", "study_key": study_key})
                return item
            return None

    # -- journal hook -----------------------------------------------------
    def _log(self, record: dict[str, Any]) -> None:  # overridden by JournalStorage
        pass

    def atomically(self, fn: Callable[[], Any]) -> Any:
        with self._lock:
            return fn()


class JournalStorage(InMemoryStorage):
    """InMemoryStorage + append-only JSONL journal with replay.

    Every mutation is journaled before being acknowledged; a freshly
    constructed ``JournalStorage`` pointed at an existing journal replays it
    to reconstruct the full service state (crash-restart of the service,
    paper sec. 3 'shared persistency').
    """

    def __init__(self, path: str):
        super().__init__()
        self._path = path
        self._file = None
        self._replaying = False
        if os.path.exists(path):
            self.replay(path)
        self._file = open(path, "a", buffering=1)

    def _log(self, record: dict[str, Any]) -> None:
        if self._file is not None and not self._replaying:
            self._file.write(json.dumps(record) + "\n")

    def replay(self, path: str) -> int:
        """Reconstruct state from the journal.  Returns #records applied."""
        n = 0
        self._replaying = True
        try:
            with open(path) as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    rec = json.loads(line)
                    self._apply(rec)
                    n += 1
        finally:
            self._replaying = False
        return n

    def _apply(self, rec: dict[str, Any]) -> None:
        op = rec["op"]
        if op == "create_study":
            self.get_or_create_study(StudyConfig.from_record(rec["config"]))
        elif op == "add_trial":
            t = Trial.from_record(rec["trial"])
            study = self._studies[t.study_key]
            # pad in case of gaps (shouldn't happen with a consistent journal)
            while len(study.trials) < t.trial_id:
                study.trials.append(t)
            study.trials.append(t)
        elif op == "update_trial":
            fields = dict(rec["fields"])
            if "state" in fields:
                fields["state"] = TrialState(fields["state"])
            if "intermediate" in fields:
                fields["intermediate"] = tuple(fields["intermediate"])
            self.update_trial(rec["uid"], **fields)
        elif op == "enqueue":
            self.enqueue_params(rec["study_key"], rec["params"], rec["retries"])
        elif op == "pop_waiting":
            self.pop_waiting(rec["study_key"])

    def close(self) -> None:
        if self._file is not None:
            self._file.close()
            self._file = None
