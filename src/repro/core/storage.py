"""Shared persistency layer, sharded per study.

The paper's reference implementation uses a PostgreSQL instance to give
*shared persistency to the multiple instances of the web application
backend* (sec. 3).  Here the same role is played by a storage object that
multiple ``HopaasServer`` workers share.  Internally the store is split
into per-study shards (``_StudyShard``): each shard owns its own lock,
an O(1) ``uid -> Trial`` index, per-state uid buckets, a min-heap of
lease deadlines, and the requeue queue.  Requests touching different
studies therefore never contend on a common lock; only study *creation*
takes the (short) registry lock.

Lease bookkeeping is heap-based: every ``add_trial``/lease renewal pushes
a ``(deadline, uid)`` entry, and ``pop_expired`` pops only entries whose
deadline has lapsed, discarding stale entries lazily (a renewal leaves the
superseded entry in the heap; it is dropped when popped because the
trial's *current* deadline is newer).  Sweeps are O(expired · log n)
instead of a full scan of every trial of every study.

Read-side acceleration: every shard carries a mutation ``version``
counter, an append-only ``completed_log`` of trials that became
observations (consumed incrementally by per-study ``ObservationCache``s
so `ask` never rescans the history), and an incrementally raced
incumbent (``best_trial`` is O(1), no scan).  Intermediate reports feed
the study's per-step / per-rung indices (see ``types.Study``) so pruner
heartbeats aggregate without walking the trial list.

An optional append-only JSONL write-ahead journal (``JournalStorage``)
provides crash-restart recovery: every mutation is journaled under the
owning shard's lock (so per-study order is preserved) before being
acknowledged, and ``replay`` reconstructs the full state — including the
indices, lease heap, completion log, and incumbent — from the log.
"""
from __future__ import annotations

import heapq
import json
import os
import threading
from collections import deque
from typing import Any, Callable

from .types import Direction, Study, StudyConfig, Trial, TrialState


class _StudyShard:
    """Everything the storage tracks for one study, under one lock."""

    __slots__ = ("study", "lock", "by_uid", "state_uids", "lease_heap",
                 "waiting", "version", "completed_log", "best_uid")

    def __init__(self, study: Study):
        self.study = study
        self.lock = threading.RLock()
        self.by_uid: dict[str, Trial] = {}
        self.state_uids: dict[TrialState, set[str]] = {
            s: set() for s in TrialState}
        # (deadline, uid) entries; renewals push fresh entries and stale
        # ones are dropped lazily on pop
        self.lease_heap: list[tuple[float, str]] = []
        self.waiting: deque[dict[str, Any]] = deque()
        # monotonically increasing mutation counter: bumped on every shard
        # mutation, so read-side caches can detect staleness with one int
        # compare instead of scanning
        self.version = 0
        # append-only log of trial uids in the order they became
        # observations (COMPLETED with a value) — consumed incrementally
        # by per-study ObservationCaches
        self.completed_log: list[str] = []
        # incumbent: uid of the best completed trial (strictly-better
        # replacement, so ties keep the earliest completion)
        self.best_uid: str | None = None


class InMemoryStorage:
    """Thread-safe sharded study/trial store (the PostgreSQL stand-in)."""

    def __init__(self):
        self._shards: dict[str, _StudyShard] = {}
        self._registry_lock = threading.RLock()
        # read-path instrumentation: number of full trial-list walks done
        # by storage read helpers.  The indexed monitoring endpoints must
        # keep this at 0 (asserted in tests) — any growth means a read
        # path regressed to scanning.
        self.trial_scans = 0

    # -- studies --------------------------------------------------------
    def get_or_create_study(self, config: StudyConfig) -> tuple[Study, bool]:
        key = config.key()
        with self._registry_lock:
            shard = self._shards.get(key)
            if shard is not None:
                return shard.study, False
            study = Study(config=config)
            study._managed = True       # mutations route through this store
            self._shards[key] = shard = _StudyShard(study)
            with shard.lock:
                self._log({"op": "create_study", "config": config.to_record()})
            return study, True

    def get_study(self, key: str) -> Study | None:
        with self._registry_lock:
            shard = self._shards.get(key)
            return None if shard is None else shard.study

    def studies(self) -> list[Study]:
        with self._registry_lock:
            return [s.study for s in self._shards.values()]

    def study_lock(self, key: str) -> threading.RLock:
        """The per-study shard lock — servers serialize per-study request
        handling on this, so different studies never contend."""
        with self._registry_lock:
            return self._shards[key].lock

    # -- trials ---------------------------------------------------------
    def _shard(self, study_key: str) -> _StudyShard | None:
        with self._registry_lock:
            return self._shards.get(study_key)

    def _index_trial(self, shard: _StudyShard, trial: Trial) -> None:
        """Append ``trial`` to the shard and maintain every index."""
        shard.study.trials.append(trial)
        shard.study.note_trial_added()
        shard.by_uid[trial.uid] = trial
        shard.state_uids[trial.state].add(trial.uid)
        if trial.state == TrialState.RUNNING and trial.lease_deadline is not None:
            heapq.heappush(shard.lease_heap, (trial.lease_deadline, trial.uid))
        shard.version += 1
        if trial.state == TrialState.COMPLETED and trial.value is not None:
            self._note_observation(shard, trial)

    @staticmethod
    def _note_observation(shard: _StudyShard, trial: Trial) -> None:
        """A trial just became an observation: log it and race the incumbent.
        Tie-break on equal values by lowest trial_id, matching the
        ``Study.best_trial()`` scan exactly."""
        shard.completed_log.append(trial.uid)
        sign = (1.0 if shard.study.config.direction == Direction.MINIMIZE
                else -1.0)
        best = (shard.by_uid.get(shard.best_uid)
                if shard.best_uid is not None else None)
        if (best is None or best.value is None
                or sign * trial.value < sign * best.value
                or (sign * trial.value == sign * best.value
                    and trial.trial_id < best.trial_id)):
            shard.best_uid = trial.uid

    def add_trial(self, study_key: str, params: dict[str, Any],
                  worker_id: str | None, lease_deadline: float | None,
                  retries: int = 0) -> Trial:
        shard = self._shard(study_key)
        if shard is None:
            raise KeyError(study_key)
        with shard.lock:
            tid = len(shard.study.trials)
            trial = Trial(trial_id=tid, uid=f"{study_key}:{tid}",
                          study_key=study_key, params=params,
                          worker_id=worker_id, lease_deadline=lease_deadline,
                          retries=retries)
            self._index_trial(shard, trial)
            self._log({"op": "add_trial", "trial": trial.to_record()})
            return trial

    def get_trial(self, uid: str) -> Trial | None:
        study_key, _, _ = uid.partition(":")
        shard = self._shard(study_key)
        if shard is None:
            return None
        with shard.lock:
            return shard.by_uid.get(uid)

    def update_trial(self, uid: str, **fields: Any) -> Trial:
        shard = self._shard(uid.partition(":")[0])
        if shard is None:
            raise KeyError(uid)
        with shard.lock:
            trial = shard.by_uid.get(uid)
            if trial is None:
                raise KeyError(uid)
            was_observation = (trial.state == TrialState.COMPLETED
                               and trial.value is not None)
            for k, v in fields.items():
                if k == "intermediate":            # (step, value) append
                    step, value = v
                    trial.intermediates[int(step)] = float(value)
                    shard.study.record_report(uid, int(step), float(value))
                elif k == "state":
                    if v != trial.state:
                        shard.state_uids[trial.state].discard(uid)
                        shard.state_uids[v].add(uid)
                    trial.state = v
                elif k == "lease_deadline":
                    trial.lease_deadline = v
                    if v is not None and trial.state == TrialState.RUNNING:
                        heapq.heappush(shard.lease_heap, (float(v), uid))
                else:
                    setattr(trial, k, v)
            shard.version += 1
            if (not was_observation and trial.state == TrialState.COMPLETED
                    and trial.value is not None):
                self._note_observation(shard, trial)
            self._log({"op": "update_trial", "uid": uid,
                       "fields": {k: (list(v) if k == "intermediate" else
                                      (v.value if isinstance(v, TrialState) else v))
                                  for k, v in fields.items()}})
            return trial

    # -- indexed views ---------------------------------------------------
    def counts(self, study_key: str) -> dict[TrialState, int]:
        """Per-state trial counts from the shard index (no trial scan)."""
        shard = self._shard(study_key)
        if shard is None:
            return {s: 0 for s in TrialState}
        with shard.lock:
            return {s: len(uids) for s, uids in shard.state_uids.items()}

    def trials_in_state(self, study_key: str, state: TrialState) -> list[Trial]:
        shard = self._shard(study_key)
        if shard is None:
            return []
        with shard.lock:
            return [shard.by_uid[u] for u in shard.state_uids[state]]

    def data_version(self, study_key: str) -> int:
        """Shard mutation counter — equal versions mean nothing changed."""
        shard = self._shard(study_key)
        if shard is None:
            return -1
        with shard.lock:
            return shard.version

    def completed_since(self, study_key: str, position: int) -> list[Trial]:
        """Observations (COMPLETED trials with a value) appended to the
        shard's completion log at index >= ``position``, in completion
        order.  O(new) — the incremental feed for ObservationCache."""
        shard = self._shard(study_key)
        if shard is None:
            return []
        with shard.lock:
            return [shard.by_uid[u]
                    for u in shard.completed_log[position:]]

    def _scan_trials(self, shard: _StudyShard) -> list[Trial]:
        """Full walk of a shard's trial list — the instrumented slow path.
        No serving read uses it today (every endpoint answers from an
        index); any future read that cannot must go through here so
        ``trial_scans`` stays honest."""
        self.trial_scans += 1
        return list(shard.study.trials)

    def trials_page(self, study_key: str, *, state: TrialState | None = None,
                    cursor: int | None = None, limit: int = 100
                    ) -> tuple[list[Trial], int | None] | None:
        """One page of a study's trials in ``trial_id`` order.

        ``cursor`` is the last ``trial_id`` of the previous page (None =
        start).  Returns ``(trials, next_cursor)`` where ``next_cursor``
        is None once the page is not full, or None if the study is
        unknown.  Unfiltered pages slice the trial list directly (ids are
        list indices, O(limit)); state-filtered pages are served from the
        per-state uid buckets — O(bucket) worst case, never a walk of the
        full trial list.
        """
        shard = self._shard(study_key)
        if shard is None:
            return None
        start = 0 if cursor is None else int(cursor) + 1
        limit = max(1, int(limit))
        with shard.lock:
            if state is None:
                trials = list(shard.study.trials[start:start + limit])
            else:
                bucket = shard.state_uids[state]
                ids = sorted(
                    tid for tid in (shard.by_uid[u].trial_id
                                    for u in bucket) if tid >= start)
                trials = [shard.by_uid[f"{study_key}:{tid}"]
                          for tid in ids[:limit]]
            next_cursor = (trials[-1].trial_id
                           if len(trials) == limit else None)
            return trials, next_cursor

    def n_trials(self, study_key: str) -> int:
        shard = self._shard(study_key)
        if shard is None:
            return 0
        with shard.lock:
            return len(shard.study.trials)

    def best_trial(self, study_key: str) -> Trial | None:
        """The incumbent, maintained incrementally on completion — O(1),
        no trial scan (ties keep the earliest completion)."""
        shard = self._shard(study_key)
        if shard is None:
            return None
        with shard.lock:
            return (None if shard.best_uid is None
                    else shard.by_uid.get(shard.best_uid))

    # -- lease heap ------------------------------------------------------
    def pop_expired(self, study_key: str, now: float) -> list[Trial]:
        """Pop trials whose lease lapsed, in deadline order.

        Touches only expired heap entries (plus stale ones superseded by a
        renewal, which are discarded).  The caller is expected to finalize
        the returned trials — they are *not* mutated here.
        """
        shard = self._shard(study_key)
        if shard is None:
            return []
        expired: list[Trial] = []
        seen: set[str] = set()
        with shard.lock:
            heap = shard.lease_heap
            while heap and heap[0][0] <= now:
                deadline, uid = heapq.heappop(heap)
                trial = shard.by_uid.get(uid)
                if trial is None or trial.state != TrialState.RUNNING:
                    continue                     # already finalized
                if trial.lease_deadline is None or trial.lease_deadline > now:
                    continue                     # renewed: stale entry
                if trial.lease_deadline != deadline or uid in seen:
                    continue                     # superseded / duplicate entry
                seen.add(uid)
                expired.append(trial)
        return expired

    def lease_heap_size(self, study_key: str) -> int:
        shard = self._shard(study_key)
        if shard is None:
            return 0
        with shard.lock:
            return len(shard.lease_heap)

    # -- fault tolerance: requeue params of expired/failed trials --------
    def enqueue_params(self, study_key: str, params: dict[str, Any],
                       retries: int) -> None:
        shard = self._shard(study_key)
        if shard is None:
            raise KeyError(study_key)
        with shard.lock:
            shard.waiting.append({"params": params, "retries": retries})
            shard.version += 1
            self._log({"op": "enqueue", "study_key": study_key,
                       "params": params, "retries": retries})

    def pop_waiting(self, study_key: str) -> dict[str, Any] | None:
        shard = self._shard(study_key)
        if shard is None:
            return None
        with shard.lock:
            if shard.waiting:
                item = shard.waiting.popleft()
                shard.version += 1
                self._log({"op": "pop_waiting", "study_key": study_key})
                return item
            return None

    # -- journal hook -----------------------------------------------------
    def _log(self, record: dict[str, Any]) -> None:  # overridden by JournalStorage
        pass

    def atomically(self, fn: Callable[[], Any]) -> Any:
        """Run ``fn`` under the registry lock (cross-study invariants only;
        per-study work should use ``study_lock`` instead)."""
        with self._registry_lock:
            return fn()


class JournalStorage(InMemoryStorage):
    """InMemoryStorage + append-only JSONL journal with replay.

    Every mutation is journaled before being acknowledged; a freshly
    constructed ``JournalStorage`` pointed at an existing journal replays it
    to reconstruct the full service state (crash-restart of the service,
    paper sec. 3 'shared persistency').  Journal appends are serialized on
    a dedicated lock because shards write concurrently.
    """

    def __init__(self, path: str):
        self._journal_lock = threading.Lock()
        super().__init__()
        self._path = path
        self._file = None
        self._replaying = False
        if os.path.exists(path):
            self.replay(path)
        self._file = open(path, "a", buffering=1)

    def _log(self, record: dict[str, Any]) -> None:
        if self._file is not None and not self._replaying:
            with self._journal_lock:
                self._file.write(json.dumps(record) + "\n")

    def replay(self, path: str) -> int:
        """Reconstruct state from the journal.  Returns #records applied."""
        n = 0
        self._replaying = True
        try:
            with open(path) as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    rec = json.loads(line)
                    self._apply(rec)
                    n += 1
        finally:
            self._replaying = False
        return n

    def _insert_trial(self, trial: Trial) -> None:
        """Replay path: insert preserving ``trial_id``, padding journal gaps
        with explicit failed tombstones so uid->trial lookups stay aligned."""
        shard = self._shard(trial.study_key)
        if shard is None:
            raise KeyError(trial.study_key)
        with shard.lock:
            while len(shard.study.trials) < trial.trial_id:
                self._index_trial(shard, Trial.tombstone(
                    trial.study_key, len(shard.study.trials)))
            self._index_trial(shard, trial)

    def _apply(self, rec: dict[str, Any]) -> None:
        op = rec["op"]
        if op == "create_study":
            self.get_or_create_study(StudyConfig.from_record(rec["config"]))
        elif op == "add_trial":
            self._insert_trial(Trial.from_record(rec["trial"]))
        elif op == "update_trial":
            fields = dict(rec["fields"])
            if "state" in fields:
                fields["state"] = TrialState(fields["state"])
            if "intermediate" in fields:
                fields["intermediate"] = tuple(fields["intermediate"])
            self.update_trial(rec["uid"], **fields)
        elif op == "enqueue":
            self.enqueue_params(rec["study_key"], rec["params"], rec["retries"])
        elif op == "pop_waiting":
            self.pop_waiting(rec["study_key"])

    def close(self) -> None:
        if self._file is not None:
            self._file.close()
            self._file = None
