"""Selector-based event-loop HTTP/1.1 frontend for the HOPAAS service.

The stdlib ``ThreadingHTTPServer`` frontend spends most of a tiny
ask/tell exchange on transport bookkeeping: one OS thread per
connection, ``email``-module header parsing, readline-based socket IO,
and whitespace-padded ``json.dumps`` on every response.  At thousands of
concurrent trial workers that overhead scales with *connection count*
instead of with work.  This module replaces it with the paper's
"scalable set of Uvicorn instances" shape in one process:

* **One IO thread** runs a ``selectors`` event loop: non-blocking
  accept/read/write over every connection, with an incremental HTTP/1.1
  request parser (plain ``bytes`` ops — no ``email`` module, no
  readline).  Keep-alive is the default and pipelined requests are
  parsed out of a single read.

* **A bounded pool of dispatch lanes** (worker threads) executes the
  router.  Requests are routed by a stable hash of the study key pulled
  from the URL (``/api/v2/studies/{key}…``, ``/api/v2/trials/{uid}…``
  where ``uid = key:n``), so all requests for one study land on the
  same lane: cross-thread contention on the per-study lock becomes
  in-order queue consumption, and the study's ``ObservationCache``
  stays hot on one thread.  Requests without a study key in the URL
  (v1 RPC, study list) use connection affinity.  Each lane is pinned to
  one ``HopaasServer`` worker, so per-study server state is not
  bounced between workers either.

* **A wire fast path**: responses are serialized with compact JSON
  separators, status/header blocks are pre-encoded once per status, and
  idempotent hot GETs are served from a response cache — the constant
  v1 ``/api/version`` body, and study resources keyed on the shard's
  ``data_version`` (the mutation counter: equal versions prove the
  serialized resource is still exact).  Cache probes still verify the
  bearer token; any miss or auth anomaly falls through to the full
  router so error envelopes stay byte-identical.

Responses to pipelined requests are written strictly in request order
(per-connection completion slots), whatever order the lanes finish in.
When a request's lane is idle and the loop isn't fanning out a busy
select round, the IO thread dispatches it *inline* — tiny exchanges
skip two thread handoffs, while sustained load flows through the lanes
and keeps its study affinity.  ``stop()`` drains in-flight work: the
listener closes immediately, established connections get a bounded
window to finish (requests already submitted — or still arriving on
them during the window — are answered), then everything closes.

The public entry point is ``HttpServiceRunner(..., backend="evloop")``
in ``repro.core.transport`` (the default backend); this module has no
HTTP *client* side.
"""
from __future__ import annotations

import collections
import http.client
import itertools
import json
import os
import queue
import selectors
import socket
import sys
import threading
import time
import zlib
from typing import Any

from .api.errors import error_payload
from .auth import bearer_token

_MAX_HEADER_BYTES = 64 * 1024
_MAX_BODY_BYTES = 32 * 1024 * 1024
_RECV_SIZE = 64 * 1024
_CACHE_MAX_STUDIES = 1024
# read backpressure: a client that pipelines requests faster than it
# reads responses stops being read past these high-water marks (the
# threaded frontend got this for free by blocking in wfile.write);
# reading resumes once both drain below half
_MAX_PENDING = 128
_MAX_OUTBUF = 1 << 20


def open_server_socket(host: str, port: int, *, reuseport: bool = False,
                       blocking: bool = False) -> socket.socket:
    """Bound + listening TCP server socket with the service's standard
    options.  Shared by the event-loop frontend (non-blocking, feeds the
    selector) and the replication hub (blocking, one accept thread)."""
    sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    if reuseport:
        sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEPORT, 1)
    sock.bind((host, port))
    sock.listen(256)
    sock.setblocking(blocking)
    return sock

_JSON_SEPARATORS = (",", ":")        # compact wire encoding


def _encode_body(payload: Any) -> bytes:
    return json.dumps(payload, separators=_JSON_SEPARATORS).encode()


# pre-encoded "status line + fixed headers + Content-Length: " blocks,
# built once per distinct status code ever sent
_HEAD_CACHE: dict[int, bytes] = {}


def _head(status: int) -> bytes:
    head = _HEAD_CACHE.get(status)
    if head is None:
        reason = http.client.responses.get(status, "Unknown")
        head = (f"HTTP/1.1 {status} {reason}\r\n"
                "Content-Type: application/json\r\n"
                "Content-Length: ").encode()
        _HEAD_CACHE[status] = head
    return head


def _encode_response(status: int, blob: bytes,
                     extra_headers: dict[str, str] | None = None,
                     close: bool = False, head_only: bool = False) -> bytes:
    # head_only (HEAD requests): Content-Length still describes the
    # body a GET would carry, but no body bytes follow (RFC 7231 §4.3.2)
    parts = [_head(status), str(len(blob)).encode(), b"\r\n"]
    if extra_headers:
        for k, v in extra_headers.items():
            parts.append(f"{k}: {v}\r\n".encode())
    if close:
        parts.append(b"Connection: close\r\n")
    parts.append(b"\r\n")
    if not head_only:
        parts.append(blob)
    return b"".join(parts)


# The frontend's few threads bounce the GIL at every recv/send/queue
# boundary; CPython's default 5 ms switch interval makes each of those
# reacquisitions wait up to a full interval behind a running dispatch,
# which dominates per-request cost under contention (profiled at ~600 us
# per syscall boundary on a loaded 2-core host).  A 1 ms interval cuts
# that convoy ~3x for a negligible preemption overhead.  It is an
# interpreter-wide knob, so it is scoped to the frontend's lifetime and
# refcounted across overlapping frontends.
_FAST_SWITCH_SECONDS = 0.001
_switch_lock = threading.Lock()
_switch_depth = 0
_switch_saved: float | None = None


def _acquire_fast_switch() -> None:
    global _switch_depth, _switch_saved
    with _switch_lock:
        _switch_depth += 1
        if _switch_depth == 1:
            saved = sys.getswitchinterval()
            if saved > _FAST_SWITCH_SECONDS:
                _switch_saved = saved
                sys.setswitchinterval(_FAST_SWITCH_SECONDS)


def _release_fast_switch() -> None:
    global _switch_depth, _switch_saved
    with _switch_lock:
        _switch_depth = max(0, _switch_depth - 1)
        if _switch_depth == 0 and _switch_saved is not None:
            sys.setswitchinterval(_switch_saved)
            _switch_saved = None


class _WireError(Exception):
    """A request the HTTP layer itself must reject (the router never
    sees it); the connection closes after the error response."""

    def __init__(self, status: int, message: str):
        super().__init__(message)
        self.status = status
        self.message = message


class _Pending:
    """One in-flight request's response slot.  Slots are appended in
    request order and flushed front-to-back, so pipelined responses
    never reorder even when lanes finish out of order."""

    __slots__ = ("data", "close_after")

    def __init__(self) -> None:
        self.data: bytes | None = None
        self.close_after = False


class _Connection:
    __slots__ = ("sock", "id", "lock", "inbuf", "outbuf", "pending",
                 "partial", "interest", "stop_reading", "throttled",
                 "closing", "closed", "broken")

    def __init__(self, sock: socket.socket, conn_id: int):
        self.sock = sock
        self.id = conn_id
        # guards pending/outbuf/socket writes: dispatch lanes write their
        # response directly from the lane thread when it is head-of-line
        # (saves two thread handoffs per request); the IO thread holds
        # the same lock in its read/write paths
        self.lock = threading.Lock()
        self.inbuf = bytearray()
        self.outbuf = bytearray()                 # reused response buffer
        self.pending: collections.deque[_Pending] = collections.deque()
        self.partial: tuple | None = None         # parsed-headers stash
        self.interest = 0                         # selector event mask
        self.stop_reading = False
        self.throttled = False                    # backpressure: no reads
        self.closing = False                      # close once outbuf drains
        self.closed = False
        self.broken = False                       # write error; IO closes


def _parse_one(conn: _Connection) -> tuple | None:
    """One complete request out of ``conn.inbuf`` -> (method, target,
    headers, body, keep_alive), or None when more bytes are needed.
    Raises ``_WireError`` for requests the HTTP layer must reject.

    Incremental: once the header block parses, it is stashed on the
    connection so body bytes arriving later never re-parse headers.
    """
    if conn.partial is None:
        end = conn.inbuf.find(b"\r\n\r\n")
        if end < 0:
            if len(conn.inbuf) > _MAX_HEADER_BYTES:
                raise _WireError(431, "request header block too large")
            return None
        lines = bytes(conn.inbuf[:end]).split(b"\r\n")
        try:
            method_b, target_b, version_b = lines[0].split(b" ", 2)
        except ValueError:
            raise _WireError(400, "malformed request line")
        keep_alive = not version_b.strip().endswith(b"/1.0")
        headers: dict[str, str] = {}
        content_length = 0
        for line in lines[1:]:
            name, sep, value = line.partition(b":")
            if not sep:
                continue
            key = name.decode("latin-1").strip()
            val = value.decode("latin-1").strip()
            headers[key] = val
            low = key.lower()
            if low == "content-length":
                try:
                    content_length = int(val)
                except ValueError:
                    raise _WireError(400, "invalid Content-Length")
                if content_length < 0:
                    raise _WireError(400, "invalid Content-Length")
            elif low == "connection":
                tokens = val.lower()
                if "close" in tokens:
                    keep_alive = False
                elif "keep-alive" in tokens:
                    keep_alive = True
            elif low == "transfer-encoding":
                raise _WireError(501, "Transfer-Encoding is not supported; "
                                      "send a Content-Length body")
        if content_length > _MAX_BODY_BYTES:
            raise _WireError(413, "request body too large")
        conn.partial = (method_b.decode("latin-1"),
                        target_b.decode("latin-1"), headers,
                        end + 4 + content_length, end + 4, keep_alive)
    method, target, headers, total, body_start, keep_alive = conn.partial
    if len(conn.inbuf) < total:
        return None
    body = bytes(conn.inbuf[body_start:total])
    del conn.inbuf[:total]
    conn.partial = None
    return method, target, headers, body, keep_alive


_STUDY_PREFIX = "/api/v2/studies/"
_TRIAL_PREFIX = "/api/v2/trials/"


def _study_key_of_target(target: str) -> str | None:
    """Study key embedded in a v2 URL, for lane affinity."""
    if target.startswith(_STUDY_PREFIX):
        rest = target[len(_STUDY_PREFIX):]
        key = rest.split("/", 1)[0].split("?", 1)[0]
        return key or None
    if target.startswith(_TRIAL_PREFIX):
        rest = target[len(_TRIAL_PREFIX):]
        seg = rest.split("/", 1)[0].split("?", 1)[0]
        key = seg.partition(":")[0]          # uid = "<study_key>:<n>"
        return key or None
    return None


class _Lane(threading.Thread):
    """One dispatch lane: a queue feeding one pinned server worker."""

    def __init__(self, frontend: "EventLoopFrontend", idx: int):
        super().__init__(daemon=True, name=f"hopaas-lane-{idx}")
        self.frontend = frontend
        self.idx = idx
        self.queue: queue.SimpleQueue = queue.SimpleQueue()
        self.busy = False                    # mid-request (inline gate)
        self.handled = 0                     # stats (single-writer)
        self.inline = 0                      # requests run on the IO thread
        self.cache_hits = 0

    def run(self) -> None:
        fe = self.frontend
        while True:
            item = self.queue.get()
            if item is None:
                return
            self.busy = True
            fe._execute(self, item)
            self.busy = False


class EventLoopFrontend:
    """Event-loop HTTP server over a list of ``HopaasServer`` workers.

    ``lanes`` bounds the dispatch pool (default: 2×cores, capped at 8).
    The listening socket binds in the constructor so ``host``/``port``
    are known before ``start()`` — same contract as the threaded
    frontend.
    """

    def __init__(self, workers: list, host: str = "127.0.0.1",
                 port: int = 0, lanes: int | None = None,
                 drain_seconds: float = 5.0, inline: bool | None = None,
                 dispatcher: Any = None, reuseport: bool = False,
                 extra_port: int | None = None):
        # ``dispatcher`` extends the crc32 study-key lane dispatch across
        # the process boundary (the shard fabric): each request is offered
        # to ``dispatcher.handle(lane, method, target, headers, body,
        # keep_alive)`` first — bytes returned are the (already encoded)
        # response, usually proxied from the owning worker process; None
        # falls through to the local workers.  A dispatcher may block on
        # upstream sockets, so inline dispatch is disabled with one.
        if not workers and dispatcher is None:
            raise ValueError("at least one server worker is required")
        self.workers = list(workers)
        self.dispatcher = dispatcher
        self._drain_seconds = float(drain_seconds)
        if dispatcher is not None:
            inline = False
        if not self.workers:
            inline = False
        elif inline is None:
            # Inline dispatch skips two thread handoffs per request, but
            # runs the handler on the IO thread.  Under the GIL that is
            # a straight win for handlers that never *block* — pure
            # in-memory dispatch is GIL-serialized whichever thread runs
            # it.  A storage engine that can sleep in fsync (journal /
            # durable backends) must stay on the lanes, or one group
            # commit would stall every connection.
            try:
                backend = self.workers[0].storage.storage_stats().get(
                    "backend")
            except Exception:
                backend = None
            inline = backend == "memory"
        self._inline_ok = bool(inline)
        if lanes is None:
            lanes = max(2, min(8, 2 * (os.cpu_count() or 2)))
        elif lanes < 1:
            raise ValueError(f"lanes must be >= 1, got {lanes}")
        self._lanes = [_Lane(self, i) for i in range(int(lanes))]
        self._listener = self._make_listener(host, port, reuseport)
        self.host, self.port = self._listener.getsockname()[:2]
        # optional second accept socket on a shared port (SO_REUSEPORT):
        # fabric workers accept straight off the public port where the
        # platform supports it, with the router proxy as the portable
        # fallback accept point on the same port
        self._extra_listener = None
        if extra_port is not None:
            if not hasattr(socket, "SO_REUSEPORT"):
                raise OSError("SO_REUSEPORT is not supported here")
            self._extra_listener = self._make_listener(host, extra_port,
                                                       True)
        self._sel = selectors.DefaultSelector()
        self._wake_r, self._wake_w = socket.socketpair()
        self._wake_r.setblocking(False)
        self._wake_w.setblocking(False)
        self._done: queue.SimpleQueue = queue.SimpleQueue()
        self._conns: dict[int, _Connection] = {}
        self._conn_seq = itertools.count()
        self._thread: threading.Thread | None = None
        # one-way False->True shutdown flag; GIL-atomic bool that the IO
        # loop re-reads every wakeup, so a stale read costs one iteration
        self._closing = False  # repro-check: allow(shared-state)
        self._started = False
        self._stopped = False
        # response cache (wire fast path) — workers share storage/tokens
        self._storage = self.workers[0].storage if self.workers else None
        self._tokens = self.workers[0].tokens if self.workers else None
        self._cache_lock = threading.Lock()
        # writes serialized by _cache_lock; lock-free dict reads are
        # GIL-atomic and every hit is re-validated against the shard's
        # data_version before being served
        self._study_cache: dict[str, tuple[int, bytes, bytes]] = {}  # repro-check: allow(shared-state)
        # idempotent write-once cache: every writer stores identical
        # frozen bytes, so duplicate lock-free stores are benign
        self._v1_version_response: bytes | None = None  # repro-check: allow(shared-state)

    @staticmethod
    def _make_listener(host: str, port: int,
                       reuseport: bool) -> socket.socket:
        return open_server_socket(host, port, reuseport=reuseport,
                                  blocking=False)

    # ------------------------------------------------------------------ #
    # lifecycle
    # ------------------------------------------------------------------ #
    def start(self) -> "EventLoopFrontend":
        self._started = True
        _acquire_fast_switch()
        for lane in self._lanes:
            lane.start()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="hopaas-evloop")
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._stopped:
            return
        self._stopped = True
        if not self._started:
            self._listener.close()
            if self._extra_listener is not None:
                self._extra_listener.close()
            return
        self._closing = True
        self._wake()
        if self._thread is not None:
            self._thread.join(timeout=self._drain_seconds + 2.0)
        for lane in self._lanes:
            lane.queue.put(None)
        for lane in self._lanes:
            lane.join(timeout=1.0)
        _release_fast_switch()

    def stats(self) -> dict[str, Any]:
        return {"backend": "evloop", "lanes": len(self._lanes),
                "requests": sum(l.handled for l in self._lanes),
                "inline_requests": sum(l.inline for l in self._lanes),
                "cache_hits": sum(l.cache_hits for l in self._lanes),
                "cache_entries": len(self._study_cache)}

    # ------------------------------------------------------------------ #
    # dispatch (lane threads; also the IO thread via the inline path)
    # ------------------------------------------------------------------ #
    def _execute(self, lane: _Lane, item: tuple) -> None:
        """Run one queued request to completion (response + flush)."""
        conn, slot, method, target, headers, body, keep_alive = item
        try:
            response = self._handle(lane, method, target, headers, body,
                                    keep_alive)
        except Exception as e:       # the frontend never drops a socket
            blob = _encode_body(error_payload(
                "internal", f"{type(e).__name__}: {e}"))
            response = _encode_response(500, blob, close=not keep_alive,
                                        head_only=method == "HEAD")
        lane.handled += 1
        slot.data = response
        slot.close_after = not keep_alive
        self._complete(conn)

    def _handle(self, lane: _Lane, method: str, target: str,
                headers: dict[str, str], body_bytes: bytes,
                keep_alive: bool) -> bytes:
        if self.dispatcher is not None:
            routed = self.dispatcher.handle(lane, method, target, headers,
                                            body_bytes, keep_alive)
            if routed is not None:
                return routed
            # None: the dispatcher determined this worker owns the study
            # (or has no opinion) — fall through to the local workers
        probe_key = None
        probe_version = -1
        body: Any = None
        body_error: str | None = None
        if method == "GET":
            # GET bodies were drained by the parser and are ignored —
            # same semantics as the threaded frontend
            if self._storage is not None:
                cached = self._cache_probe(lane, target, headers,
                                           keep_alive)
                if cached is not None:
                    return cached
                probe_key = self._cacheable_study_key(target)
                if probe_key is not None:
                    # read the version *before* dispatch: a concurrent
                    # mutation can only make the stored entry
                    # conservatively stale-keyed (next probe misses),
                    # never stale-served
                    probe_version = self._storage.data_version(probe_key)
        elif body_bytes:
            try:
                body = json.loads(body_bytes)
            except json.JSONDecodeError as e:
                body_error = f"request body is not valid JSON: {e.msg}"
        worker = self.workers[lane.idx % len(self.workers)]
        status, payload, extra = worker.handle_request(
            method, target, body, headers, body_error)
        blob = _encode_body(payload)
        if probe_key is not None and status == 200 and probe_version >= 0:
            with self._cache_lock:
                if len(self._study_cache) >= _CACHE_MAX_STUDIES:
                    self._study_cache.pop(next(iter(self._study_cache)))
                self._study_cache[probe_key] = (
                    probe_version, blob, _encode_response(200, blob))
        return _encode_response(status, blob, extra or None,
                                close=not keep_alive,
                                head_only=method == "HEAD")

    @staticmethod
    def _cacheable_study_key(target: str) -> str | None:
        """Key when ``target`` is exactly ``GET /api/v2/studies/{key}`` —
        the one study resource URL (no subpath, query, or verb)."""
        if not target.startswith(_STUDY_PREFIX):
            return None
        rest = target[len(_STUDY_PREFIX):]
        if not rest or "/" in rest or "?" in rest or ":" in rest:
            return None
        return rest

    def _cache_probe(self, lane: _Lane, target: str,
                     headers: dict[str, str],
                     keep_alive: bool) -> bytes | None:
        """Serve a hot GET from the response cache, or None to fall
        through to the router.  Auth is still enforced; anything
        unusual (bad token, unknown study) falls through so the error
        envelope is produced by the one true code path."""
        if target == "/api/version":
            if not keep_alive:
                return None      # rare: build via the normal path
            response = self._v1_version_response
            if response is None:
                status, payload, _ = self.workers[0].handle_request(
                    "GET", target, None, {})
                if status != 200:
                    return None
                # the v1 version payload is byte-frozen — cache forever
                response = _encode_response(status, _encode_body(payload))
                self._v1_version_response = response
            else:
                lane.cache_hits += 1
            return response
        key = self._cacheable_study_key(target)
        if key is None:
            return None
        token = bearer_token(headers)     # the router's parsing policy
        if token is None:
            return None
        try:
            self._tokens.verify(token)
        except Exception:
            return None
        entry = self._study_cache.get(key)
        if entry is None:
            return None
        version, blob, response = entry
        if self._storage.data_version(key) != version:
            return None
        lane.cache_hits += 1
        if not keep_alive:
            return _encode_response(200, blob, close=True)
        return response

    def _complete(self, conn: _Connection) -> None:
        """Called from a lane thread when its response slot is filled.

        Fast path: if this response is head-of-line, write it straight
        from the lane thread — the common one-request-in-flight case
        then never bounces back through the IO thread (two thread
        handoffs saved per request).  Anything left over (partial
        write, connection teardown, selector interest changes) is
        handed to the IO thread, which owns the selector.
        """
        with conn.lock:
            if not conn.closed and not conn.broken:
                self._flush_ready(conn)
                self._write_some(conn)
            needs_io_thread = bool(
                conn.broken or conn.outbuf or conn.throttled
                or (conn.closing and not conn.pending))
        if needs_io_thread:
            self._done.put(conn)
            self._wake()

    def _wake(self) -> None:
        try:
            # repro-check: allow(blocking) -- non-blocking wake pipe;
            # a full pipe means a wakeup is already pending
            self._wake_w.send(b"x")
        except (BlockingIOError, OSError):
            pass                 # wakeup already pending / loop gone

    # ------------------------------------------------------------------ #
    # IO thread
    # ------------------------------------------------------------------ #
    def _loop(self) -> None:
        sel = self._sel
        listeners = [self._listener]
        if self._extra_listener is not None:
            listeners.append(self._extra_listener)
        for lsock in listeners:
            sel.register(lsock, selectors.EVENT_READ, ("accept", lsock))
        sel.register(self._wake_r, selectors.EVENT_READ, ("wake", None))
        listener_open = True
        drain_deadline: float | None = None
        while True:
            if self._closing:
                if listener_open:
                    # clients already in the listen backlog completed
                    # their handshake (and likely sent a request); adopt
                    # them into the drain instead of RSTing them
                    for lsock in listeners:
                        self._accept(lsock)
                        sel.unregister(lsock)
                        lsock.close()
                    listener_open = False
                    drain_deadline = time.monotonic() + self._drain_seconds
                timeout = 0.05
            else:
                timeout = 0.5
            for key, events in sel.select(timeout):
                kind, conn = key.data
                if kind == "accept":
                    self._accept(conn)
                elif kind == "wake":
                    try:
                        # repro-check: allow(blocking) -- draining the
                        # non-blocking wake pipe after readiness
                        while self._wake_r.recv(4096):
                            pass
                    except (BlockingIOError, OSError):
                        pass
                else:
                    if events & selectors.EVENT_READ:
                        self._on_read(conn)
                    if events & selectors.EVENT_WRITE and not conn.closed:
                        self._on_write(conn)
            self._drain_done()
            if self._closing and not listener_open:
                # reap only after a select pass, so requests whose bytes
                # arrived before the shutdown still get parsed, answered,
                # and flushed; a connection with nothing in flight after
                # that pass is genuinely idle
                for conn in [c for c in self._conns.values()
                             if not c.pending and not c.outbuf]:
                    self._close_conn(conn)
                if not self._conns or (drain_deadline is not None
                                       and time.monotonic() > drain_deadline):
                    break
        for conn in list(self._conns.values()):
            self._close_conn(conn)
        if listener_open:
            for lsock in listeners:
                sel.unregister(lsock)
                lsock.close()
        sel.close()
        self._wake_r.close()
        self._wake_w.close()

    def _accept(self, listener: socket.socket | None = None) -> None:
        if listener is None:
            listener = self._listener
        while True:
            try:
                # repro-check: allow(blocking) -- non-blocking listener,
                # called only after select() reported it readable
                sock, _addr = listener.accept()
            except (BlockingIOError, OSError):
                return
            sock.setblocking(False)
            try:
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            except OSError:
                pass
            conn = _Connection(sock, next(self._conn_seq))
            self._conns[conn.id] = conn
            self._set_interest(conn)

    def _on_read(self, conn: _Connection) -> None:
        try:
            # repro-check: allow(blocking) -- non-blocking socket read
            # after readiness; EWOULDBLOCK returns to the loop
            data = conn.sock.recv(_RECV_SIZE)
        except (BlockingIOError, InterruptedError):
            return
        except OSError:
            self._close_conn(conn)
            return
        if not data:                       # peer closed its write side
            with conn.lock:
                conn.stop_reading = True
                idle = not conn.pending and not conn.outbuf
                if not idle:
                    conn.closing = True    # flush in-flight, then close
            if idle:
                self._close_conn(conn)
            else:
                self._set_interest(conn)
            return
        conn.inbuf += data
        dispatches = []
        with conn.lock:
            while True:
                try:
                    request = _parse_one(conn)
                except _WireError as e:
                    slot = _Pending()
                    slot.data = _encode_response(
                        e.status, _encode_body(
                            error_payload("bad_request", e.message)),
                        close=True)
                    slot.close_after = True
                    conn.pending.append(slot)
                    conn.stop_reading = True
                    break
                if request is None:
                    break
                method, target, headers, body, keep_alive = request
                slot = _Pending()
                conn.pending.append(slot)
                dispatches.append(
                    (conn, slot, method, target, headers, body, keep_alive))
            if (len(conn.pending) >= _MAX_PENDING
                    or len(conn.outbuf) >= _MAX_OUTBUF):
                conn.throttled = True      # stop reading until drained
        for item in dispatches:
            lane = self._route(item[3], conn)
            # adaptive inline fast path: when dispatch cannot block (see
            # __init__), the target lane is idle, and this is the
            # connection's only in-flight request, running the handler
            # on the IO thread skips two thread handoffs — the dominant
            # per-request cost for tiny exchanges.  Pipelined bursts and
            # anything queued behind a busy lane still flow through the
            # lanes and keep their study-affinity batching.
            if (self._inline_ok and len(conn.pending) == 1
                    and not lane.busy and lane.queue.empty()):
                lane.inline += 1
                # repro-check: allow(blocking) -- _inline_ok is set only
                # for the pure in-memory backend with no fabric
                # dispatcher (see __init__): nothing on this path can
                # fsync, wait for replication, or touch a socket
                self._execute(lane, item)
            else:
                lane.queue.put(item)
        self._flush(conn)

    def _route(self, target: str, conn: _Connection) -> _Lane:
        key = _study_key_of_target(target)
        if key is None:
            return self._lanes[conn.id % len(self._lanes)]
        return self._lanes[zlib.crc32(key.encode()) % len(self._lanes)]

    def _drain_done(self) -> None:
        while True:
            try:
                conn = self._done.get_nowait()
            except queue.Empty:
                return
            if not conn.closed:
                self._flush(conn)

    @staticmethod
    def _flush_ready(conn: _Connection) -> None:
        """Move ready responses (in request order) into the output
        buffer.  Caller holds ``conn.lock``."""
        while conn.pending and conn.pending[0].data is not None:
            slot = conn.pending.popleft()
            conn.outbuf += slot.data
            if slot.close_after:
                conn.closing = True
                conn.stop_reading = True
                conn.pending.clear()       # never respond past a close
                break

    @staticmethod
    def _write_some(conn: _Connection) -> None:
        """Send as much of the output buffer as the socket accepts.
        Caller holds ``conn.lock``; never raises — write failures mark
        the connection broken for the IO thread to reap."""
        while conn.outbuf:
            try:
                # repro-check: allow(blocking) -- non-blocking socket
                # write; EWOULDBLOCK leaves the rest for the next round
                sent = conn.sock.send(conn.outbuf)
            except (BlockingIOError, InterruptedError):
                return
            except OSError:
                conn.broken = True
                return
            if not sent:
                return
            del conn.outbuf[:sent]

    def _flush(self, conn: _Connection) -> None:
        """IO-thread flush: drain ready slots, write, then reconcile
        selector interest / teardown (lanes cannot touch the selector)."""
        with conn.lock:
            self._flush_ready(conn)
            self._write_some(conn)
            if (conn.throttled and len(conn.pending) < _MAX_PENDING // 2
                    and len(conn.outbuf) < _MAX_OUTBUF // 2):
                conn.throttled = False     # drained: resume reading
            done = conn.broken or (conn.closing and not conn.outbuf
                                   and not conn.pending)
        if done:
            self._close_conn(conn)
        else:
            self._set_interest(conn)

    def _on_write(self, conn: _Connection) -> None:
        self._flush(conn)

    def _set_interest(self, conn: _Connection) -> None:
        events = 0
        if not conn.stop_reading and not conn.throttled:
            events |= selectors.EVENT_READ
        if conn.outbuf:
            events |= selectors.EVENT_WRITE
        if events == conn.interest:
            return
        try:
            if events == 0:
                self._sel.unregister(conn.sock)
            elif conn.interest == 0:
                self._sel.register(conn.sock, events, ("conn", conn))
            else:
                self._sel.modify(conn.sock, events, ("conn", conn))
        except (KeyError, ValueError, OSError):
            pass
        conn.interest = events

    def _close_conn(self, conn: _Connection) -> None:
        with conn.lock:
            if conn.closed:
                return
            conn.closed = True
            if conn.interest:
                try:
                    self._sel.unregister(conn.sock)
                except (KeyError, ValueError, OSError):
                    pass
                conn.interest = 0
            try:
                conn.sock.close()
            except OSError:
                pass
        self._conns.pop(conn.id, None)
