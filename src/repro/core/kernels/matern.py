"""Fused Matérn-5/2 cross-covariance for the GP sampler.

The seed implementation built the (A, B) kernel matrix through an
(A, B, D) pairwise-difference tensor.  Expanding the squared distance,

    d²[a,b] = |as_a|² + |bs_b|² - 2 as_a · bs_b     (as = a/ls, bs = b/ls)

turns it into one (A, D)x(D, B) matmul plus rank-1 terms, which the
Pallas kernel folds into a single augmented contraction per tile
(aug_a = [-2·as, |as|², 1], aug_b = [bs, 1, |bs|²]) followed by the
element-wise Matérn form — no rank-3 intermediate in either backend.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._backend import backend as _select_backend
from ._backend import largest_divisor_block

_SQRT5 = math.sqrt(5.0)


def _matern_form(d2: jax.Array) -> jax.Array:
    d = jnp.sqrt(jnp.maximum(d2, 1e-12))
    s5d = _SQRT5 * d
    return (1.0 + s5d + s5d * s5d / 3.0) * jnp.exp(-s5d)


def _matern_kernel(aa_ref, bb_ref, out_ref):
    aa = aa_ref[...].astype(jnp.float32)               # (ba, D+2)
    bb = bb_ref[...].astype(jnp.float32)               # (bb, D+2)
    d2 = jax.lax.dot_general(
        aa, bb, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)            # (ba, bb) = d²
    out_ref[...] = _matern_form(d2).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _matern_pallas_impl(aa: jax.Array, bb: jax.Array, *,
                        interpret: bool = False) -> jax.Array:
    A, da = aa.shape
    B, _ = bb.shape
    ba = largest_divisor_block(A, 128)
    bb_blk = largest_divisor_block(B, 128)
    return pl.pallas_call(
        _matern_kernel,
        grid=(A // ba, B // bb_blk),
        in_specs=[
            pl.BlockSpec((ba, da), lambda i, j: (i, 0)),
            pl.BlockSpec((bb_blk, da), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((ba, bb_blk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((A, B), jnp.float32),
        interpret=interpret,
    )(aa, bb)


def matern52_cross(a: jax.Array, b: jax.Array, ls: jax.Array, *,
                   backend: str | None = None) -> jax.Array:
    """(A, B) Matérn-5/2 cross-covariance of two point sets on the unit
    cube with per-dim lengthscales ``ls``.  Jit-composable."""
    be = backend or _select_backend()
    as_ = a / ls
    bs = b / ls
    sa = jnp.sum(as_ * as_, axis=-1)                   # (A,)
    sb = jnp.sum(bs * bs, axis=-1)                     # (B,)
    if be == "jnp":
        d2 = sa[:, None] + sb[None, :] - 2.0 * (as_ @ bs.T)
        return _matern_form(d2)
    ones_a = jnp.ones_like(sa)[:, None]
    ones_b = jnp.ones_like(sb)[:, None]
    aa = jnp.concatenate([-2.0 * as_, sa[:, None], ones_a], axis=1)
    bb = jnp.concatenate([bs, ones_b, sb[:, None]], axis=1)
    return _matern_pallas_impl(aa, bb,
                               interpret=(be == "pallas_interpret"))
