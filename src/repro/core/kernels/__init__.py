"""Fused acquisition kernels for the HPO service samplers.

Two backends per op, selected automatically:

  * ``pallas`` — real TPU kernels (flash-attention-style tiling, online
    logsumexp) that never materialize the (candidates, observations, dim)
    intermediate the naive formulation implies;
  * ``jnp``    — pure jax.numpy fallback with the same matmul-form math
    (still avoids the rank-3 intermediate), used off-TPU and under
    ``JAX_PLATFORMS=cpu`` CI so the fallback path stays exercised.

Selection: ``REPRO_HPO_KERNELS`` env var (``pallas`` | ``pallas_interpret``
| ``jnp``) wins; otherwise ``pallas`` on a TPU backend, ``jnp`` elsewhere.
``pallas_interpret`` runs the Pallas kernels in interpret mode (Python
emulation) — slow, but it lets CPU tests exercise the kernel bodies.

All public ops are jit-composable: the backend branch happens at trace
time, so they can be called from inside ``jax.jit``-ted sampler code.
"""
from __future__ import annotations

from ._backend import backend
from .matern import matern52_cross
from .parzen import parzen_log_density

__all__ = ["backend", "matern52_cross", "parzen_log_density"]
