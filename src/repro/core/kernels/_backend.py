"""Backend selection shared by the acquisition kernels (see package doc)."""
from __future__ import annotations

import os

import jax

_VALID = ("pallas", "pallas_interpret", "jnp")


def backend() -> str:
    """The kernel backend in effect for this process."""
    env = os.environ.get("REPRO_HPO_KERNELS", "").strip().lower()
    if env:
        if env not in _VALID:
            raise ValueError(
                f"REPRO_HPO_KERNELS={env!r}; expected one of {_VALID}")
        return env
    try:
        on_tpu = jax.default_backend() == "tpu"
    except Exception:          # backend discovery can fail in odd sandboxes
        on_tpu = False
    return "pallas" if on_tpu else "jnp"


def largest_divisor_block(n: int, cap: int) -> int:
    """Largest block size <= cap dividing n (grids need exact tiling)."""
    b = min(cap, n)
    while n % b:
        b -= 1
    return b
