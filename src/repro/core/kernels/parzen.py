"""Fused TPE Parzen-mixture log-density.

The TPE acquisition scores C candidates against N observations under a
per-dimension truncated-Gaussian mixture:

    out[c] = logsumexp_n[ sum_d( -0.5 z²  - log(bw_d √2π) ) ],
    z = (x[c,d] - obs[n,d]) / bw_d

The naive formulation materializes the (C, N, D) ``z`` tensor.  Expanding
the square turns the inner sum into a matmul over D:

    logk[c,n] = xs_c · os_n - 0.5|xs_c|² - (0.5|os_n|² + Σ_d log(bw_d√2π))
    (xs = x / bw, os = obs / bw)

so the whole score is one (C, D)x(D, N) contraction plus rank-1 terms —
MXU-shaped, no rank-3 intermediate.  The per-candidate term is pulled out
of the logsumexp (it is constant in n) and the per-observation term is
folded into the matmul by augmenting each operand with one extra column
(xa = [xs, -1], oa = [os, so]), so the Pallas kernel is a single tiled
matmul with a flash-attention-style *online logsumexp* across observation
tiles: running (max, sumexp) state lives in VMEM scratch across the
sequential trailing grid axis and the (C, N) score matrix never exists in
HBM either.

Masked observations (padding rows) get ``so = +LARGE`` which drives their
scores to -inf; if a whole tile is masked the online rescale wipes its
(garbage) contribution as soon as a valid tile arrives — callers always
have >= 1 valid observation.

The ``jnp`` fallback uses the same matmul-form math without the tiling.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._backend import backend as _select_backend
from ._backend import largest_divisor_block

NEG_INF = -1e30


def _parzen_kernel(xa_ref, oa_ref, out_ref, m_scr, l_scr, *,
                   n_obs_blocks: int):
    ni = pl.program_id(1)

    @pl.when(ni == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    xa = xa_ref[...].astype(jnp.float32)               # (bc, D+1)
    oa = oa_ref[...].astype(jnp.float32)               # (bn, D+1)
    s = jax.lax.dot_general(
        xa, oa, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)            # (bc, bn)

    m_prev = m_scr[...]                                # (bc, 128)
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)          # (bc, 1)
    m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
    alpha = jnp.exp(m_prev - m_new)                    # rescale old sum
    p = jnp.exp(s - m_new[:, :1])                      # (bc, bn)
    l_new = alpha * l_prev + jnp.broadcast_to(
        jnp.sum(p, axis=1, keepdims=True), l_prev.shape)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ni == n_obs_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-37)             # fully-masked guard
        out_ref[...] = (jnp.log(l) + m_scr[...]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _parzen_pallas(xa: jax.Array, oa: jax.Array, *,
                   interpret: bool = False) -> jax.Array:
    C, da = xa.shape
    N, _ = oa.shape
    bc = largest_divisor_block(C, 128)
    bn = largest_divisor_block(N, 128)
    n_obs_blocks = N // bn
    out = pl.pallas_call(
        functools.partial(_parzen_kernel, n_obs_blocks=n_obs_blocks),
        grid=(C // bc, n_obs_blocks),    # trailing obs axis runs in order
        in_specs=[
            pl.BlockSpec((bc, da), lambda ci, ni: (ci, 0)),
            pl.BlockSpec((bn, da), lambda ci, ni: (ni, 0)),
        ],
        out_specs=pl.BlockSpec((bc, 128), lambda ci, ni: (ci, 0)),
        out_shape=jax.ShapeDtypeStruct((C, 128), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bc, 128), jnp.float32),        # running max
            pltpu.VMEM((bc, 128), jnp.float32),        # running sumexp
        ],
        interpret=interpret,
    )(xa, oa)
    return out[:, 0]


def parzen_log_density(x: jax.Array, obs: jax.Array, mask: jax.Array,
                       bw: jax.Array, *, backend: str | None = None
                       ) -> jax.Array:
    """(C,) masked Parzen-mixture log-density of candidates ``x``.

    x: (C, D) candidates; obs: (N, D) observations (padded);
    mask: (N,) validity; bw: (D,) per-dim bandwidths.  Jit-composable —
    the backend branch resolves at trace time.
    """
    be = backend or _select_backend()
    xs = x / bw
    os_ = obs / bw
    sx = 0.5 * jnp.sum(xs * xs, axis=-1)                          # (C,)
    log_norm = jnp.sum(jnp.log(bw * math.sqrt(2 * math.pi)))
    so = 0.5 * jnp.sum(os_ * os_, axis=-1) + log_norm             # (N,)
    if be == "jnp":
        s = xs @ os_.T - so[None, :]                              # (C, N)
        s = jnp.where(mask[None, :] > 0, s, -jnp.inf)
        return jax.scipy.special.logsumexp(s, axis=1) - sx
    so_masked = jnp.where(mask > 0, so, -NEG_INF)    # +1e30: kill padding
    xa = jnp.concatenate([xs, -jnp.ones_like(sx)[:, None]], axis=1)
    oa = jnp.concatenate([os_, so_masked[:, None]], axis=1)
    out = _parzen_pallas(xa, oa, interpret=(be == "pallas_interpret"))
    return out - sx
