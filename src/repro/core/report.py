"""Study reporting — the CSV/ASCII stand-in for the paper's web dashboard."""
from __future__ import annotations

import json
from typing import Any

from .types import Direction, Study, TrialState


def convergence_trace(study: Study) -> list[float]:
    """Best-so-far objective after each completed trial (ordered by finish)."""
    sign = 1.0 if study.config.direction == Direction.MINIMIZE else -1.0
    done = sorted(study.completed(), key=lambda t: t.finished_at or 0.0)
    best, trace = float("inf"), []
    for t in done:
        best = min(best, sign * t.value)
        trace.append(sign * best)
    return trace


def study_summary(study: Study) -> dict[str, Any]:
    best = study.best_trial()
    states = [t.state for t in study.trials]
    return {
        "name": study.config.name,
        "key": study.key,
        "direction": study.config.direction.value,
        "sampler": study.config.sampler,
        "pruner": study.config.pruner,
        "n_trials": len(study.trials),
        "n_completed": states.count(TrialState.COMPLETED),
        "n_pruned": states.count(TrialState.PRUNED),
        "n_failed": states.count(TrialState.FAILED),
        "n_running": states.count(TrialState.RUNNING),
        "best_value": None if best is None else best.value,
        "best_params": None if best is None else best.params,
        "total_steps": sum(len(t.intermediates) for t in study.trials),
    }


def format_report(study: Study) -> str:
    s = study_summary(study)
    lines = [f"study {s['name']} [{s['key']}]  direction={s['direction']}",
             f"  sampler={s['sampler']}  pruner={s['pruner']}",
             f"  trials: {s['n_trials']} total | {s['n_completed']} completed | "
             f"{s['n_pruned']} pruned | {s['n_failed']} failed | {s['n_running']} running",
             f"  best value: {s['best_value']}",
             f"  best params: {json.dumps(s['best_params'], default=str)}"]
    return "\n".join(lines)
