"""Incremental observation cache — the ask-hot-path accelerator.

Before this cache, every ``ask`` re-featurized the *entire* trial history
(per-trial ``space.to_unit_vector`` in a Python loop, per-dim ``math.log``)
to rebuild the ``(X, y)`` observation matrix the numeric samplers (TPE /
GP / CMA-ES) consume, making ask cost O(n_trials * dim) in pure Python.
The cache instead appends one featurized row per *completion event*:

  * the storage shard keeps an append-only ``completed_log`` of trials
    that became observations (COMPLETED with a value) plus a mutation
    ``version`` counter;
  * ``sync`` compares one integer, consumes only log entries it has not
    seen, and featurizes them with the vectorized space codec — O(new),
    O(1) for the common ask-after-ask case;
  * rows live in amortized-doubling buffers kept at power-of-two capacity
    so the padded views handed to jitted/Pallas kernels keep a stable
    shape signature across history growth (one recompile per doubling,
    not per trial count).

Row order: internally rows sit in completion order; ``observations()``
returns them sorted by ``trial_id`` through a lazily-maintained
permutation so the result is bit-identical to the from-scratch
``Sampler.observations`` scan (which walks ``study.trials`` in id order).
That keeps sampler proposals byte-for-byte reproducible whether or not
the cache is used, including across journal replay.

Pending view (constant liar): when constructed with ``liar != "none"``
the cache additionally tracks the study's RUNNING (leased) trials and
exposes ``augmented()`` — the observed rows followed by one fantasy row
per in-flight trial whose objective is imputed from the observed values
(``min`` = optimistic, ``mean`` = neutral, ``max`` = pessimistic, all in
minimization sign).  Pending-aware samplers consume this view so their
acquisition repels points other workers are already evaluating instead
of handing N concurrent asks near-identical proposals.  Pending rows are
rebuilt wholesale from the shard's RUNNING index on sync (sorted by
trial id, one vectorized featurization) — the same construction a
from-scratch scan or a WAL replay produces, so augmented buffers stay
bit-identical across recovery too.

Thread-safety: sync/reads are performed under the owning study's shard
lock (the server serializes per-study request handling on it).
``snapshot()`` captures an immutable read view that is safe to hand to
a sampler *off* the lock (the speculative precompute path): every array
it exposes is either a fancy-index copy or a fresh concatenation, never
one of the live append buffers.
"""
from __future__ import annotations

import numpy as np

from .space import SearchSpace
from .types import Direction, Trial, TrialState

_MIN_CAPACITY = 8

#: accepted constant-liar imputation modes ("none" disables the pending
#: view entirely — the cache behaves exactly like the pre-liar version)
LIAR_MODES = ("none", "min", "mean", "max")


def check_liar(mode: str) -> str:
    if mode not in LIAR_MODES:
        raise ValueError(f"unknown liar mode {mode!r}; "
                         f"expected one of {LIAR_MODES}")
    return mode


def liar_value(y: np.ndarray, mode: str) -> float:
    """Imputed objective for in-flight trials (minimization sign).

    One definition shared by the cache and the from-scratch sampler path
    so both produce bit-identical fantasy rows (``mean`` is computed as
    sum/n over the trial-id-ordered values on purpose — a different
    summation order would differ in the last ulp).
    """
    if mode == "min":
        return float(np.min(y))
    if mode == "max":
        return float(np.max(y))
    return float(np.sum(y) / len(y))


def pad_pow2(n: int, lo: int = _MIN_CAPACITY) -> int:
    """Smallest power of two >= n (floor ``lo``) — the shared padding
    width for cache capacity and the samplers' jit-stable buffers.  One
    definition: cached and from-scratch paths must agree on shapes."""
    return max(lo, 1 << max(n - 1, 0).bit_length())


class ObservationCache:
    """Incrementally maintained ``(X, y)`` of a study's observations."""

    def __init__(self, space: SearchSpace, direction: Direction,
                 liar: str = "none"):
        self._space = space
        self._sign = 1.0 if direction == Direction.MINIMIZE else -1.0
        self._liar = check_liar(liar)
        cap = _MIN_CAPACITY
        self._X = np.zeros((cap, space.dim), dtype=np.float64)
        self._y = np.zeros(cap, dtype=np.float64)
        self._ids = np.zeros(cap, dtype=np.int64)
        self._n = 0
        self._log_position = 0        # consumed prefix of the completion log
        self._version = -2            # last storage version seen (fast no-op)
        self._ordered: tuple[np.ndarray, np.ndarray] | None = None
        self._padded: tuple[np.ndarray, np.ndarray, np.ndarray] | None = None
        # pending (RUNNING) trials, sorted by trial_id: fantasy rows for
        # the constant-liar view.  _pending_fp bumps only when the
        # pending *set* changes, so sampler memos keyed on `token` stay
        # valid across syncs that only renewed leases.
        self._pending_ids: list[int] = []
        self._pending_X = np.zeros((0, space.dim), dtype=np.float64)
        self._pending_fp = 0
        self._aug: tuple[np.ndarray, np.ndarray] | None = None
        self._aug_padded: tuple[np.ndarray, np.ndarray,
                                np.ndarray] | None = None

    # -- properties ------------------------------------------------------
    @property
    def count(self) -> int:
        return self._n

    @property
    def capacity(self) -> int:
        return len(self._y)

    @property
    def liar(self) -> str:
        return self._liar

    @property
    def pending_count(self) -> int:
        return len(self._pending_ids)

    @property
    def pending_ids(self) -> tuple[int, ...]:
        return tuple(self._pending_ids)

    @property
    def version(self) -> int:
        """Storage mutation version the cache was last synced at."""
        return self._version

    @property
    def token(self) -> tuple[int, int]:
        """Cheap identity of the cache *contents* — changes iff the
        observed rows or the pending set changed.  Sampler memo key."""
        return (self._n, self._pending_fp)

    # -- ingestion -------------------------------------------------------
    def sync(self, storage, study_key: str) -> "ObservationCache":
        """Pull completion events the cache has not seen.  Call under the
        study's shard lock.  One int compare when nothing changed."""
        version = storage.data_version(study_key)
        if version == self._version:
            return self
        new = storage.completed_since(study_key, self._log_position)
        if new:
            self._append(new)
            self._log_position += len(new)
        if self._liar != "none":
            self._sync_pending(storage, study_key)
        self._version = version
        return self

    def _sync_pending(self, storage, study_key: str) -> None:
        """Rebuild the fantasy rows from the shard's RUNNING index.

        Wholesale rebuild (not incremental): pending sets are small and
        churn on every ask/tell, and building from the sorted RUNNING
        list in one vectorized featurization is exactly what a replayed
        shard produces — bit-identical buffers across recovery."""
        running = storage.trials_in_state(study_key, TrialState.RUNNING)
        running.sort(key=lambda t: t.trial_id)
        ids = [t.trial_id for t in running]
        if ids == self._pending_ids:
            return
        self._pending_ids = ids
        self._pending_X = (
            self._space.to_unit_matrix([t.params for t in running])
            if running else np.zeros((0, self._space.dim), dtype=np.float64))
        self._pending_fp += 1
        self._aug = None
        self._aug_padded = None

    def _append(self, trials: list[Trial]) -> None:
        k = len(trials)
        need = self._n + k
        if need > self.capacity:
            cap = pad_pow2(need)
            X = np.zeros((cap, self._space.dim), dtype=np.float64)
            y = np.zeros(cap, dtype=np.float64)
            ids = np.zeros(cap, dtype=np.int64)
            X[: self._n] = self._X[: self._n]
            y[: self._n] = self._y[: self._n]
            ids[: self._n] = self._ids[: self._n]
            self._X, self._y, self._ids = X, y, ids
        rows = self._space.to_unit_matrix([t.params for t in trials])
        self._X[self._n: need] = rows
        self._y[self._n: need] = [self._sign * t.value for t in trials]
        self._ids[self._n: need] = [t.trial_id for t in trials]
        self._n = need
        self._ordered = None
        self._padded = None
        self._aug = None          # liar value depends on the observed set
        self._aug_padded = None

    # -- read views ------------------------------------------------------
    def observations(self) -> tuple[np.ndarray, np.ndarray]:
        """(X, y) in trial-id order — bit-identical to the from-scratch
        ``Sampler.observations`` scan.  Cached until the next append."""
        if self._ordered is None:
            n = self._n
            order = np.argsort(self._ids[:n], kind="stable")
            self._ordered = (self._X[:n][order], self._y[:n][order])
        return self._ordered

    def padded(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(X, y, mask) zero-padded to the pow-2 capacity, trial-id order.
        Stable shapes across asks -> stable jit signatures."""
        if self._padded is None:
            cap = pad_pow2(self._n)
            X = np.zeros((cap, self._space.dim), dtype=np.float64)
            y = np.zeros(cap, dtype=np.float64)
            mask = np.zeros(cap, dtype=np.float64)
            Xs, ys = self.observations()
            X[: self._n], y[: self._n], mask[: self._n] = Xs, ys, 1.0
            self._padded = (X, y, mask)
        return self._padded

    # -- pending (constant-liar) views -----------------------------------
    def liar_value(self) -> float | None:
        """Imputed objective for fantasy rows, or None when the liar is
        off or there is nothing observed to impute from."""
        if self._liar == "none" or self._n == 0:
            return None
        return liar_value(self.observations()[1], self._liar)

    def augmented(self) -> tuple[np.ndarray, np.ndarray]:
        """(X, y) of observed rows followed by one liar-imputed row per
        RUNNING trial (trial-id order within each group).  Falls back to
        ``observations()`` when the liar is off, nothing is pending, or
        nothing has been observed yet."""
        lv = self.liar_value()
        if lv is None or not self._pending_ids:
            return self.observations()
        if self._aug is None:
            Xo, yo = self.observations()
            k = len(self._pending_ids)
            self._aug = (np.concatenate([Xo, self._pending_X]),
                         np.concatenate([yo, np.full(k, lv)]))
        return self._aug

    def padded_augmented(self) -> tuple[np.ndarray, np.ndarray,
                                        np.ndarray]:
        """``augmented()`` zero-padded to pow-2 with a validity mask —
        the pending-aware analogue of ``padded()``."""
        if self._aug_padded is None:
            Xa, ya = self.augmented()
            n = len(ya)
            cap = pad_pow2(n)
            X = np.zeros((cap, self._space.dim), dtype=np.float64)
            y = np.zeros(cap, dtype=np.float64)
            mask = np.zeros(cap, dtype=np.float64)
            X[:n], y[:n], mask[:n] = Xa, ya, 1.0
            self._aug_padded = (X, y, mask)
        return self._aug_padded

    def snapshot(self) -> "CacheSnapshot":
        """Frozen read view for off-lock sampler compute.  Take it under
        the shard lock; use it anywhere."""
        return CacheSnapshot(self)


class CacheSnapshot:
    """Immutable point-in-time view of an ``ObservationCache``.

    Exposes the same read surface the samplers consume (``count``,
    ``observations``/``augmented``/``padded``/``padded_augmented``,
    ``liar_value``, ``token``) plus the storage ``version`` the cache
    was synced at — the tag a speculative proposal buffer is published
    under.  The underlying arrays are the cache's memoized copies
    (fancy-index copies / fresh concatenations, never the live append
    buffers), so reading them off the shard lock is safe; the padded
    views are materialized eagerly for the same reason.
    """

    __slots__ = ("version", "count", "pending_count", "token", "liar",
                 "_obs", "_aug", "_padded", "_aug_padded", "_lv")

    def __init__(self, cache: ObservationCache):
        self.version = cache.version
        self.count = cache.count
        self.pending_count = cache.pending_count
        self.token = cache.token
        self.liar = cache.liar
        self._obs = cache.observations()
        self._aug = cache.augmented()
        self._padded = cache.padded()
        self._aug_padded = cache.padded_augmented()
        self._lv = cache.liar_value()

    def observations(self) -> tuple[np.ndarray, np.ndarray]:
        return self._obs

    def augmented(self) -> tuple[np.ndarray, np.ndarray]:
        return self._aug

    def padded(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        return self._padded

    def padded_augmented(self) -> tuple[np.ndarray, np.ndarray,
                                        np.ndarray]:
        return self._aug_padded

    def liar_value(self) -> float | None:
        return self._lv

    def with_fantasies(self, X_unit: np.ndarray) -> "CacheSnapshot":
        """A new snapshot with ``X_unit`` rows appended as liar-imputed
        pending rows — the speculative precompute uses this to chain
        the constant-liar across streamed proposal slices (slice i+1 is
        repelled from slice i the same way a live ask is repelled from
        in-flight trials).  No-op view of the same observed data; the
        liar value and version tag are unchanged."""
        k = len(X_unit)
        if k == 0 or self._lv is None:
            return self
        out = object.__new__(CacheSnapshot)
        out.version = self.version
        out.count = self.count
        out.pending_count = self.pending_count + k
        # distinct token -> samplers memoizing on (id, token) can never
        # confuse the extended view with its parent
        out.token = (self.token[0], self.token[1] + k)
        out.liar = self.liar
        out._obs = self._obs
        out._lv = self._lv
        Xa, ya = self._aug
        Xa = np.concatenate([Xa, np.asarray(X_unit, dtype=np.float64)])
        ya = np.concatenate([ya, np.full(k, self._lv)])
        out._aug = (Xa, ya)
        out._padded = self._padded
        n = len(ya)
        cap = pad_pow2(n)
        Xp = np.zeros((cap, Xa.shape[1]), dtype=np.float64)
        yp = np.zeros(cap, dtype=np.float64)
        mask = np.zeros(cap, dtype=np.float64)
        Xp[:n], yp[:n], mask[:n] = Xa, ya, 1.0
        out._aug_padded = (Xp, yp, mask)
        return out
