"""Incremental observation cache — the ask-hot-path accelerator.

Before this cache, every ``ask`` re-featurized the *entire* trial history
(per-trial ``space.to_unit_vector`` in a Python loop, per-dim ``math.log``)
to rebuild the ``(X, y)`` observation matrix the numeric samplers (TPE /
GP / CMA-ES) consume, making ask cost O(n_trials * dim) in pure Python.
The cache instead appends one featurized row per *completion event*:

  * the storage shard keeps an append-only ``completed_log`` of trials
    that became observations (COMPLETED with a value) plus a mutation
    ``version`` counter;
  * ``sync`` compares one integer, consumes only log entries it has not
    seen, and featurizes them with the vectorized space codec — O(new),
    O(1) for the common ask-after-ask case;
  * rows live in amortized-doubling buffers kept at power-of-two capacity
    so the padded views handed to jitted/Pallas kernels keep a stable
    shape signature across history growth (one recompile per doubling,
    not per trial count).

Row order: internally rows sit in completion order; ``observations()``
returns them sorted by ``trial_id`` through a lazily-maintained
permutation so the result is bit-identical to the from-scratch
``Sampler.observations`` scan (which walks ``study.trials`` in id order).
That keeps sampler proposals byte-for-byte reproducible whether or not
the cache is used, including across journal replay.

Thread-safety: sync/reads are performed under the owning study's shard
lock (the server serializes per-study request handling on it).
"""
from __future__ import annotations

import numpy as np

from .space import SearchSpace
from .types import Direction, Trial

_MIN_CAPACITY = 8


def pad_pow2(n: int, lo: int = _MIN_CAPACITY) -> int:
    """Smallest power of two >= n (floor ``lo``) — the shared padding
    width for cache capacity and the samplers' jit-stable buffers.  One
    definition: cached and from-scratch paths must agree on shapes."""
    return max(lo, 1 << max(n - 1, 0).bit_length())


class ObservationCache:
    """Incrementally maintained ``(X, y)`` of a study's observations."""

    def __init__(self, space: SearchSpace, direction: Direction):
        self._space = space
        self._sign = 1.0 if direction == Direction.MINIMIZE else -1.0
        cap = _MIN_CAPACITY
        self._X = np.zeros((cap, space.dim), dtype=np.float64)
        self._y = np.zeros(cap, dtype=np.float64)
        self._ids = np.zeros(cap, dtype=np.int64)
        self._n = 0
        self._log_position = 0        # consumed prefix of the completion log
        self._version = -2            # last storage version seen (fast no-op)
        self._ordered: tuple[np.ndarray, np.ndarray] | None = None
        self._padded: tuple[np.ndarray, np.ndarray, np.ndarray] | None = None

    # -- properties ------------------------------------------------------
    @property
    def count(self) -> int:
        return self._n

    @property
    def capacity(self) -> int:
        return len(self._y)

    # -- ingestion -------------------------------------------------------
    def sync(self, storage, study_key: str) -> "ObservationCache":
        """Pull completion events the cache has not seen.  Call under the
        study's shard lock.  One int compare when nothing changed."""
        version = storage.data_version(study_key)
        if version == self._version:
            return self
        new = storage.completed_since(study_key, self._log_position)
        if new:
            self._append(new)
            self._log_position += len(new)
        self._version = version
        return self

    def _append(self, trials: list[Trial]) -> None:
        k = len(trials)
        need = self._n + k
        if need > self.capacity:
            cap = pad_pow2(need)
            X = np.zeros((cap, self._space.dim), dtype=np.float64)
            y = np.zeros(cap, dtype=np.float64)
            ids = np.zeros(cap, dtype=np.int64)
            X[: self._n] = self._X[: self._n]
            y[: self._n] = self._y[: self._n]
            ids[: self._n] = self._ids[: self._n]
            self._X, self._y, self._ids = X, y, ids
        rows = self._space.to_unit_matrix([t.params for t in trials])
        self._X[self._n: need] = rows
        self._y[self._n: need] = [self._sign * t.value for t in trials]
        self._ids[self._n: need] = [t.trial_id for t in trials]
        self._n = need
        self._ordered = None
        self._padded = None

    # -- read views ------------------------------------------------------
    def observations(self) -> tuple[np.ndarray, np.ndarray]:
        """(X, y) in trial-id order — bit-identical to the from-scratch
        ``Sampler.observations`` scan.  Cached until the next append."""
        if self._ordered is None:
            n = self._n
            order = np.argsort(self._ids[:n], kind="stable")
            self._ordered = (self._X[:n][order], self._y[:n][order])
        return self._ordered

    def padded(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(X, y, mask) zero-padded to the pow-2 capacity, trial-id order.
        Stable shapes across asks -> stable jit signatures."""
        if self._padded is None:
            cap = pad_pow2(self._n)
            X = np.zeros((cap, self._space.dim), dtype=np.float64)
            y = np.zeros(cap, dtype=np.float64)
            mask = np.zeros(cap, dtype=np.float64)
            Xs, ys = self.observations()
            X[: self._n], y[: self._n], mask[: self._n] = Xs, ys, 1.0
            self._padded = (X, y, mask)
        return self._padded
