"""Multi-worker optimization campaigns (paper sec. 4).

Drives N concurrent HOPAAS clients — the stand-in for the >20 heterogeneous
MARCONI-100 / INFN-Cloud / GCP nodes of the paper — against one service.
Workers are *elastic*: they can join late, leave early, or die mid-trial
(``failure_rate``); the server's lease/requeue machinery absorbs all of it.

``transport_factory`` is called once per worker.  It may return a fresh
transport each time (one socket per node — the distributed shape) or
one shared ``PooledHttpTransport`` (all workers draw from a bounded
keep-alive pool; checkout/checkin keeps concurrent workers off each
other's sockets without opening N connections).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable

import numpy as np

from .client import Client, HopaasError, Study, Trial
from .transport import Transport
from .types import Direction, StudyConfig


def _safe_tell(study: Study, trial: Trial, value: float | None,
               state: str | None) -> None:
    try:
        study.tell(trial, value=value, state=state)
    except HopaasError:
        pass      # server already finalized the trial (lease sweep / prune)

# objective(trial_params, report) -> float, where report(step, value) -> bool
Objective = Callable[[dict[str, Any], Callable[[int, float], bool]], float]


@dataclasses.dataclass
class CampaignResult:
    n_trials: int
    n_completed: int
    n_pruned: int
    n_failed: int
    best_value: float | None
    best_params: dict[str, Any] | None
    wall_seconds: float
    trials_per_worker: dict[str, int]


def run_campaign(objective: Objective, *, study_spec: dict[str, Any],
                 transport_factory: Callable[[], Transport], token: str,
                 n_workers: int = 8, n_trials: int = 64,
                 failure_rate: float = 0.0, stagger_seconds: float = 0.0,
                 batch_size: int = 1, seed: int = 0) -> CampaignResult:
    """Run ``n_trials`` total across ``n_workers`` concurrent workers.

    With ``batch_size > 1`` each worker claims up to ``batch_size`` trials
    per round and uses the batched wire protocol — one ``ask_batch`` round
    trip to suggest them and one ``tell_batch`` to finalize the survivors —
    instead of 2·k sequential round trips.
    """
    counter_lock = threading.Lock()
    issued = {"n": 0}
    per_worker: dict[str, int] = {}
    rng = np.random.default_rng(seed)
    fail_draws = rng.uniform(size=n_trials * 2)
    t0 = time.time()

    def worker(widx: int) -> None:
        if stagger_seconds:
            time.sleep(stagger_seconds * widx)   # elastic late join
        wid = f"node-{widx:02d}"
        client = Client(transport_factory(), token, worker_id=wid)
        study = Study(client=client, **study_spec)
        while True:
            with counter_lock:
                if issued["n"] >= n_trials:
                    return
                k = min(max(1, batch_size), n_trials - issued["n"])
                first_idx = issued["n"]
                issued["n"] += k
                per_worker[wid] = per_worker.get(wid, 0) + k
            trials = study.ask_batch(k) if batch_size > 1 else [study.ask()]
            finished: list[tuple] = []
            for j, trial in enumerate(trials):
                die = (failure_rate > 0
                       and fail_draws[first_idx + j] < failure_rate)

                def report(step: int, value: float, _t=trial) -> bool:
                    return _t.should_prune(step, value)

                try:
                    value = objective(trial.params, report)
                except Exception:
                    finished.append((trial, None, "failed"))
                    continue
                if die:
                    continue      # worker "crashes": never tells -> lease expires
                # a worker may lose the race against the lease sweeper (it
                # was declared dead and its trial requeued); the server's
                # verdict wins — losing this tell is the designed straggler
                # behavior.
                finished.append(
                    (trial, value, "pruned" if trial.pruned else None))
            if batch_size > 1:
                try:
                    study.tell_batch(finished)
                except HopaasError:
                    pass          # whole-batch transport failure: leases expire
            else:
                for trial, value, state in finished:
                    _safe_tell(study, trial, value, state)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(n_workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    # summarize through the service API (what the web UI would show):
    # the study key is content-addressed, so it can be derived locally and
    # its v2 resource fetched directly — a pure read (no study list scan,
    # and no accidental create if every worker died before its first ask)
    client = Client(transport_factory(), token)
    probe = Study(client=client, **study_spec)
    key = StudyConfig(
        name=probe.name, properties=probe.properties,
        direction=Direction(probe.direction), sampler=probe.sampler,
        pruner=probe.pruner, directions=probe.directions).key()
    try:
        s: dict[str, Any] = client.study(key)
    except HopaasError:
        s = {}
    return CampaignResult(
        n_trials=s.get("n_trials", 0), n_completed=s.get("n_completed", 0),
        n_pruned=s.get("n_pruned", 0), n_failed=s.get("n_failed", 0),
        best_value=s.get("best_value"), best_params=s.get("best_params"),
        wall_seconds=time.time() - t0, trials_per_worker=per_worker)
