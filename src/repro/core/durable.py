"""Durable storage engine: snapshots + segmented WAL + group-commit fsync.

The paper's deployment leans on PostgreSQL for *shared persistency to the
multiple instances of the web application backend* (sec. 3).  The
single-file ``JournalStorage`` reproduces the durability role but not its
operational properties: the log grows without bound, recovery replays the
whole lifetime, and nothing is ever fsynced.  ``DurableStorage`` is the
real engine:

* **Segmented WAL** — mutations append to ``wal-<n>.jsonl``; when the
  active segment passes ``segment_bytes`` it is sealed (fsynced, closed)
  and a new one opened.  Sealed segments are immutable.
* **Snapshots** — ``snapshot-<n>.json`` holds the full store state
  (``InMemoryStorage.state_record``) as of the end of segment ``n``.
  Snapshots are written atomically (tmp + rename + dir fsync).
* **Background compaction** — a daemon thread folds sealed segments into
  a fresh snapshot by replaying them into a *shadow* store built from the
  previous snapshot, then deletes the folded files.  Compaction reads
  only immutable files, so it never takes a live shard lock and never
  stalls traffic.
* **Group-commit durability** — three modes:
    - ``always``: the mutation is acknowledged only after an fsync covers
      its record.  Concurrent writers share fsyncs (classic group
      commit): whoever grabs the in-flight slot syncs everything written
      so far and wakes the rest.
    - ``group``: the mutation is acknowledged once written to the OS; a
      flusher thread issues one fsync per ``group_interval`` window, so
      the loss window after a power failure is bounded by the interval
      (and sealing always fsyncs).
    - ``off``: no fsync (crash-consistent against process death, not
      power loss) — the mode for tests and throwaway runs.
* **Recovery** = load the newest snapshot + replay only the segment tail
  past it — O(new work since the last compaction), not O(lifetime).  A
  torn final record (crash mid-append) in the *last* segment is truncated
  with a warning; corruption anywhere else raises
  ``CorruptJournalError``.  Recovered state is index-for-index identical
  to the pre-crash store — ``InMemoryStorage.state_digest`` is the
  equality witness used by the tests.

Layout of ``root``::

    snapshot-00000007.json   state as of the end of segment 7
    wal-00000008.jsonl       sealed, awaiting compaction
    wal-00000009.jsonl       active

Every restart seals the previous active segment (repaired if torn) and
starts a fresh one, so segment files are append-only for their lifetime.
"""
from __future__ import annotations

import enum
import json
import logging
import os
import re
import socket
import threading
import time
from typing import Any

from . import faults
from .storage import (CorruptJournalError, InMemoryStorage,
                      load_journal_file)

try:                                    # POSIX only; see _acquire_dir_lock
    import fcntl
except ImportError:                     # pragma: no cover - non-POSIX
    fcntl = None

logger = logging.getLogger("repro.storage")


class WalDirectoryLockedError(RuntimeError):
    """Another live process already owns this WAL directory.  Two writers
    appending to the same segment stream would interleave records and
    corrupt the log, so the second opener is refused outright."""

_SNAP_RE = re.compile(r"snapshot-(\d{8})\.json$")
_SEG_RE = re.compile(r"wal-(\d{8})\.jsonl$")


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except (PermissionError, OSError):
        return True
    return True


def _describe_lock_meta(meta_path: str) -> str:
    """Human-readable holder description from a ``LOCK.meta`` file, with
    an explicit staleness verdict: a meta whose pid is dead describes a
    *previous* holder, not whoever owns the flock now."""
    try:
        with open(meta_path) as f:
            meta = json.load(f)
    except (OSError, ValueError):
        return ""
    pid = meta.get("pid")
    host = meta.get("host", "?")
    started = meta.get("started_at")
    when = (time.strftime("%Y-%m-%dT%H:%M:%S", time.localtime(started))
            if isinstance(started, (int, float)) else "?")
    state = ("live" if isinstance(pid, int) and _pid_alive(pid)
             else "stale: meta pid is dead")
    return (f"; holder meta: pid {pid} on {host} since {when} ({state})")


class FsyncMode(str, enum.Enum):
    ALWAYS = "always"       # ack after fsync (batched across writers)
    GROUP = "group"         # ack after write; fsync per commit window
    OFF = "off"             # never fsync (tests / throwaway runs)


class DurableStorage(InMemoryStorage):
    """Snapshot + segmented-WAL storage engine (see module docstring)."""

    # replication hooks (see attach_replicator): inert by default so a
    # plain DurableStorage behaves exactly as before
    _replicator = None
    _semisync = False

    def __init__(self, root: str, *, fsync: str | FsyncMode = FsyncMode.GROUP,
                 segment_bytes: int = 4 * 1024 * 1024,
                 group_interval: float = 0.005,
                 auto_compact: bool = True, compact_min_segments: int = 1):
        self._journal_lock = threading.Lock()
        super().__init__()
        self.root = root
        self.fsync_mode = FsyncMode(fsync)
        self.segment_bytes = max(1, int(segment_bytes))
        self.group_interval = float(group_interval)
        self.auto_compact = bool(auto_compact)
        self.compact_min_segments = max(1, int(compact_min_segments))
        # append bookkeeping (under _journal_lock)
        self._seq = 0                    # records appended this process
        # monotone high-water mark: advanced only under _journal_lock;
        # sampled under _durable_cv by the fsync protocol, where a stale
        # read merely shrinks one group-commit batch
        self._written_seq = 0  # repro-check: allow(shared-state)
        self._records = 0
        self._bytes = 0
        self._rotations = 0
        self._closed = False
        # fsync protocol (under _durable_cv)
        self._durable_cv = threading.Condition()
        # monotone; the flusher's lock-free peek can only skip an fsync
        # that another writer already covered
        self._durable_seq = 0  # repro-check: allow(shared-state)
        self._fsync_inflight = False
        self._fsync_count = 0
        self._commits = 0                # fsync batches (group commits)
        # compaction
        self._compact_lock = threading.Lock()
        # threading.Event is internally synchronized and never rebound
        self._compact_event = threading.Event()  # repro-check: allow(shared-state)
        # stats below are written by the compactor under _compact_lock;
        # storage_stats() snapshots them lock-free for observability
        self._compactions = 0  # repro-check: allow(shared-state)
        self._last_compaction: dict[str, Any] | None = None  # repro-check: allow(shared-state)
        self._covers = 0  # repro-check: allow(shared-state) -- last segment folded into a snapshot
        # threads (started lazily)
        self._stop = threading.Event()
        # write-once thread handles: every spawn site holds _journal_lock
        # (or runs before the instance is published); close() only joins
        self._flusher: threading.Thread | None = None  # repro-check: allow(shared-state)
        self._compactor: threading.Thread | None = None  # repro-check: allow(shared-state)

        os.makedirs(root, exist_ok=True)
        self._lock_file = self._acquire_dir_lock()
        self._recover()
        # always start a fresh segment: repaired/previous files stay sealed
        existing = self._segment_indexes()
        self._active_index = max(existing + [self._covers]) + 1
        # swapped only by _rotate_locked while holding both _journal_lock
        # and the fsync-inflight slot; the fsyncing thread samples it with
        # that same slot held, so writer and syncer can never interleave
        self._active_file = open(  # repro-check: allow(shared-state)
            self._segment_path(self._active_index), "ab")
        self._active_size = 0
        if self.auto_compact and any(i < self._active_index for i in existing):
            self._start_compactor()
            self._compact_event.set()

    # ------------------------------------------------------------------ #
    # directory ownership
    # ------------------------------------------------------------------ #
    def _acquire_dir_lock(self):
        """Take an exclusive advisory lock on ``root/.lock`` so two live
        processes can never append to the same segment stream.  The lock
        dies with the process (kernel-released on crash), so a killed
        worker never wedges its directory.  On platforms without fcntl
        the guard is skipped."""
        if fcntl is None:               # pragma: no cover - non-POSIX
            return None
        lock_path = os.path.join(self.root, ".lock")
        meta_path = os.path.join(self.root, "LOCK.meta")
        f = open(lock_path, "a+")
        try:
            fcntl.flock(f.fileno(), fcntl.LOCK_EX | fcntl.LOCK_NB)
        except OSError:
            holder = ""
            try:
                f.seek(0)
                holder = f.read(64).strip()
            except OSError:
                pass
            f.close()
            raise WalDirectoryLockedError(
                f"WAL directory {self.root!r} is locked by another live "
                f"process{f' (pid {holder})' if holder else ''}"
                f"{_describe_lock_meta(meta_path)}; two "
                f"writers on one segment stream would corrupt the log")
        f.seek(0)
        f.truncate()
        f.write(f"{os.getpid()}\n")
        f.flush()
        try:        # holder metadata for the refusal message above
            with open(meta_path, "w") as mf:
                json.dump({"pid": os.getpid(),
                           "host": socket.gethostname(),
                           "started_at": time.time()}, mf)
        except OSError:                 # pragma: no cover - best effort
            pass
        return f

    def _release_dir_lock(self) -> None:
        f = getattr(self, "_lock_file", None)
        if f is None:
            return
        self._lock_file = None
        try:
            if fcntl is not None:
                fcntl.flock(f.fileno(), fcntl.LOCK_UN)
        except OSError:                 # pragma: no cover
            pass
        f.close()
        try:
            os.remove(os.path.join(self.root, "LOCK.meta"))
        except OSError:
            pass

    # ------------------------------------------------------------------ #
    # paths
    # ------------------------------------------------------------------ #
    def _segment_path(self, index: int) -> str:
        return os.path.join(self.root, f"wal-{index:08d}.jsonl")

    def _snapshot_path(self, covers: int) -> str:
        return os.path.join(self.root, f"snapshot-{covers:08d}.json")

    def _segment_indexes(self) -> list[int]:
        out = []
        for name in os.listdir(self.root):
            m = _SEG_RE.fullmatch(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def _snapshot_indexes(self) -> list[int]:
        out = []
        for name in os.listdir(self.root):
            m = _SNAP_RE.fullmatch(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def _fsync_dir(self) -> None:
        try:
            fd = os.open(self.root, os.O_RDONLY)
        except OSError:              # platform without directory fds
            return
        try:
            os.fsync(fd)
        except OSError:
            pass
        finally:
            os.close(fd)

    # ------------------------------------------------------------------ #
    # recovery: latest snapshot + segment-tail replay
    # ------------------------------------------------------------------ #
    def _recover(self) -> None:
        t0 = time.perf_counter()
        for name in os.listdir(self.root):     # crash mid-snapshot-write
            if name.endswith(".tmp"):
                os.remove(os.path.join(self.root, name))
        snaps = self._snapshot_indexes()
        covers = snaps[-1] if snaps else 0
        snapshot_trials = 0
        if covers:
            with open(self._snapshot_path(covers), "rb") as f:
                try:
                    snap = json.load(f)
                except json.JSONDecodeError as e:
                    raise CorruptJournalError(
                        f"unreadable snapshot {self._snapshot_path(covers)}: "
                        f"{e.msg}") from e
            self.load_state(snap["state"])
            snapshot_trials = sum(len(s["study"]["trials"])
                                  for s in snap["state"]["studies"])
        for stale in snaps[:-1]:               # superseded snapshots
            os.remove(self._snapshot_path(stale))
        segments = self._segment_indexes()
        for folded in [i for i in segments if i <= covers]:
            # folded into the snapshot; the pre-crash compactor died
            # between the rename and the delete
            os.remove(self._segment_path(folded))
        tail = [i for i in segments if i > covers]
        replayed, torn = 0, False
        self._replaying = True
        try:
            for j, index in enumerate(tail):
                n, t = load_journal_file(
                    self._segment_path(index), self._apply,
                    # only the newest segment can have a torn tail: older
                    # ones were sealed with an fsync before rotation
                    tolerate_torn_tail=(j == len(tail) - 1), repair=True)
                torn = torn or t
                replayed += n
        finally:
            self._replaying = False
        self._covers = covers
        self.last_recovery = {
            "snapshot_covers": covers,
            "snapshot_trials": snapshot_trials,
            "segments_replayed": len(tail),
            "records_replayed": replayed,
            "torn_tail": torn,
            "seconds": round(time.perf_counter() - t0, 6),
        }

    # ------------------------------------------------------------------ #
    # WAL append + group-commit fsync
    # ------------------------------------------------------------------ #
    # repro-check: allow(blocking-under-lock) -- the durability contract:
    # a mutation is acknowledged only after its WAL record is fsynced
    # (and, in semi-sync, follower-acked).  Callers hold the shard lock
    # across _log by design; group commit amortizes the stall.
    def _log(self, record: dict[str, Any]) -> None:
        if self._replaying:
            return
        # strict JSON: NaN/Infinity would make the segment unreadable
        text = json.dumps(record, allow_nan=False)
        line = (text + "\n").encode()
        pub = 0
        # sampled under the journal lock: attach_replicator can swap the
        # hub concurrently (promotion), and the ack wait below must go to
        # the hub that assigned ``pub``, not whichever is current by then
        rep = None
        semi = False
        with self._journal_lock:
            if self._closed:
                return
            f = self._active_file
            f.write(line)
            f.flush()                   # in the OS before we advance seq
            self._seq += 1
            seq = self._seq
            self._written_seq = seq
            self._active_size += len(line)
            self._records += 1
            self._bytes += len(line)
            rep = self._replicator
            semi = self._semisync
            if rep is not None:
                # under the journal lock: stream position order is
                # exactly file order (publish is O(1), no I/O)
                pub = rep.publish(text)
            if self._active_size >= self.segment_bytes:
                self._rotate_locked()
            if self.fsync_mode is FsyncMode.GROUP:
                self._start_flusher()
        if self.fsync_mode is FsyncMode.ALWAYS:
            self._ensure_durable(seq)
        if pub and semi:
            # the ack is only as strong as the weakest link: locally
            # durable (above) AND held by a live follower (here)
            rep.wait_ack(pub)

    def _ensure_durable(self, seq: int) -> None:
        """Block until an fsync covers ``seq`` — the group-commit core.
        One thread grabs the in-flight slot and syncs everything written
        so far; the rest ride on its notify."""
        while True:
            with self._durable_cv:
                if self._durable_seq >= seq:
                    return
                if self._fsync_inflight:
                    self._durable_cv.wait(timeout=1.0)
                    continue
                self._fsync_inflight = True
                target = self._written_seq
                f = self._active_file
            synced = False
            try:
                faults.crash("crash_before_fsync")
                os.fsync(f.fileno())
                faults.crash("crash_after_fsync")
                synced = True
            finally:
                with self._durable_cv:
                    self._fsync_inflight = False
                    if synced:
                        self._durable_seq = max(self._durable_seq, target)
                        self._fsync_count += 1
                        self._commits += 1
                    self._durable_cv.notify_all()

    # repro-check: allow(blocking-under-lock) -- sealing fsyncs the old
    # segment under the journal lock on purpose: the swap of the active
    # file handle must be atomic with respect to appenders.
    def _rotate_locked(self) -> None:
        """Seal the active segment and open the next (caller holds the
        journal lock).  Takes the fsync slot so no concurrent fsync can
        race the file handle being closed."""
        with self._durable_cv:
            while self._fsync_inflight:
                self._durable_cv.wait()
            self._fsync_inflight = True
        sealed_seq = self._written_seq
        try:
            f = self._active_file
            f.flush()
            if self.fsync_mode is not FsyncMode.OFF:
                os.fsync(f.fileno())
            f.close()
            self._active_index += 1
            self._active_file = open(
                self._segment_path(self._active_index), "ab")
            self._active_size = 0
            self._rotations += 1
        finally:
            with self._durable_cv:
                self._fsync_inflight = False
                if self.fsync_mode is not FsyncMode.OFF:
                    self._durable_seq = max(self._durable_seq, sealed_seq)
                    self._fsync_count += 1
                self._durable_cv.notify_all()
        if self.auto_compact:
            self._start_compactor()
            self._compact_event.set()

    # ------------------------------------------------------------------ #
    # replication hooks
    # ------------------------------------------------------------------ #
    def attach_replicator(self, hub, *, semisync: bool = False) -> None:
        """Publish every subsequent WAL append to ``hub`` (under the
        journal lock, so stream order equals file order).  With
        ``semisync`` each write additionally blocks until a live
        follower acknowledges the record, degrading to async when no
        follower is connected (``ReplicationHub.wait_ack``)."""
        with self._journal_lock:
            self._replicator = hub
            self._semisync = bool(semisync)

    def replication_baseline(self) -> dict[str, Any]:
        """Capture (stream position, immutable files) atomically: seal
        the active segment so every record published so far lives in a
        sealed file, pin the hub position under the journal lock, then
        read the files under the compaction lock (same order as
        ``compact``, so a concurrent fold cannot delete a segment
        mid-read)."""
        with self._compact_lock:
            with self._journal_lock:
                if not self._closed and self._active_size:
                    self._rotate_locked()
                active = self._active_index
                pos = (self._replicator.position()
                       if self._replicator is not None else 0)
            covers = self._covers
            snapshot = None
            if covers:
                with open(self._snapshot_path(covers), "r") as f:
                    snapshot = f.read()
            segments = []
            for index in self._segment_indexes():
                if covers < index < active:
                    with open(self._segment_path(index), "r") as f:
                        segments.append(f.read())
            return {"pos": pos, "covers": covers, "snapshot": snapshot,
                    "segments": segments}

    # ------------------------------------------------------------------ #
    # segment shipping (the fabric shard-handoff unit)
    # ------------------------------------------------------------------ #
    def seal_active(self) -> int:
        """Seal the active segment (fsync + close) and open the next.
        After this returns, every record appended so far lives in an
        immutable file — the precondition for ``read_immutable_files``.
        Returns the index of the newly opened active segment."""
        with self._journal_lock:
            if not self._closed:
                self._rotate_locked()
            return self._active_index

    def read_immutable_files(self) -> dict[str, Any]:
        """The current snapshot + every sealed segment, as shippable
        payloads.  Reads only immutable files (same rule as compaction),
        under the compaction lock so a concurrent fold cannot delete a
        segment mid-read.  Callers that need the payload to cover *all*
        acknowledged mutations must call ``seal_active`` first."""
        with self._compact_lock:
            with self._journal_lock:
                active = self._active_index
            covers = self._covers
            snapshot = None
            if covers:
                with open(self._snapshot_path(covers), "r") as f:
                    snapshot = f.read()
            segments = []
            for index in self._segment_indexes():
                if covers < index < active:
                    with open(self._segment_path(index), "r") as f:
                        segments.append(f.read())
            return {"covers": covers, "snapshot": snapshot,
                    "segments": segments}

    # ------------------------------------------------------------------ #
    # background threads
    # ------------------------------------------------------------------ #
    def _start_flusher(self) -> None:
        if self._flusher is None:
            self._flusher = threading.Thread(
                target=self._flusher_loop, daemon=True,
                name="durable-flusher")
            self._flusher.start()

    def _flusher_loop(self) -> None:
        while not self._stop.wait(self.group_interval):
            with self._journal_lock:
                if self._closed:
                    return
                seq = self._written_seq
            if seq > self._durable_seq:
                self._ensure_durable(seq)

    def _start_compactor(self) -> None:
        if self._compactor is None:
            self._compactor = threading.Thread(
                target=self._compactor_loop, daemon=True,
                name="durable-compactor")
            self._compactor.start()

    def _compactor_loop(self) -> None:
        while True:
            self._compact_event.wait()
            self._compact_event.clear()
            if self._stop.is_set():
                return
            try:
                self.compact()
            except Exception:
                logger.exception("background compaction failed")

    # ------------------------------------------------------------------ #
    # compaction
    # ------------------------------------------------------------------ #
    # repro-check: allow(blocking-under-lock) -- the compaction lock
    # serializes compaction against segment shipping only; appenders
    # and the request path never take it, so fsyncing under it is free.
    def compact(self, min_segments: int | None = None) -> int:
        """Fold sealed segments into a fresh snapshot; delete the folded
        files.  Returns the number of segments folded (0 = nothing to do).

        The snapshot is built by replaying the sealed segments into a
        *shadow* store seeded from the previous snapshot — only immutable
        files are read, so compaction never touches a live shard lock and
        the result is exactly the state a recovery of those files would
        produce.  The new snapshot lands atomically (tmp + rename); only
        then are the old snapshot and folded segments deleted, so a crash
        at any point leaves a recoverable directory.
        """
        with self._compact_lock:
            if self._stop.is_set():
                # a straggler compaction after close() would delete files
                # under a DurableStorage re-opened on the same directory
                return 0
            with self._journal_lock:
                active = self._active_index
            covers = self._covers
            sealed = [i for i in self._segment_indexes()
                      if covers < i < active]
            need = (self.compact_min_segments if min_segments is None
                    else max(1, int(min_segments)))
            if len(sealed) < need:
                return 0
            shadow = InMemoryStorage()
            if covers:
                with open(self._snapshot_path(covers), "rb") as f:
                    shadow.load_state(json.load(f)["state"])
            replayed = 0
            for index in sealed:
                n, _ = load_journal_file(
                    self._segment_path(index), shadow._apply,
                    tolerate_torn_tail=False, repair=False)
                replayed += n
            new_covers = sealed[-1]
            blob = json.dumps({"covers": new_covers,
                               "state": shadow.state_record()},
                              allow_nan=False).encode()
            tmp = self._snapshot_path(new_covers) + ".tmp"
            with open(tmp, "wb") as f:
                f.write(blob)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self._snapshot_path(new_covers))
            self._fsync_dir()
            if covers and os.path.exists(self._snapshot_path(covers)):
                os.remove(self._snapshot_path(covers))
            for index in sealed:
                os.remove(self._segment_path(index))
            self._covers = new_covers
            self._compactions += 1
            self._last_compaction = {"folded_segments": len(sealed),
                                     "records": replayed,
                                     "covers": new_covers}
            return len(sealed)

    # ------------------------------------------------------------------ #
    # durability hooks + stats
    # ------------------------------------------------------------------ #
    def flush(self) -> None:
        """Force everything acknowledged so far to disk (any mode)."""
        with self._journal_lock:
            if self._closed:
                return
            self._active_file.flush()
            seq = self._written_seq
        if seq:
            self._ensure_durable(seq)

    # repro-check: allow(blocking-under-lock) -- shutdown: the final
    # fsync + file close must be atomic with setting _closed, or a
    # racing appender could write into a closed segment.
    def close(self) -> None:
        """Flush, fsync, stop the background threads.  Idempotent."""
        with self._journal_lock:
            if self._closed:
                return
            self._closed = True
            with self._durable_cv:
                while self._fsync_inflight:
                    self._durable_cv.wait()
                self._fsync_inflight = True
            try:
                f = self._active_file
                f.flush()
                os.fsync(f.fileno())
                f.close()
            finally:
                with self._durable_cv:
                    self._fsync_inflight = False
                    self._durable_seq = self._written_seq
                    self._fsync_count += 1
                    self._durable_cv.notify_all()
        self._stop.set()
        self._compact_event.set()          # wake the compactor to exit
        # fence: wait out any in-flight compaction so the directory is
        # safe to re-open the moment close() returns
        with self._compact_lock:
            pass
        for t in (self._flusher, self._compactor):
            if t is not None:
                t.join(timeout=5.0)
        self._release_dir_lock()

    def storage_stats(self) -> dict[str, Any]:
        stats = super().storage_stats()
        with self._journal_lock:
            active = self._active_index
            active_bytes = self._active_size
            records, wal_bytes = self._records, self._bytes
            rotations = self._rotations
        with self._durable_cv:
            fsyncs, commits = self._fsync_count, self._commits
        stats.update({
            "backend": "durable",
            "root": self.root,
            "fsync": self.fsync_mode.value,
            "segment_bytes": self.segment_bytes,
            "snapshot_covers": self._covers,
            "active_segment": active,
            "active_segment_bytes": active_bytes,
            "sealed_segments": sum(
                1 for i in self._segment_indexes() if i < active),
            "wal_records": records,
            "wal_bytes": wal_bytes,
            "fsyncs": fsyncs,
            "group_commits": commits,
            "rotations": rotations,
            "compactions": self._compactions,
            "last_compaction": self._last_compaction,
            "last_recovery": self.last_recovery,
        })
        # lock-free stats snapshot: both fields are rebound atomically by
        # attach_replicator, and a torn mode/hub pairing here only skews
        # one observability read (the durability path samples them under
        # _journal_lock in _log)
        rep = self._replicator  # repro-check: allow(shared-state)
        if rep is not None:
            stats["replication"] = {
                "mode": "semisync" if self._semisync else "async",  # repro-check: allow(shared-state)
                **rep.status()}
        return stats
