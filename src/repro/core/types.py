"""Core datatypes for the HOPAAS service.

Terminology follows the paper (sec. 2):
  * a *trial* is a single training attempt with a specific set of
    hyperparameters to test;
  * a *study* represents an optimization session and includes a collection
    of trials.  A study is unambiguously defined by the set of
    hyperparameters to optimize, their ranges, and the search modality
    (sampler + pruner + direction).
"""
from __future__ import annotations

import dataclasses
import enum
import hashlib
import json
import time
from typing import Any


class TrialState(str, enum.Enum):
    RUNNING = "running"
    COMPLETED = "completed"
    PRUNED = "pruned"
    FAILED = "failed"      # lease expired / worker died


class Direction(str, enum.Enum):
    MINIMIZE = "minimize"
    MAXIMIZE = "maximize"


@dataclasses.dataclass
class Trial:
    """A single hyperparameter evaluation, tracked server-side."""

    trial_id: int                      # index within the study
    uid: str                           # globally unique "study_key:trial_id"
    study_key: str
    params: dict[str, Any]
    state: TrialState = TrialState.RUNNING
    value: float | None = None
    # multi-objective studies (paper sec. 5 future work): one value per
    # objective; ``value`` then mirrors values[0] for display
    values: list[float] | None = None
    # step -> intermediate objective value (fed through should_prune)
    intermediates: dict[int, float] = dataclasses.field(default_factory=dict)
    worker_id: str | None = None
    lease_deadline: float | None = None   # epoch seconds; None = no lease
    created_at: float = dataclasses.field(default_factory=time.time)
    finished_at: float | None = None
    # bookkeeping for fault tolerance: how many times these params were
    # re-enqueued after a worker died mid-trial
    retries: int = 0

    def last_step(self) -> int:
        return max(self.intermediates) if self.intermediates else -1

    @classmethod
    def tombstone(cls, study_key: str, trial_id: int) -> "Trial":
        """Explicit placeholder for a journal gap: a FAILED trial that holds
        the slot so uid->trial lookups of later trials stay aligned."""
        t = cls(trial_id=trial_id, uid=f"{study_key}:{trial_id}",
                study_key=study_key, params={}, state=TrialState.FAILED)
        t.finished_at = t.created_at
        return t

    def to_record(self) -> dict[str, Any]:
        # hot path: journaled on every add/update.  dataclasses.asdict
        # deep-copies recursively (~100us per call); the explicit dict is
        # equivalent for this flat record (params/intermediates values
        # are scalars) at a fraction of the cost.
        return {"trial_id": self.trial_id, "uid": self.uid,
                "study_key": self.study_key, "params": dict(self.params),
                "state": self.state.value, "value": self.value,
                "values": (None if self.values is None
                           else list(self.values)),
                "intermediates": dict(self.intermediates),
                "worker_id": self.worker_id,
                "lease_deadline": self.lease_deadline,
                "created_at": self.created_at,
                "finished_at": self.finished_at, "retries": self.retries}

    @classmethod
    def from_record(cls, d: dict[str, Any]) -> "Trial":
        d = dict(d)
        d["state"] = TrialState(d["state"])
        d["intermediates"] = {int(k): float(v) for k, v in d["intermediates"].items()}
        return cls(**d)


@dataclasses.dataclass
class StudyConfig:
    """Everything that unambiguously defines a study (paper sec. 2)."""

    name: str
    # hyperparameter name -> serialized space spec (see repro.core.space)
    properties: dict[str, Any]
    direction: Direction = Direction.MINIMIZE
    sampler: dict[str, Any] = dataclasses.field(default_factory=lambda: {"name": "tpe"})
    pruner: dict[str, Any] = dataclasses.field(default_factory=lambda: {"name": "none"})
    # multi-objective: per-objective directions; None = single-objective
    directions: list[str] | None = None

    @property
    def n_objectives(self) -> int:
        return len(self.directions) if self.directions else 1

    def direction_signs(self) -> list[float]:
        """+1 per minimized objective, -1 per maximized."""
        if self.directions is None:
            return [1.0 if self.direction == Direction.MINIMIZE else -1.0]
        return [1.0 if Direction(d) == Direction.MINIMIZE else -1.0
                for d in self.directions]

    def key(self) -> str:
        """Content hash used by the server to route `ask` requests."""
        blob = json.dumps(
            {
                "name": self.name,
                "properties": self.properties,
                "direction": self.direction.value,
                "sampler": self.sampler,
                "pruner": self.pruner,
                "directions": self.directions,
            },
            sort_keys=True,
        )
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    def to_record(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d["direction"] = self.direction.value
        return d

    @classmethod
    def from_record(cls, d: dict[str, Any]) -> "StudyConfig":
        d = dict(d)
        d["direction"] = Direction(d["direction"])
        return cls(**d)


@dataclasses.dataclass
class Study:
    config: StudyConfig
    trials: list[Trial] = dataclasses.field(default_factory=list)
    created_at: float = dataclasses.field(default_factory=time.time)
    # -- runtime read-path indices (never serialized) -------------------
    # step -> {trial_uid -> latest reported value}; lets the median /
    # percentile / SHA pruner heartbeats aggregate over "who reported at
    # this step" without scanning every trial's intermediates dict.
    _step_reports: dict[int, dict[str, float]] | None = dataclasses.field(
        default=None, init=False, repr=False, compare=False)
    _last_steps: dict[str, int] = dataclasses.field(
        default_factory=dict, init=False, repr=False, compare=False)
    # (resource, sign) -> {uid -> best sign*value within the resource};
    # built on first SHA/hyperband query, then maintained per report
    _rung_cache: dict[tuple[int, float], dict[str, float]] = dataclasses.field(
        default_factory=dict, init=False, repr=False, compare=False)
    _indexed_trials: int = dataclasses.field(
        default=0, init=False, repr=False, compare=False)
    # True only for studies owned by a storage layer, which routes every
    # mutation through record_report/note_trial_added under the shard
    # lock — the precondition for trusting the incremental indices
    _managed: bool = dataclasses.field(
        default=False, init=False, repr=False, compare=False)

    @property
    def key(self) -> str:
        return self.config.key()

    # -- snapshot serialization ----------------------------------------
    # The storage engine's point-in-time snapshots serialize whole
    # studies; the runtime read-path indices are derived state and are
    # rebuilt on load, never serialized.
    def to_record(self) -> dict[str, Any]:
        return {"config": self.config.to_record(),
                "created_at": self.created_at,
                "trials": [t.to_record() for t in self.trials]}

    @classmethod
    def from_record(cls, d: dict[str, Any]) -> "Study":
        return cls(config=StudyConfig.from_record(d["config"]),
                   trials=[Trial.from_record(t) for t in d["trials"]],
                   created_at=d["created_at"])

    # -- incremental report index --------------------------------------
    # Maintained by the storage layer under the shard lock: every
    # ``update_trial(intermediate=...)`` calls ``record_report`` and every
    # ``add_trial`` calls ``note_trial_added``.  Studies built by hand
    # (tests, library use) are not managed and rebuild the index on every
    # query — the pre-cache live-scan semantics, so direct mutation of
    # ``trial.intermediates`` is always observed.
    def _ensure_index(self) -> None:
        if (self._managed and self._step_reports is not None
                and self._indexed_trials == len(self.trials)):
            return
        idx: dict[int, dict[str, float]] = {}
        last: dict[str, int] = {}
        for t in self.trials:
            for s, v in t.intermediates.items():
                idx.setdefault(s, {})[t.uid] = v
            if t.intermediates:
                last[t.uid] = max(t.intermediates)
        self._step_reports = idx
        self._last_steps = last
        self._rung_cache = {}
        self._indexed_trials = len(self.trials)

    def note_trial_added(self) -> None:
        """O(1) index maintenance for a freshly created (report-less) trial."""
        if (self._managed and self._step_reports is not None
                and self._indexed_trials == len(self.trials) - 1):
            self._indexed_trials += 1

    def record_report(self, uid: str, step: int, value: float) -> None:
        """O(1) index maintenance for one intermediate report."""
        if (not self._managed or self._step_reports is None
                or self._indexed_trials != len(self.trials)):
            return                      # stale: next query rebuilds anyway
        reports = self._step_reports.setdefault(step, {})
        re_report = uid in reports
        reports[uid] = value
        if step > self._last_steps.get(uid, -1):
            self._last_steps[uid] = step
        for (resource, sign), rung in self._rung_cache.items():
            if step + 1 > resource:
                continue
            if not re_report:
                sv = sign * value
                if sv < rung.get(uid, float("inf")):
                    rung[uid] = sv
            else:
                # a step's value was *replaced* (client retry): the min is
                # not incrementally updatable, recompute this uid's entry
                # from its latest-per-step reports
                rung[uid] = min(
                    sign * reps[uid]
                    for s, reps in self._step_reports.items()
                    if s + 1 <= resource and uid in reps)

    def reports_at(self, step: int) -> dict[str, float]:
        """{trial_uid: latest value reported at ``step``} from the index."""
        self._ensure_index()
        return self._step_reports.get(step, {})

    def _rung_snapshot(self, resource: int, sign: float) -> dict[str, float]:
        self._ensure_index()
        key = (int(resource), float(sign))
        snap = self._rung_cache.get(key)
        if snap is None:
            snap = {}
            for s, reports in self._step_reports.items():
                if s + 1 <= resource:
                    for uid, v in reports.items():
                        sv = sign * v
                        if sv < snap.get(uid, float("inf")):
                            snap[uid] = sv
            self._rung_cache[key] = snap
        return snap

    def rung_value(self, uid: str, resource: int, sign: float) -> float | None:
        """Best sign*value ``uid`` achieved within ``resource`` steps."""
        return self._rung_snapshot(resource, sign).get(uid)

    def rung_competitors(self, resource: int, sign: float,
                         exclude_uid: str) -> list[float]:
        """Rung values of every *other* trial that reached the rung."""
        snap = self._rung_snapshot(resource, sign)
        last = self._last_steps
        return [v for uid, v in snap.items()
                if uid != exclude_uid and last.get(uid, -1) + 1 >= resource]

    def completed(self) -> list[Trial]:
        return [t for t in self.trials if t.state == TrialState.COMPLETED]

    def best_trial(self) -> Trial | None:
        done = [t for t in self.completed() if t.value is not None]
        if not done:
            return None
        sign = 1.0 if self.config.direction == Direction.MINIMIZE else -1.0
        return min(done, key=lambda t: sign * t.value)

    def pareto_front(self) -> list[Trial]:
        """Non-dominated completed trials (multi-objective studies)."""
        signs = self.config.direction_signs()
        done = [t for t in self.completed() if t.values is not None
                and len(t.values) == len(signs)]
        front: list[Trial] = []
        for t in done:
            tv = [s * v for s, v in zip(signs, t.values)]
            dominated = False
            for o in done:
                if o is t:
                    continue
                ov = [s * v for s, v in zip(signs, o.values)]
                if all(a <= b for a, b in zip(ov, tv)) and \
                        any(a < b for a, b in zip(ov, tv)):
                    dominated = True
                    break
            if not dominated:
                front.append(t)
        return front
