"""The HOPAAS server: ask / tell / should_prune / version (paper Table 1),
the batched ask_batch / tell_batch extension, and the v2 resource surface.

The wire layer is declarative (``repro.core.api``): routes are data —
method + path template + typed schemas — dispatched by a router that
enforces validation, header auth, 405-with-Allow, and structured error
envelopes *before* a handler runs.  ``HopaasServer`` itself exposes
transport-independent core operations (``op_ask``/``op_tell``/...) that
raise ``ApiError`` for client failures; the v1 compat shim and the v2
resource routes are both thin adapters over the same ops, mounted by
``api.build_router``.

``handle_request(method, path, body, headers)`` is the full entry point
(status, payload, response headers); ``handle(method, path, body)`` is
the pre-router signature kept for in-process callers.  Multiple
``HopaasServer`` *workers* may share one storage object, reproducing the
paper's "scalable set of Uvicorn instances + shared PostgreSQL"
architecture.

Sharding: the server holds one ``StudyContext`` per study — sampler,
pruner, decoded search space, a per-study RNG, the storage shard's
lock, and an incremental ``ObservationCache``.  All request handling
serializes on the *per-study* lock, so requests for different studies
proceed fully in parallel; there is no global server lock.  Lease
expiry is driven by the storage's per-study deadline min-heap, so
sweeps touch only expired entries instead of scanning every trial.

Hot-path cost model: `ask` syncs the observation cache (O(1) when
nothing completed, O(new) otherwise — never a history rescan) and hands
it to the sampler; intermediate reports aggregate over the study's
per-step indices; study summaries read the incrementally raced
incumbent; paginated trial listings answer from the per-state uid
buckets.  Nothing on the request path scales with trial count.

Fault tolerance beyond the paper's text (needed for 1000+-node campaigns):
  * every RUNNING trial carries a *lease*; intermediate reports act as
    heartbeats that renew it;
  * `sweep_expired()` marks trials whose lease lapsed as FAILED and
    re-enqueues their parameters so another worker picks them up (straggler
    mitigation / elastic membership);
  * all state mutations flow through the (journaled) storage, so a service
    restart resumes every study where it left off.
"""
from __future__ import annotations

import atexit
import dataclasses
import math
import os
import shutil
import tempfile
import threading
import time
from typing import Any, Callable

import numpy as np

from . import faults
from .api import ApiError, build_openapi, build_router
from .api.router import Router
from .auth import TokenManager
from .obs_cache import ObservationCache
from .pruners import make_pruner
from .samplers import make_sampler
from .space import SearchSpace
from .speculate import SpeculativeQueue, SpeculativeWorker
from .storage import InMemoryStorage
from .types import Direction, StudyConfig, Trial, TrialState

HOPAAS_VERSION = "1.1.0-jax"

# the exact key set (and order) of a pre-router /api/studies record —
# the v1 shim projects the richer v2 resource down to this
_V1_STUDY_KEYS = ("key", "name", "n_trials", "n_completed", "n_pruned",
                  "n_failed", "best_value", "best_params")


def _default_storage() -> InMemoryStorage:
    """Storage for servers constructed without one.

    ``REPRO_STORAGE=durable`` switches the default to a ``DurableStorage``
    in a throwaway directory (fsync off — the point is exercising the
    engine's WAL/snapshot/recovery code paths, not disk latency).  CI
    runs the tier-1 suite a second time under this flag so every test
    that builds a bare ``HopaasServer()`` also drives the journaled
    engine.
    """
    mode = os.environ.get("REPRO_STORAGE", "memory")
    if mode.startswith("durable"):
        from .durable import DurableStorage
        root = tempfile.mkdtemp(prefix="hopaas-durable-")
        storage = DurableStorage(root, fsync="off",
                                 segment_bytes=256 * 1024)
        atexit.register(shutil.rmtree, root, ignore_errors=True)
        return storage
    return InMemoryStorage()


def _default_speculate_depth() -> int:
    """Depth of the per-study speculative proposal buffer, from the
    ``REPRO_SPECULATE`` env (0 = off).  Off by default: a bare server's
    proposals must not depend on background-thread timing — speculation
    is opted into per deployment (``--speculate-depth``), per server
    (ctor arg), or per fleet (env, inherited by fabric workers)."""
    try:
        return max(0, int(os.environ.get("REPRO_SPECULATE", "0") or 0))
    except ValueError:
        return 0


def _require_finite_value(value: float | None, field: str = "value") -> None:
    """Non-finite objectives never reach storage: NaN corrupts incumbent
    comparisons and bare NaN/Infinity is invalid strict JSON for the WAL.
    The wire schemas already reject these with a 422; this guards the
    direct in-process op_* callers the same way."""
    if value is not None and not math.isfinite(value):
        raise ApiError(422, "invalid_value",
                       f"field {field!r} must be finite, got {value!r}",
                       field=field)


@dataclasses.dataclass
class StudyContext:
    """Per-study shard of the server: everything `ask`/`tell`/`should_prune`
    need, guarded by the storage shard's lock (shared across workers)."""

    key: str
    config: StudyConfig
    space: SearchSpace
    sampler: Any
    pruner: Any
    lock: threading.RLock
    rng: np.random.Generator
    # incremental (X, y) featurization of this study's observations —
    # synced from the storage's completion log under the shard lock, so
    # ask cost no longer scales with history length
    cache: ObservationCache
    # speculative ask pipeline (None when speculation is off or the
    # sampler cannot precompute): version-tagged proposal buffer drained
    # by op_ask, refilled off-lock by the server's SpeculativeWorker
    spec: SpeculativeQueue | None = None
    # dedicated sampler instance for the precompute thread (built
    # lazily): the request path's sampler memos must never be touched
    # from two threads
    spec_sampler: Any = None
    # precompute round counter — seeds a dedicated rng stream per round,
    # disjoint from ctx.rng (which stays single-threaded on the request
    # path); guarded by ctx.lock
    spec_round: int = 0
    # largest worker-fleet size hint seen on an ask (the v2
    # ``parallelism`` field): raises the effective precompute depth so
    # the buffer covers one full wave of concurrent asks
    parallelism: int = 0


class HopaasServer:
    # precompute rounds publish in slices of at least this many
    # proposals so the first supply lands in the queue while the tail
    # of the round is still computing; each slice is one fused sampler
    # evaluation, so fewer/larger slices also mean faster rounds (the
    # background thread is GIL-starved under a contended fleet and
    # supply rate, not latency, bounds the queue hit rate)
    _SPECULATE_SLICE = 32

    def __init__(self, storage: InMemoryStorage | None = None,
                 tokens: TokenManager | None = None,
                 lease_seconds: float = 60.0, max_retries: int = 3,
                 seed: int = 0, worker_name: str = "worker-0",
                 speculate_depth: int | None = None,
                 speculate_staleness: int | None = None):
        self.storage = storage or _default_storage()
        self.tokens = tokens or TokenManager()
        self.lease_seconds = float(lease_seconds)
        self.max_retries = int(max_retries)
        self.worker_name = worker_name
        self._seed = int(seed)
        self._contexts: dict[str, StudyContext] = {}
        self._ctx_lock = threading.Lock()      # guards context creation only
        self._router: Router | None = None
        self.speculate_depth = (_default_speculate_depth()
                                if speculate_depth is None
                                else max(0, int(speculate_depth)))
        # proposals computed <= this many storage versions ago still
        # drain (the liar rows already anticipated the in-flight trials
        # behind most bumps — registrations, lease renewals, tells).
        # None -> dynamic: scales with the fleet-size hint, since a
        # 256-worker wave legitimately bumps the version ~512 times
        # between a proposal's compute and its drain
        self.speculate_staleness = (None if speculate_staleness is None
                                    else max(0, int(speculate_staleness)))
        self._speculator: SpeculativeWorker | None = None
        if self.speculate_depth > 0:
            self._speculator = SpeculativeWorker(
                self._precompute_study,
                name=f"speculate-{worker_name}")

    def close(self) -> None:
        """Stop the speculative precompute thread (no-op when off)."""
        if self._speculator is not None:
            self._speculator.stop()
            self._speculator = None

    # ------------------------------------------------------------------ #
    # wire entry points
    # ------------------------------------------------------------------ #
    @property
    def router(self) -> Router:
        if self._router is None:
            self._router = build_router(self)
        return self._router

    def handle_request(self, method: str, path: str, body: Any = None,
                       headers: dict[str, str] | None = None,
                       body_error: str | None = None
                       ) -> tuple[int, dict[str, Any], dict[str, str]]:
        """Full dispatch: (status, payload, response headers)."""
        return self.router.dispatch(method, path, body, headers, body_error)

    def handle(self, method: str, path: str, body: dict[str, Any] | None = None
               ) -> tuple[int, dict[str, Any]]:
        """Pre-router signature kept for in-process callers and tests."""
        status, payload, _ = self.handle_request(method, path, body)
        return status, payload

    def openapi_document(self) -> dict[str, Any]:
        return build_openapi(self.router, HOPAAS_VERSION)

    # ------------------------------------------------------------------ #
    # per-study contexts
    # ------------------------------------------------------------------ #
    def _build_context(self, key: str, config: StudyConfig) -> StudyContext:
        space = SearchSpace.from_properties(config.properties)
        sampler = make_sampler(config.sampler)
        # the cache maintains the pending (constant-liar) view only for
        # samplers that consume it — everyone else keeps the exact
        # pre-liar behaviour and sync cost
        liar = (getattr(sampler, "liar", "none")
                if getattr(sampler, "pending_aware", False) else "none")
        speculative = (self._speculator is not None
                       and getattr(sampler, "uses_cache", False)
                       and liar != "none")
        return StudyContext(
            key=key, config=config, space=space,
            sampler=sampler,
            pruner=make_pruner(config.pruner),
            lock=self.storage.study_lock(key),
            # per-study stream: concurrent asks on different studies must
            # not share one (non-thread-safe) Generator
            rng=np.random.default_rng([self._seed, int(key[:8], 16)]),
            cache=ObservationCache(space, config.direction, liar=liar),
            spec=SpeculativeQueue() if speculative else None)

    def _context(self, config: StudyConfig) -> tuple[StudyContext, bool]:
        study, created = self.storage.get_or_create_study(config)
        key = study.key
        with self._ctx_lock:
            ctx = self._contexts.get(key)
            if ctx is None:
                ctx = self._build_context(key, study.config)
                self._contexts[key] = ctx
        return ctx, created

    def evict_context(self, study_key: str) -> None:
        """Forget the cached per-study context (sampler state, observation
        cache, resource cache).  Required when a shard is dropped from the
        backing storage (fabric handoff): a re-adopted study must rebuild
        its context against the new shard, not serve from the stale one."""
        with self._ctx_lock:
            self._contexts.pop(study_key, None)

    def _context_for_key(self, study_key: str) -> StudyContext | None:
        """Context for a study possibly created by another worker."""
        with self._ctx_lock:
            ctx = self._contexts.get(study_key)
        if ctx is not None:
            return ctx
        study = self.storage.get_study(study_key)
        if study is None:
            return None
        with self._ctx_lock:
            ctx = self._contexts.get(study_key)
            if ctx is None:
                ctx = self._build_context(study_key, study.config)
                self._contexts[study_key] = ctx
        return ctx

    # ------------------------------------------------------------------ #
    # study resolution + config validation
    # ------------------------------------------------------------------ #
    @staticmethod
    def _study_config(body: dict[str, Any]) -> StudyConfig:
        return StudyConfig(
            name=body.get("name", "unnamed"),
            properties=body.get("properties", {}),
            direction=Direction(body.get("direction") or "minimize"),
            sampler=body.get("sampler") or {"name": "tpe"},
            pruner=body.get("pruner") or {"name": "none"},
            directions=body.get("directions"),
        )

    def _validate_config(self, config: StudyConfig) -> None:
        """Dry-run the context pieces so a bad spec is a 422 *before* the
        study is persisted — never a 500 and never a poisoned study."""
        try:
            SearchSpace.from_properties(config.properties)
        except Exception as e:
            raise ApiError(422, "invalid_space",
                           f"invalid search space: {e}", field="properties")
        try:
            make_sampler(config.sampler)
        except Exception as e:
            raise ApiError(422, "invalid_sampler", str(e), field="sampler")
        try:
            make_pruner(config.pruner)
        except Exception as e:
            raise ApiError(422, "invalid_pruner", str(e), field="pruner")

    def op_resolve_study(self, spec: dict[str, Any]
                         ) -> tuple[StudyContext, bool]:
        """Create-or-get the study a spec describes (content-addressed)."""
        config = self._study_config(spec)
        if self.storage.get_study(config.key()) is None:
            self._validate_config(config)
        return self._context(config)

    # ------------------------------------------------------------------ #
    # resource serialization
    # ------------------------------------------------------------------ #
    @staticmethod
    def trial_resource(t: Trial) -> dict[str, Any]:
        return {"uid": t.uid, "trial_id": t.trial_id,
                "study_key": t.study_key, "params": t.params,
                "state": t.state.value, "value": t.value, "values": t.values,
                "worker_id": t.worker_id, "retries": t.retries,
                "last_step": t.last_step(), "created_at": t.created_at,
                "finished_at": t.finished_at}

    def study_resource(self, study) -> dict[str, Any]:
        key = study.key
        with self.storage.study_lock(key):
            counts = self.storage.counts(key)
            # incumbent is tracked incrementally on tell — no scan
            best = self.storage.best_trial(key)
            res: dict[str, Any] = {
                "key": key, "name": study.config.name,
                "n_trials": len(study.trials),
                "n_completed": counts[TrialState.COMPLETED],
                "n_pruned": counts[TrialState.PRUNED],
                "n_failed": counts[TrialState.FAILED],
                "best_value": None if best is None else best.value,
                "best_params": None if best is None else best.params,
            }
            if study.config.directions:
                res["pareto_front"] = [
                    {"params": t.params, "values": t.values}
                    for t in study.pareto_front()]
            res.update({
                "n_running": counts[TrialState.RUNNING],
                "direction": study.config.direction.value,
                "directions": study.config.directions,
                "sampler": study.config.sampler.get("name", "tpe"),
                "pruner": study.config.pruner.get("name", "none"),
                # shard mutation counter: mutations replay identically, so
                # the resource stays equal across a crash-restart recovery
                "data_version": self.storage.data_version(key),
            })
        return res

    # ------------------------------------------------------------------ #
    # core operations (raise ApiError on client failures)
    # ------------------------------------------------------------------ #
    # fabric workers replace this with a callable merging their
    # role/epoch/replication view into the health resource
    health_hook: Callable[[], dict[str, Any]] | None = None

    def _lease_deadline(self) -> float:
        """Lease stamp for a suggested/heartbeating trial.  The
        ``lease_skew`` fault point simulates a skewed clock here without
        touching the system clock."""
        return time.time() + self.lease_seconds + faults.skew("lease_skew")

    def op_version(self) -> dict[str, Any]:
        return {"version": HOPAAS_VERSION}

    def op_health(self) -> dict[str, Any]:
        """Machine-readable readiness (``GET /api/v2/health``): role,
        lease epoch, replication lag, WAL/fsync stats — what a load
        balancer or the fabric monitor needs to pick a backend."""
        stats = self.storage.storage_stats()
        storage_keys = ("backend", "n_studies", "fsync", "wal_records",
                        "wal_bytes", "fsyncs", "group_commits",
                        "active_segment", "snapshot_covers")
        health: dict[str, Any] = {
            "status": "ok",
            "version": HOPAAS_VERSION,
            "worker": self.worker_name,
            "role": "leader",
            "epoch": int(getattr(self.storage, "lease_epoch", 0)),
            "replication": stats.get("replication"),
            "storage": {k: stats[k] for k in storage_keys if k in stats},
            "speculation": self.speculation_stats(),
        }
        hook = self.health_hook
        if hook is not None:
            health.update(hook() or {})
        return health

    def op_version_v2(self) -> dict[str, Any]:
        """v2 version resource: adds the storage/durability stats (the v1
        payload is byte-frozen to ``{"version": ...}``)."""
        stats = dict(self.storage.storage_stats())
        stats["speculation"] = self.speculation_stats()
        return {"version": HOPAAS_VERSION, "storage": stats}

    def op_create_study(self, spec: dict[str, Any]
                        ) -> tuple[bool, dict[str, Any]]:
        ctx, created = self.op_resolve_study(spec)
        return created, self.study_resource(self.storage.get_study(ctx.key))

    def op_get_study(self, key: str) -> dict[str, Any]:
        study = self.storage.get_study(key)
        if study is None:
            raise ApiError(404, "study_not_found", f"unknown study {key!r}")
        return self.study_resource(study)

    def op_list_studies(self, cursor: int | None = None, limit: int = 100
                        ) -> tuple[list[dict[str, Any]], int | None]:
        studies = self.storage.studies()      # registry order (stable)
        start = 0 if cursor is None else int(cursor) + 1
        page = studies[start:start + limit]
        next_cursor = (start + len(page) - 1) if len(page) == limit else None
        return [self.study_resource(s) for s in page], next_cursor

    def op_list_trials(self, key: str, state: str | None = None,
                       cursor: int | None = None, limit: int = 100
                       ) -> tuple[list[dict[str, Any]], int | None]:
        page = self.storage.trials_page(
            key, state=None if state is None else TrialState(state),
            cursor=cursor, limit=limit)
        if page is None:
            raise ApiError(404, "study_not_found", f"unknown study {key!r}")
        trials, next_cursor = page
        return [self.trial_resource(t) for t in trials], next_cursor

    def op_get_trial(self, uid: str) -> dict[str, Any]:
        trial = self.storage.get_trial(uid)
        if trial is None:
            raise ApiError(404, "trial_not_found", f"unknown trial {uid!r}")
        return self.trial_resource(trial)

    def op_ask(self, study_key: str, worker_id: str | None, n: int = 1,
               parallelism: int | None = None) -> list[dict[str, Any]]:
        """Suggest ``n`` trials for an *existing* study (v2 path).

        ``parallelism`` is the client's fleet-size hint: the speculative
        precompute sizes its proposal buffer to cover one full wave of
        that many concurrent asks (capped; ignored when speculation is
        off)."""
        ctx = self._context_for_key(study_key)
        if ctx is None:
            raise ApiError(404, "study_not_found",
                           f"unknown study {study_key!r}")
        with ctx.lock:
            if parallelism:
                ctx.parallelism = max(ctx.parallelism,
                                      min(int(parallelism), 4096))
            self._sweep_study(ctx.key, time.time())
            trials = self._start_trials(ctx, n, worker_id)
        return [self.trial_resource(t) for t in trials]

    def op_tell(self, uid: str, value: Any = None,
                state: str = "completed",
                idempotency_key: str | None = None) -> dict[str, Any]:
        # multi-objective: value may be a list (one entry per objective)
        values = None
        if isinstance(value, (list, tuple)):
            values = [float(v) for v in value]
            for i, v in enumerate(values):
                _require_finite_value(v, f"value[{i}]")
            value = values[0]
        elif value is not None:
            _require_finite_value(float(value))
        final_state = TrialState(state or "completed")
        trial = self.storage.get_trial(uid)
        if trial is None:
            raise ApiError(404, "trial_not_found", f"unknown trial {uid!r}")
        with self.storage.study_lock(trial.study_key):
            if idempotency_key:
                prior = self.storage.idempotent_result(
                    trial.study_key, idempotency_key)
                if prior is not None:
                    # a retry of a tell that already applied (lost
                    # response, fabric resend, failover replay): return
                    # the original result — exactly-once, never a 409
                    return dict(prior)
            if trial.state == TrialState.PRUNED:
                # the server already finalized this trial on a report;
                # accept the client's value but keep the PRUNED state.
                out = {"uid": uid, "state": trial.state.value}
                self.storage.update_trial(
                    uid, value=(None if value is None else float(value)),
                    values=values,
                    idem=(None if not idempotency_key
                          else (idempotency_key, out)))
            else:
                if trial.state != TrialState.RUNNING:
                    raise ApiError(409, "conflict",
                                   f"trial {uid} already {trial.state.value}")
                out = {"uid": uid, "state": final_state.value}
                # the dedup note rides in the finalize's own WAL record
                # (one atomic unit through recovery, replication, and
                # migration), so a replica can never hold the finalize
                # without the key that makes its retry recognizable
                self.storage.update_trial(
                    uid, value=(None if value is None else float(value)),
                    values=values, state=final_state,
                    finished_at=time.time(), lease_deadline=None,
                    idem=(None if not idempotency_key
                          else (idempotency_key, out)))
        # a finalize is exactly the event that invalidates precomputed
        # proposals: new observation, smaller pending set
        self._notify_speculator(self._peek_context(trial.study_key))
        return out

    def op_tell_batch(self, tells: list[dict[str, Any]]
                      ) -> list[dict[str, Any]]:
        """Per-item finalization: one conflict never fails the batch."""
        results = []
        for item in tells:
            try:
                out = self.op_tell(item.get("trial_uid", ""),
                                   item.get("value"),
                                   item.get("state") or "completed",
                                   item.get("idempotency_key"))
                results.append({"status": 200, **out})
            except ApiError as e:
                results.append({"status": e.status,
                                "uid": item.get("trial_uid", ""),
                                "error": e.payload()["error"]})
        return results

    def op_report(self, uid: str, step: int = 0, value: float = 0.0
                  ) -> dict[str, Any]:
        """Record an intermediate value (lease heartbeat) and return the
        pruning verdict — v1 ``should_prune``."""
        _require_finite_value(float(value))
        trial = self.storage.get_trial(uid)
        if trial is None:
            raise ApiError(404, "trial_not_found", f"unknown trial {uid!r}")
        ctx = self._context_for_key(trial.study_key)
        if ctx is None:
            # the trial exists but its study is not resolvable (e.g. a
            # partially replayed or externally mutated store) — a client
            # error, not a server crash
            raise ApiError(404, "study_not_found",
                           f"study {trial.study_key!r} for trial "
                           f"{uid!r} is not resolvable")
        with ctx.lock:
            if trial.state != TrialState.RUNNING:
                # zombie worker: its lease was revoked (or the trial pruned)
                # while it was away — instruct it to abandon the trial.
                return {"uid": uid, "should_prune": True,
                        "note": f"trial is {trial.state.value}"}
            study = self.storage.get_study(trial.study_key)
            # heartbeat: renew the lease + record the intermediate
            self.storage.update_trial(
                uid, intermediate=(int(step), float(value)),
                lease_deadline=self._lease_deadline())
            prune = bool(ctx.pruner.should_prune(study, trial, int(step)))
            if prune:
                self.storage.update_trial(
                    uid, state=TrialState.PRUNED, finished_at=time.time(),
                    lease_deadline=None)
        if prune:
            self._notify_speculator(ctx)
        return {"uid": uid, "should_prune": prune}

    # ------------------------------------------------------------------ #
    # trial suggestion (shared by v1 and v2 ask paths)
    # ------------------------------------------------------------------ #
    def _start_trials(self, ctx: StudyContext, n: int,
                      worker_id: str | None) -> list[Trial]:
        """Suggest + register ``n`` trials.  Caller holds ``ctx.lock``."""
        study = self.storage.get_study(ctx.key)
        batch: list[tuple[dict[str, Any], int]] = []    # (params, retries)
        while len(batch) < n:                 # fault-tolerance requeue path
            waiting = self.storage.pop_waiting(ctx.key)
            if waiting is None:
                break
            batch.append((waiting["params"], waiting["retries"]))
        remaining = n - len(batch)
        if remaining and ctx.spec is not None:
            # speculative fast path: drain precomputed proposals.  The
            # version is stable while we hold the shard lock, and a
            # drained proposal is registered through the same journaled
            # add_trial as an inline one — nothing moves off-WAL.
            version = self.storage.data_version(ctx.key)
            bound = self._staleness_bound(ctx)
            while remaining:
                params = ctx.spec.take(version, bound)
                if params is None:
                    break                     # miss -> inline, never block
                batch.append((params, 0))
                remaining -= 1
        if remaining:
            kwargs: dict[str, Any] = {}
            if getattr(ctx.sampler, "multi_objective", False):
                kwargs["signs"] = ctx.config.direction_signs()
            if getattr(ctx.sampler, "uses_cache", False):
                # O(1) when nothing completed since the last ask; O(new)
                # otherwise — never a rescan of the trial list
                kwargs["cache"] = ctx.cache.sync(self.storage, ctx.key)
            # cooperative overprovisioning: a miss already pays the
            # lock + KDE cost for a top-1 draw, and widening the same
            # fused evaluation to top-(1+extra) is nearly free — the
            # surplus publishes at the current version, so the next
            # wave of asks drains exact hits instead of missing too.
            # This is what keeps the queue fed under heavy contention:
            # the lone background thread is GIL-starved by the very
            # fleet it serves, while the miss path's compute budget
            # scales with demand by construction.
            extra = 0
            if (ctx.spec is not None and "cache" in kwargs
                    and ctx.sampler.speculative_ready(kwargs["cache"])):
                extra = max(4, min(32, ctx.parallelism // 8))
                if remaining == 1:
                    # single-ask miss (the contended hot path): one
                    # fused draw, no intra-batch re-chunking
                    kwargs["chunk"] = remaining + extra
            if remaining == 1 and not extra:
                params_list = [ctx.sampler.suggest(
                    ctx.space, study.trials, ctx.config.direction, ctx.rng,
                    **kwargs)]
            else:
                params_list = ctx.sampler.suggest_batch(
                    ctx.space, study.trials, ctx.config.direction, ctx.rng,
                    remaining + extra, **kwargs)
            if extra:
                ctx.spec.publish(self.storage.data_version(ctx.key),
                                 params_list[remaining:])
                params_list = params_list[:remaining]
            batch.extend((p, 0) for p in params_list)
        trials = [self.storage.add_trial(
                      ctx.key, params, worker_id=worker_id,
                      lease_deadline=self._lease_deadline(),
                      retries=retries)
                  for params, retries in batch]
        # every ask changes the pending set (and possibly drained the
        # buffer) -> wake the precompute worker to refill against the
        # new view.  The dirty set dedups bursts.
        self._notify_speculator(ctx)
        return trials

    # ------------------------------------------------------------------ #
    # speculative precompute (off-lock proposal pipeline)
    # ------------------------------------------------------------------ #
    def _notify_speculator(self, ctx: StudyContext | None) -> None:
        if ctx is not None and ctx.spec is not None \
                and self._speculator is not None:
            self._speculator.notify(ctx.key)

    def _peek_context(self, study_key: str) -> StudyContext | None:
        """Already-built context, or None — never builds one (the tell/
        sweep notify path must stay allocation-free)."""
        with self._ctx_lock:
            return self._contexts.get(study_key)

    def _staleness_bound(self, ctx: StudyContext) -> int:
        """Max proposal age (in storage versions) the drain accepts.
        A wave of K concurrent asks bumps the version ~2K times (one
        registration + one tell each) between a proposal's compute and
        its drain, so the dynamic bound tracks the fleet-size hint."""
        if self.speculate_staleness is not None:
            return self.speculate_staleness
        return max(64, 8 * max(self.speculate_depth, ctx.parallelism))

    def _precompute_study(self, study_key: str) -> None:
        """SpeculativeWorker callback: regenerate one study's proposal
        buffer.  Snapshot under the shard lock, sample off it."""
        ctx = self._context_for_key(study_key)
        if ctx is None or ctx.spec is None:
            return
        with ctx.lock:
            cache = ctx.cache.sync(self.storage, ctx.key)
            snap = cache.snapshot()
            depth = max(self.speculate_depth, ctx.parallelism)
            round_no = ctx.spec_round
            ctx.spec_round += 1
            sampler = ctx.spec_sampler
            if sampler is None:
                sampler = make_sampler(ctx.config.sampler)
                ctx.spec_sampler = sampler
        if ctx.spec.depth() >= depth:
            # queue already holds a full wave — don't burn sampler
            # compute on proposals the next publish would only age out;
            # the next drain re-notifies and refills
            return
        if not sampler.speculative_ready(snap):
            # startup (or a size-gated model) falls back to index-based
            # proposals that need the live trial count — inline only
            return
        rng = np.random.default_rng(
            [self._seed, int(study_key[:8], 16), 0x5bec, round_no])
        # stream the round in slices: each slice is one fused sampler
        # evaluation published as soon as it lands (same version -> the
        # queue merges them), then appended to the snapshot as fantasy
        # rows so the next slice is liar-repelled from it.  Total
        # compute matches the monolithic chunked batch — only the
        # publish granularity changes, so contended asks drain the
        # early slices while the tail is still computing instead of
        # missing to inline for the whole round.
        slice_n = max(self._SPECULATE_SLICE, -(-depth // 4))
        view = snap
        done = 0
        while done < depth:
            k = min(slice_n, depth - done)
            proposals = sampler.suggest_batch(
                ctx.space, [], ctx.config.direction, rng, k,
                cache=view, chunk=k)
            if not proposals:
                break
            if not ctx.spec.publish(snap.version, proposals):
                break                         # a newer round already landed
            done += len(proposals)
            if done < depth:
                view = view.with_fantasies(
                    ctx.space.to_unit_matrix(proposals))

    def speculation_stats(self) -> dict[str, Any]:
        """Aggregated speculative-pipeline counters across studies —
        surfaced in ``/api/v2/version`` storage stats and ``/health``."""
        with self._ctx_lock:
            ctxs = list(self._contexts.values())
        out: dict[str, Any] = {
            "enabled": self._speculator is not None,
            "depth": self.speculate_depth,
            # the per-drain bound additionally scales with each study's
            # parallelism hint; this is the floor
            "staleness_limit": (self.speculate_staleness
                                if self.speculate_staleness is not None
                                else max(64, 8 * self.speculate_depth)),
            "hits": 0, "stale_hits": 0, "misses": 0, "published": 0,
            "rejected": 0, "discarded": 0, "queued": 0,
            "pending_trials": 0, "rounds": 0, "errors": 0,
        }
        if self._speculator is not None:
            w = self._speculator.stats()
            out["rounds"], out["errors"] = w["rounds"], w["errors"]
        for ctx in ctxs:
            out["pending_trials"] += ctx.cache.pending_count
            if ctx.spec is not None:
                s = ctx.spec.stats()
                for k in ("hits", "stale_hits", "misses", "published",
                          "rejected", "discarded", "queued"):
                    out[k] += s[k]
        return out

    # ------------------------------------------------------------------ #
    # v1 compat endpoints (byte-compatible success payloads; also the
    # in-process API used by existing tests and tools)
    # ------------------------------------------------------------------ #
    @staticmethod
    def _v1_trial(trial: Trial, study_key: str) -> dict[str, Any]:
        return {"trial_uid": trial.uid, "trial_id": trial.trial_id,
                "study_key": study_key, "properties": trial.params}

    def _ask(self, body: dict[str, Any], identity: dict[str, Any]
             ) -> tuple[int, dict[str, Any]]:
        try:
            ctx, created = self.op_resolve_study(body)
            worker_id = body.get("worker_id") or identity.get("user")
            with ctx.lock:
                self._sweep_study(ctx.key, time.time())
                (trial,) = self._start_trials(ctx, 1, worker_id)
        except ApiError as e:
            return e.status, e.payload()
        payload = self._v1_trial(trial, ctx.key)
        payload["study_created"] = created
        return 200, payload

    def _ask_batch(self, body: dict[str, Any], identity: dict[str, Any]
                   ) -> tuple[int, dict[str, Any]]:
        n = int(body.get("n", 1))
        if n < 1:
            # direct in-process callers only: the wire path rejects this
            # with a schema 422 before the handler runs
            return 400, {"detail": f"batch size must be >= 1, got {n}"}
        try:
            ctx, created = self.op_resolve_study(body)
            worker_id = body.get("worker_id") or identity.get("user")
            with ctx.lock:
                self._sweep_study(ctx.key, time.time())
                trials = self._start_trials(ctx, n, worker_id)
        except ApiError as e:
            return e.status, e.payload()
        return 200, {"trials": [self._v1_trial(t, ctx.key) for t in trials],
                     "study_key": ctx.key, "study_created": created}

    def _tell(self, body: dict[str, Any]) -> tuple[int, dict[str, Any]]:
        try:
            out = self.op_tell(body.get("trial_uid", ""), body.get("value"),
                               body.get("state") or "completed",
                               body.get("idempotency_key"))
        except ApiError as e:
            return e.status, e.payload()
        return 200, {"trial_uid": out["uid"], "state": out["state"]}

    def _tell_batch(self, body: dict[str, Any]) -> tuple[int, dict[str, Any]]:
        tells = body.get("tells")
        if not isinstance(tells, list):
            # direct in-process callers only: the wire path rejects this
            # with a schema 422 before the handler runs
            return 400, {"detail": "tell_batch needs a 'tells' list"}
        results = []
        for item in tells:
            status, payload = self._tell(item or {})
            results.append({"status": status, **payload})
        return 200, {"results": results}

    def _should_prune(self, body: dict[str, Any]
                      ) -> tuple[int, dict[str, Any]]:
        try:
            out = self.op_report(body.get("trial_uid", ""),
                                 int(body.get("step", 0)),
                                 float(body.get("value", 0.0)))
        except ApiError as e:
            return e.status, e.payload()
        payload = {"trial_uid": out["uid"],
                   "should_prune": out["should_prune"]}
        if "note" in out:
            payload["detail"] = out["note"]
        return 200, payload

    def _studies(self) -> tuple[int, dict[str, Any]]:
        out = []
        for s in self.storage.studies():
            res = self.study_resource(s)
            rec = {k: res[k] for k in _V1_STUDY_KEYS}
            if "pareto_front" in res:
                rec["pareto_front"] = res["pareto_front"]
            out.append(rec)
        return 200, {"studies": out}

    # ------------------------------------------------------------------ #
    # fault tolerance
    # ------------------------------------------------------------------ #
    def _sweep_study(self, study_key: str, now: float) -> int:
        """Fail this study's lapsed-lease trials; requeue params (bounded).
        Heap-backed: cost is O(expired · log n), not a trial scan."""
        with self.storage.study_lock(study_key):
            expired = self.storage.pop_expired(study_key, now)
            for t in expired:
                self.storage.update_trial(
                    t.uid, state=TrialState.FAILED, finished_at=now,
                    lease_deadline=None)
                if t.retries < self.max_retries:
                    self.storage.enqueue_params(
                        study_key, t.params, t.retries + 1)
        if expired:
            self._notify_speculator(self._peek_context(study_key))
        return len(expired)

    def sweep_expired(self, study_key: str | None = None) -> int:
        now = time.time()
        keys = ([study_key] if study_key is not None
                else [s.key for s in self.storage.studies()])
        return sum(self._sweep_study(k, now) for k in keys)
