"""The HOPAAS server: ask / tell / should_prune / version (paper Table 1),
plus the batched ask_batch / tell_batch extension.

``HopaasServer.handle(method, path, body)`` is transport-independent — the
same handler is mounted behind the stdlib HTTP transport (the Uvicorn role)
or called in-process (``DirectTransport``).  Multiple ``HopaasServer``
*workers* may share one storage object, reproducing the paper's
"scalable set of Uvicorn instances + shared PostgreSQL" architecture.

Sharding: the server holds one ``StudyContext`` per study — sampler,
pruner, decoded search space, a per-study RNG, the storage shard's
lock, and an incremental ``ObservationCache``.  All request handling
serializes on the *per-study* lock, so requests for different studies
proceed fully in parallel; there is no global server lock.  Lease
expiry is driven by the storage's per-study deadline min-heap, so
sweeps touch only expired entries instead of scanning every trial.

Hot-path cost model: `ask` syncs the observation cache (O(1) when
nothing completed, O(new) otherwise — never a history rescan) and hands
it to the sampler; `should_prune` heartbeats aggregate over the study's
per-step report indices; `/api/studies` reads the incrementally raced
incumbent.  Nothing on the request path scales with trial count.

Batch protocol: ``POST /api/ask_batch`` suggests k trials in one round
trip (the sampler sees the whole batch at once — ``suggest_batch`` —
enabling vectorized proposals), and ``POST /api/tell_batch`` finalizes k
trials with per-item statuses, so a straggler conflict on one trial never
fails the rest of the batch.

Fault tolerance beyond the paper's text (needed for 1000+-node campaigns):
  * every RUNNING trial carries a *lease*; `should_prune` reports act as
    heartbeats that renew it;
  * `sweep_expired()` marks trials whose lease lapsed as FAILED and
    re-enqueues their parameters so another worker picks them up (straggler
    mitigation / elastic membership);
  * all state mutations flow through the (journaled) storage, so a service
    restart resumes every study where it left off.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any

import numpy as np

from .auth import AuthError, TokenManager
from .obs_cache import ObservationCache
from .pruners import make_pruner
from .samplers import make_sampler
from .space import SearchSpace
from .storage import InMemoryStorage
from .types import Direction, StudyConfig, TrialState

HOPAAS_VERSION = "1.1.0-jax"


@dataclasses.dataclass
class StudyContext:
    """Per-study shard of the server: everything `ask`/`tell`/`should_prune`
    need, guarded by the storage shard's lock (shared across workers)."""

    key: str
    config: StudyConfig
    space: SearchSpace
    sampler: Any
    pruner: Any
    lock: threading.RLock
    rng: np.random.Generator
    # incremental (X, y) featurization of this study's observations —
    # synced from the storage's completion log under the shard lock, so
    # ask cost no longer scales with history length
    cache: ObservationCache


class HopaasServer:
    def __init__(self, storage: InMemoryStorage | None = None,
                 tokens: TokenManager | None = None,
                 lease_seconds: float = 60.0, max_retries: int = 3,
                 seed: int = 0, worker_name: str = "worker-0"):
        self.storage = storage or InMemoryStorage()
        self.tokens = tokens or TokenManager()
        self.lease_seconds = float(lease_seconds)
        self.max_retries = int(max_retries)
        self.worker_name = worker_name
        self._seed = int(seed)
        self._contexts: dict[str, StudyContext] = {}
        self._ctx_lock = threading.Lock()      # guards context creation only

    # ------------------------------------------------------------------ #
    # per-study contexts
    # ------------------------------------------------------------------ #
    def _build_context(self, key: str, config: StudyConfig) -> StudyContext:
        space = SearchSpace.from_properties(config.properties)
        return StudyContext(
            key=key, config=config, space=space,
            sampler=make_sampler(config.sampler),
            pruner=make_pruner(config.pruner),
            lock=self.storage.study_lock(key),
            # per-study stream: concurrent asks on different studies must
            # not share one (non-thread-safe) Generator
            rng=np.random.default_rng([self._seed, int(key[:8], 16)]),
            cache=ObservationCache(space, config.direction))

    def _context(self, config: StudyConfig) -> tuple[StudyContext, bool]:
        study, created = self.storage.get_or_create_study(config)
        key = study.key
        with self._ctx_lock:
            ctx = self._contexts.get(key)
            if ctx is None:
                ctx = self._build_context(key, study.config)
                self._contexts[key] = ctx
        return ctx, created

    def _context_for_key(self, study_key: str) -> StudyContext | None:
        """Context for a study possibly created by another worker."""
        with self._ctx_lock:
            ctx = self._contexts.get(study_key)
        if ctx is not None:
            return ctx
        study = self.storage.get_study(study_key)
        if study is None:
            return None
        with self._ctx_lock:
            ctx = self._contexts.get(study_key)
            if ctx is None:
                ctx = self._build_context(study_key, study.config)
                self._contexts[study_key] = ctx
        return ctx

    # ------------------------------------------------------------------ #
    # transport-independent request handler
    # ------------------------------------------------------------------ #
    def handle(self, method: str, path: str, body: dict[str, Any] | None = None
               ) -> tuple[int, dict[str, Any]]:
        try:
            parts = [p for p in path.split("/") if p]
            if parts[:1] != ["api"]:
                return 404, {"detail": "not found"}
            endpoint = parts[1] if len(parts) > 1 else ""
            if method == "GET" and endpoint == "version":
                return 200, {"version": HOPAAS_VERSION}
            token = parts[2] if len(parts) > 2 else ""
            try:
                identity = self.tokens.verify(token)
            except AuthError as e:
                return 401, {"detail": str(e)}
            body = body or {}
            if method == "POST" and endpoint == "ask":
                return self._ask(body, identity)
            if method == "POST" and endpoint == "ask_batch":
                return self._ask_batch(body, identity)
            if method == "POST" and endpoint == "tell":
                return self._tell(body)
            if method == "POST" and endpoint == "tell_batch":
                return self._tell_batch(body)
            if method == "POST" and endpoint == "should_prune":
                return self._should_prune(body)
            if method == "GET" and endpoint == "studies":
                return self._studies()
            return 404, {"detail": f"unknown endpoint {endpoint!r}"}
        except Exception as e:  # a production server never drops the socket
            return 500, {"detail": f"{type(e).__name__}: {e}"}

    # ------------------------------------------------------------------ #
    # endpoints
    # ------------------------------------------------------------------ #
    @staticmethod
    def _study_config(body: dict[str, Any]) -> StudyConfig:
        return StudyConfig(
            name=body.get("name", "unnamed"),
            properties=body.get("properties", {}),
            direction=Direction(body.get("direction", "minimize")),
            sampler=body.get("sampler", {"name": "tpe"}),
            pruner=body.get("pruner", {"name": "none"}),
            directions=body.get("directions"),
        )

    def _start_trials(self, ctx: StudyContext, n: int, body: dict[str, Any],
                      identity: dict[str, Any]) -> list[dict[str, Any]]:
        """Suggest + register ``n`` trials.  Caller holds ``ctx.lock``."""
        study = self.storage.get_study(ctx.key)
        worker_id = body.get("worker_id", identity.get("user"))
        batch: list[tuple[dict[str, Any], int]] = []    # (params, retries)
        while len(batch) < n:                 # fault-tolerance requeue path
            waiting = self.storage.pop_waiting(ctx.key)
            if waiting is None:
                break
            batch.append((waiting["params"], waiting["retries"]))
        remaining = n - len(batch)
        if remaining:
            kwargs: dict[str, Any] = {}
            if getattr(ctx.sampler, "multi_objective", False):
                kwargs["signs"] = ctx.config.direction_signs()
            if getattr(ctx.sampler, "uses_cache", False):
                # O(1) when nothing completed since the last ask; O(new)
                # otherwise — never a rescan of the trial list
                kwargs["cache"] = ctx.cache.sync(self.storage, ctx.key)
            if remaining == 1:
                params_list = [ctx.sampler.suggest(
                    ctx.space, study.trials, ctx.config.direction, ctx.rng,
                    **kwargs)]
            else:
                params_list = ctx.sampler.suggest_batch(
                    ctx.space, study.trials, ctx.config.direction, ctx.rng,
                    remaining, **kwargs)
            batch.extend((p, 0) for p in params_list)
        out = []
        for params, retries in batch:
            trial = self.storage.add_trial(
                ctx.key, params, worker_id=worker_id,
                lease_deadline=time.time() + self.lease_seconds,
                retries=retries)
            out.append({"trial_uid": trial.uid, "trial_id": trial.trial_id,
                        "study_key": ctx.key, "properties": params})
        return out

    def _ask(self, body: dict[str, Any], identity: dict[str, Any]
             ) -> tuple[int, dict[str, Any]]:
        ctx, created = self._context(self._study_config(body))
        with ctx.lock:
            self._sweep_study(ctx.key, time.time())
            (payload,) = self._start_trials(ctx, 1, body, identity)
        payload["study_created"] = created
        return 200, payload

    def _ask_batch(self, body: dict[str, Any], identity: dict[str, Any]
                   ) -> tuple[int, dict[str, Any]]:
        n = int(body.get("n", 1))
        if n < 1:
            return 400, {"detail": f"batch size must be >= 1, got {n}"}
        ctx, created = self._context(self._study_config(body))
        with ctx.lock:
            self._sweep_study(ctx.key, time.time())
            trials = self._start_trials(ctx, n, body, identity)
        return 200, {"trials": trials, "study_key": ctx.key,
                     "study_created": created}

    def _tell_one(self, body: dict[str, Any]) -> tuple[int, dict[str, Any]]:
        uid = body.get("trial_uid", "")
        value = body.get("value", None)
        # multi-objective: value may be a list (one entry per objective)
        values = None
        if isinstance(value, (list, tuple)):
            values = [float(v) for v in value]
            value = values[0]
        state = TrialState(body.get("state", "completed"))
        trial = self.storage.get_trial(uid)
        if trial is None:
            return 404, {"detail": f"unknown trial {uid!r}"}
        with self.storage.study_lock(trial.study_key):
            if trial.state == TrialState.PRUNED:
                # the server already finalized this trial on should_prune;
                # accept the client's value but keep the PRUNED state.
                self.storage.update_trial(
                    uid, value=(None if value is None else float(value)),
                    values=values)
                return 200, {"trial_uid": uid, "state": trial.state.value}
            if trial.state != TrialState.RUNNING:
                return 409, {"detail": f"trial {uid} already {trial.state.value}"}
            self.storage.update_trial(
                uid, value=(None if value is None else float(value)),
                values=values,
                state=state, finished_at=time.time(), lease_deadline=None)
        return 200, {"trial_uid": uid, "state": state.value}

    def _tell(self, body: dict[str, Any]) -> tuple[int, dict[str, Any]]:
        return self._tell_one(body)

    def _tell_batch(self, body: dict[str, Any]) -> tuple[int, dict[str, Any]]:
        tells = body.get("tells")
        if not isinstance(tells, list):
            return 400, {"detail": "tell_batch needs a 'tells' list"}
        results = []
        for item in tells:
            status, payload = self._tell_one(item or {})
            results.append({"status": status, **payload})
        return 200, {"results": results}

    def _should_prune(self, body: dict[str, Any]) -> tuple[int, dict[str, Any]]:
        uid = body.get("trial_uid", "")
        step = int(body.get("step", 0))
        value = float(body.get("value", 0.0))
        trial = self.storage.get_trial(uid)
        if trial is None:
            return 404, {"detail": f"unknown trial {uid!r}"}
        ctx = self._context_for_key(trial.study_key)
        if ctx is None:
            # the trial exists but its study is not resolvable (e.g. a
            # partially replayed or externally mutated store) — a client
            # error, not a server crash
            return 404, {"detail": f"study {trial.study_key!r} for trial "
                                   f"{uid!r} is not resolvable"}
        with ctx.lock:
            if trial.state != TrialState.RUNNING:
                # zombie worker: its lease was revoked (or the trial pruned)
                # while it was away — instruct it to abandon the trial.
                return 200, {"trial_uid": uid, "should_prune": True,
                             "detail": f"trial is {trial.state.value}"}
            study = self.storage.get_study(trial.study_key)
            # heartbeat: renew the lease + record the intermediate
            self.storage.update_trial(
                uid, intermediate=(step, value),
                lease_deadline=time.time() + self.lease_seconds)
            prune = bool(ctx.pruner.should_prune(study, trial, step))
            if prune:
                self.storage.update_trial(
                    uid, state=TrialState.PRUNED, finished_at=time.time(),
                    lease_deadline=None)
        return 200, {"trial_uid": uid, "should_prune": prune}

    def _studies(self) -> tuple[int, dict[str, Any]]:
        out = []
        for s in self.storage.studies():
            with self.storage.study_lock(s.key):
                counts = self.storage.counts(s.key)
                # incumbent is tracked incrementally on tell — no scan
                best = self.storage.best_trial(s.key)
                rec = {
                    "key": s.key, "name": s.config.name,
                    "n_trials": len(s.trials),
                    "n_completed": counts[TrialState.COMPLETED],
                    "n_pruned": counts[TrialState.PRUNED],
                    "n_failed": counts[TrialState.FAILED],
                    "best_value": None if best is None else best.value,
                    "best_params": None if best is None else best.params,
                }
                if s.config.directions:
                    rec["pareto_front"] = [
                        {"params": t.params, "values": t.values}
                        for t in s.pareto_front()]
            out.append(rec)
        return 200, {"studies": out}

    # ------------------------------------------------------------------ #
    # fault tolerance
    # ------------------------------------------------------------------ #
    def _sweep_study(self, study_key: str, now: float) -> int:
        """Fail this study's lapsed-lease trials; requeue params (bounded).
        Heap-backed: cost is O(expired · log n), not a trial scan."""
        with self.storage.study_lock(study_key):
            expired = self.storage.pop_expired(study_key, now)
            for t in expired:
                self.storage.update_trial(
                    t.uid, state=TrialState.FAILED, finished_at=now,
                    lease_deadline=None)
                if t.retries < self.max_retries:
                    self.storage.enqueue_params(
                        study_key, t.params, t.retries + 1)
        return len(expired)

    def sweep_expired(self, study_key: str | None = None) -> int:
        now = time.time()
        keys = ([study_key] if study_key is not None
                else [s.key for s in self.storage.studies()])
        return sum(self._sweep_study(k, now) for k in keys)
