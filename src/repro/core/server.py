"""The HOPAAS server: ask / tell / should_prune / version (paper Table 1).

``HopaasServer.handle(method, path, body)`` is transport-independent — the
same handler is mounted behind the stdlib HTTP transport (the Uvicorn role)
or called in-process (``DirectTransport``).  Multiple ``HopaasServer``
*workers* may share one storage object, reproducing the paper's
"scalable set of Uvicorn instances + shared PostgreSQL" architecture.

Fault tolerance beyond the paper's text (needed for 1000+-node campaigns):
  * every RUNNING trial carries a *lease*; `should_prune` reports act as
    heartbeats that renew it;
  * `sweep_expired()` marks trials whose lease lapsed as FAILED and
    re-enqueues their parameters so another worker picks them up (straggler
    mitigation / elastic membership);
  * all state mutations flow through the (journaled) storage, so a service
    restart resumes every study where it left off.
"""
from __future__ import annotations

import threading
import time
from typing import Any

import numpy as np

from .auth import AuthError, TokenManager
from .pruners import make_pruner
from .samplers import make_sampler
from .space import SearchSpace
from .storage import InMemoryStorage
from .types import Direction, StudyConfig, TrialState

HOPAAS_VERSION = "1.0.0-jax"


class HopaasServer:
    def __init__(self, storage: InMemoryStorage | None = None,
                 tokens: TokenManager | None = None,
                 lease_seconds: float = 60.0, max_retries: int = 3,
                 seed: int = 0, worker_name: str = "worker-0"):
        self.storage = storage or InMemoryStorage()
        self.tokens = tokens or TokenManager()
        self.lease_seconds = float(lease_seconds)
        self.max_retries = int(max_retries)
        self.worker_name = worker_name
        self._rng = np.random.default_rng(seed)
        self._lock = threading.RLock()
        # per-study sampler/pruner/space caches (samplers can be stateful)
        self._samplers: dict[str, Any] = {}
        self._pruners: dict[str, Any] = {}
        self._spaces: dict[str, SearchSpace] = {}

    # ------------------------------------------------------------------ #
    # transport-independent request handler
    # ------------------------------------------------------------------ #
    def handle(self, method: str, path: str, body: dict[str, Any] | None = None
               ) -> tuple[int, dict[str, Any]]:
        try:
            parts = [p for p in path.split("/") if p]
            if parts[:1] != ["api"]:
                return 404, {"detail": "not found"}
            endpoint = parts[1] if len(parts) > 1 else ""
            if method == "GET" and endpoint == "version":
                return 200, {"version": HOPAAS_VERSION}
            token = parts[2] if len(parts) > 2 else ""
            try:
                identity = self.tokens.verify(token)
            except AuthError as e:
                return 401, {"detail": str(e)}
            body = body or {}
            if method == "POST" and endpoint == "ask":
                return self._ask(body, identity)
            if method == "POST" and endpoint == "tell":
                return self._tell(body)
            if method == "POST" and endpoint == "should_prune":
                return self._should_prune(body)
            if method == "GET" and endpoint == "studies":
                return self._studies()
            return 404, {"detail": f"unknown endpoint {endpoint!r}"}
        except Exception as e:  # a production server never drops the socket
            return 500, {"detail": f"{type(e).__name__}: {e}"}

    # ------------------------------------------------------------------ #
    # endpoints
    # ------------------------------------------------------------------ #
    def _ask(self, body: dict[str, Any], identity: dict[str, Any]
             ) -> tuple[int, dict[str, Any]]:
        config = StudyConfig(
            name=body.get("name", "unnamed"),
            properties=body.get("properties", {}),
            direction=Direction(body.get("direction", "minimize")),
            sampler=body.get("sampler", {"name": "tpe"}),
            pruner=body.get("pruner", {"name": "none"}),
            directions=body.get("directions"),
        )
        with self._lock:
            study, created = self.storage.get_or_create_study(config)
            key = study.key
            if key not in self._spaces:
                self._spaces[key] = SearchSpace.from_properties(config.properties)
                self._samplers[key] = make_sampler(config.sampler)
                self._pruners[key] = make_pruner(config.pruner)
            self.sweep_expired(key)

            waiting = self.storage.pop_waiting(key)
            if waiting is not None:      # fault-tolerance requeue path
                params, retries = waiting["params"], waiting["retries"]
            else:
                sampler = self._samplers[key]
                if getattr(sampler, "multi_objective", False):
                    params = sampler.suggest(
                        self._spaces[key], study.trials, config.direction,
                        self._rng, signs=config.direction_signs())
                else:
                    params = sampler.suggest(
                        self._spaces[key], study.trials, config.direction,
                        self._rng)
                retries = 0
            trial = self.storage.add_trial(
                key, params, worker_id=body.get("worker_id", identity.get("user")),
                lease_deadline=time.time() + self.lease_seconds, retries=retries)
        return 200, {"trial_uid": trial.uid, "trial_id": trial.trial_id,
                     "study_key": key, "study_created": created,
                     "properties": params}

    def _tell(self, body: dict[str, Any]) -> tuple[int, dict[str, Any]]:
        uid = body.get("trial_uid", "")
        value = body.get("value", None)
        # multi-objective: value may be a list (one entry per objective)
        values = None
        if isinstance(value, (list, tuple)):
            values = [float(v) for v in value]
            value = values[0]
        state = TrialState(body.get("state", "completed"))
        with self._lock:
            trial = self.storage.get_trial(uid)
            if trial is None:
                return 404, {"detail": f"unknown trial {uid!r}"}
            if trial.state == TrialState.PRUNED:
                # the server already finalized this trial on should_prune;
                # accept the client's value but keep the PRUNED state.
                self.storage.update_trial(
                    uid, value=(None if value is None else float(value)),
                    values=values)
                return 200, {"trial_uid": uid, "state": trial.state.value}
            if trial.state != TrialState.RUNNING:
                return 409, {"detail": f"trial {uid} already {trial.state.value}"}
            self.storage.update_trial(
                uid, value=(None if value is None else float(value)),
                values=values,
                state=state, finished_at=time.time(), lease_deadline=None)
        return 200, {"trial_uid": uid, "state": state.value}

    def _should_prune(self, body: dict[str, Any]) -> tuple[int, dict[str, Any]]:
        uid = body.get("trial_uid", "")
        step = int(body.get("step", 0))
        value = float(body.get("value", 0.0))
        with self._lock:
            trial = self.storage.get_trial(uid)
            if trial is None:
                return 404, {"detail": f"unknown trial {uid!r}"}
            if trial.state != TrialState.RUNNING:
                # zombie worker: its lease was revoked (or the trial pruned)
                # while it was away — instruct it to abandon the trial.
                return 200, {"trial_uid": uid, "should_prune": True,
                             "detail": f"trial is {trial.state.value}"}
            study = self.storage.get_study(trial.study_key)
            # heartbeat: renew the lease + record the intermediate
            self.storage.update_trial(
                uid, intermediate=(step, value),
                lease_deadline=time.time() + self.lease_seconds)
            pruner = self._pruners.get(trial.study_key) or make_pruner(
                study.config.pruner)
            prune = bool(pruner.should_prune(study, trial, step))
            if prune:
                self.storage.update_trial(
                    uid, state=TrialState.PRUNED, finished_at=time.time(),
                    lease_deadline=None)
        return 200, {"trial_uid": uid, "should_prune": prune}

    def _studies(self) -> tuple[int, dict[str, Any]]:
        out = []
        for s in self.storage.studies():
            best = s.best_trial()
            rec = {
                "key": s.key, "name": s.config.name,
                "n_trials": len(s.trials),
                "n_completed": len(s.completed()),
                "n_pruned": sum(t.state == TrialState.PRUNED for t in s.trials),
                "n_failed": sum(t.state == TrialState.FAILED for t in s.trials),
                "best_value": None if best is None else best.value,
                "best_params": None if best is None else best.params,
            }
            if s.config.directions:
                rec["pareto_front"] = [
                    {"params": t.params, "values": t.values}
                    for t in s.pareto_front()]
            out.append(rec)
        return 200, {"studies": out}

    # ------------------------------------------------------------------ #
    # fault tolerance
    # ------------------------------------------------------------------ #
    def sweep_expired(self, study_key: str | None = None) -> int:
        """Fail trials whose lease lapsed; requeue their params (bounded)."""
        now = time.time()
        n = 0
        for study in self.storage.studies():
            if study_key is not None and study.key != study_key:
                continue
            for t in study.trials:
                if (t.state == TrialState.RUNNING and t.lease_deadline is not None
                        and t.lease_deadline < now):
                    self.storage.update_trial(
                        t.uid, state=TrialState.FAILED, finished_at=now,
                        lease_deadline=None)
                    if t.retries < self.max_retries:
                        self.storage.enqueue_params(
                            study.key, t.params, t.retries + 1)
                    n += 1
        return n
