"""repro-check: repo-specific static analysis + runtime lock sanitizer.

Static side (``python -m repro.analysis``): an AST/call-graph framework
(:mod:`.loader`, :mod:`.callgraph`, :mod:`.findings`) with five
checkers (:mod:`.checkers`) guarding invariants the test suite cannot
see directly — lock acquisition order, the never-block rule of the
event-loop IO thread, write-ahead journaling order, client/server wire
agreement, and swallowed exceptions in background threads.

Runtime side (:mod:`.sanitize`, enabled by ``REPRO_SANITIZE=1``): an
instrumented lock wrapper that records real acquisition order during
the test suite and cross-checks it against the static graph, plus a
watchdog that dumps every held lock and all thread stacks on a
suspected deadlock.
"""
from .findings import Baseline, Finding
from .loader import Project, load_core

__all__ = ["Baseline", "Finding", "Project", "load_core"]
