"""Finding, baseline and allowlist model for repro-check.

A ``Finding`` is one violation: checker, rule, location and message.
Its *fingerprint* deliberately excludes the line number so that
unrelated edits above a known finding do not churn the baseline — only
the checker, rule, file, enclosing symbol and normalized detail count.

The baseline file records open findings by fingerprint.  The contract:

  * a finding in the baseline is *known debt* — reported, but does not
    fail the run;
  * a finding not in the baseline fails the run (``--fail-on-new`` is
    the default and only mode);
  * a baseline entry with no matching finding is *stale* and reported
    so fixed debt gets deleted, never accumulated.

Permanent, audited exceptions do not belong here — they get an in-code
``# repro-check: allow(<tag>)`` annotation next to the excused line.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from pathlib import Path


@dataclasses.dataclass(frozen=True)
class Finding:
    checker: str        # "lock-order", "evloop-blocking", ...
    rule: str           # "lock-cycle", "blocking-under-lock", ...
    path: str           # repo-relative file
    line: int
    symbol: str         # enclosing function/class qual ("" if module level)
    message: str
    detail: str = ""    # stable discriminator (lock pair, call chain, ...)

    @property
    def fingerprint(self) -> str:
        raw = "|".join((self.checker, self.rule, self.path, self.symbol,
                        self.detail or self.message))
        return hashlib.sha256(raw.encode()).hexdigest()[:16]

    def render(self) -> str:
        return (f"{self.path}:{self.line}: [{self.checker}/{self.rule}] "
                f"{self.message}  ({self.fingerprint})")


class Baseline:
    VERSION = 1

    def __init__(self, entries: dict[str, str] | None = None):
        # fingerprint -> human summary (for reviewable diffs)
        self.entries: dict[str, str] = dict(entries or {})

    @classmethod
    def load(cls, path: str | Path) -> "Baseline":
        path = Path(path)
        if not path.exists():
            return cls()
        data = json.loads(path.read_text())
        if data.get("version") != cls.VERSION:
            raise ValueError(
                f"unsupported baseline version {data.get('version')!r} "
                f"in {path}")
        return cls(data.get("findings", {}))

    def save(self, path: str | Path) -> None:
        data = {
            "version": self.VERSION,
            "findings": dict(sorted(self.entries.items())),
        }
        Path(path).write_text(json.dumps(data, indent=1) + "\n")

    @classmethod
    def from_findings(cls, findings: list[Finding]) -> "Baseline":
        return cls({f.fingerprint: f"{f.path}: [{f.checker}/{f.rule}] "
                                   f"{f.message}"
                    for f in findings})

    def split(self, findings: list[Finding]
              ) -> tuple[list[Finding], list[Finding], list[str]]:
        """-> (new findings, baselined findings, stale fingerprints)."""
        new, known = [], []
        seen: set[str] = set()
        for f in findings:
            if f.fingerprint in self.entries:
                known.append(f)
                seen.add(f.fingerprint)
            else:
                new.append(f)
        stale = [fp for fp in self.entries if fp not in seen]
        return new, known, stale
