"""repro-check CLI: ``python -m repro.analysis``.

    PYTHONPATH=src python -m repro.analysis                 # run everything
    PYTHONPATH=src python -m repro.analysis --checker lock-order
    PYTHONPATH=src python -m repro.analysis --write-baseline
    PYTHONPATH=src python -m repro.analysis --format json

Exit codes: 0 = no non-baselined findings; 1 = new findings (this is
``--fail-on-new``, which is the default and only mode — the flag is
accepted for CI readability); 2 = usage error.

Stale baseline entries (fixed findings still listed) are reported so
debt gets deleted from the baseline, never hoarded; they do not fail
the run.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .checkers import CHECKERS
from .findings import Baseline, Finding
from .loader import Project


def _default_repo_root() -> Path:
    # src/repro/analysis/cli.py -> repo root is three levels above src/
    return Path(__file__).resolve().parents[3]


def run_checkers(project: Project, names: list[str] | None = None
                 ) -> list[Finding]:
    findings: list[Finding] = []
    for name, checker in CHECKERS.items():
        if names and name not in names:
            continue
        findings.extend(checker(project))
    return findings


def print_stats(project: Project) -> int:
    """Per-checker coverage counts (``--stats``).  Returns non-zero when
    thread-root discovery comes up empty for any required subsystem —
    a rename that silently shrinks coverage must fail CI, because zero
    roots reads exactly like a clean run."""
    from .checkers import lock_order, shared_state, wire_schema

    print(f"repro-check: project: {len(project.modules)} module(s), "
          f"{len(project.functions)} function(s), "
          f"{len(project.classes)} class(es) loaded")

    graph = lock_order.build_lock_graph(project)
    print(f"repro-check: lock-order: {len(graph['keys'])} lock "
          f"class(es), {len(graph['edges'])} static acquisition edge(s)")

    routes = 0
    for name in wire_schema.DEFAULT_CONFIG["routes_modules"]:
        mod = project.modules.get(name)
        if mod is not None:
            routes += len(wire_schema._routes(mod))
    client = project.modules.get(wire_schema.DEFAULT_CONFIG["client_module"])
    calls = len(wire_schema._client_calls(client)) if client else 0
    print(f"repro-check: wire-schema: {routes} route(s), "
          f"{calls} client call(s) cross-checked")

    ss = shared_state.stats(project)
    per_sub = ", ".join(f"{sub}: {n}" for sub, n
                        in ss["roots_by_subsystem"].items())
    print(f"repro-check: shared-state: {ss['roots']} thread root(s) "
          f"({per_sub}); {ss['classes_found']}/"
          f"{ss['classes_configured']} configured class(es) found; "
          f"{ss['fields_examined']} field(s) examined, "
          f"{ss['fields_escaped']} escaped to >=2 roots, "
          f"{ss['fields_allowed']} allow-audited, "
          f"{ss['fields_flagged']} flagged")

    empty = [sub for sub in ss["required_subsystems"]
             if not ss["roots_by_subsystem"].get(sub)]
    if empty:
        print(f"repro-check: FAIL: zero thread roots discovered in "
              f"subsystem(s): {', '.join(empty)} — root discovery "
              f"coverage collapsed (a spawn-site rename reads as "
              f"'clean')", file=sys.stderr)
        return 1
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific static analysis: lock order, "
                    "event-loop blocking, write-ahead ordering, "
                    "wire-schema drift, thread hygiene")
    ap.add_argument("--root", default=None,
                    help="package to analyze "
                         "(default: <repo>/src/repro/core)")
    ap.add_argument("--baseline", default=None,
                    help="baseline file "
                         "(default: <repo>/repro-check.baseline.json)")
    ap.add_argument("--checker", action="append", default=None,
                    choices=sorted(CHECKERS),
                    help="run only this checker (repeatable)")
    ap.add_argument("--fail-on-new", action="store_true",
                    help="exit non-zero on non-baselined findings "
                         "(the default; flag kept for explicit CI steps)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline from current findings")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--stats", action="store_true",
                    help="print per-checker coverage counts instead of "
                         "findings; fails when thread-root discovery is "
                         "empty for a required subsystem")
    args = ap.parse_args(argv)

    repo_root = _default_repo_root()
    root = Path(args.root) if args.root else repo_root / "src/repro/core"
    if not root.is_dir():
        print(f"repro-check: no such package root: {root}",
              file=sys.stderr)
        return 2
    baseline_path = (Path(args.baseline) if args.baseline
                     else repo_root / "repro-check.baseline.json")

    project = Project(root, repo_root=repo_root).load()
    if args.stats:
        return print_stats(project)
    findings = run_checkers(project, args.checker)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))

    if args.write_baseline:
        Baseline.from_findings(findings).save(baseline_path)
        print(f"repro-check: wrote {len(findings)} finding(s) to "
              f"{baseline_path}")
        return 0

    baseline = Baseline.load(baseline_path)
    new, known, stale = baseline.split(findings)

    if args.format == "json":
        print(json.dumps({
            "new": [f.__dict__ | {"fingerprint": f.fingerprint}
                    for f in new],
            "baselined": [f.fingerprint for f in known],
            "stale": stale,
        }, indent=1))
    else:
        for f in new:
            print(f.render())
        if known:
            print(f"repro-check: {len(known)} baselined finding(s) "
                  f"suppressed")
        for fp in stale:
            print(f"repro-check: stale baseline entry {fp} "
                  f"({baseline.entries[fp]}) — finding fixed, delete it "
                  f"from {baseline_path.name}")
        counts: dict[str, int] = {}
        for f in findings:
            counts[f.checker] = counts.get(f.checker, 0) + 1
        ran = args.checker or sorted(CHECKERS)
        summary = ", ".join(f"{c}: {counts.get(c, 0)}" for c in ran)
        print(f"repro-check: {summary}; {len(new)} new")
    return 1 if new else 0
