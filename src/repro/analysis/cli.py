"""repro-check CLI: ``python -m repro.analysis``.

    PYTHONPATH=src python -m repro.analysis                 # run everything
    PYTHONPATH=src python -m repro.analysis --checker lock-order
    PYTHONPATH=src python -m repro.analysis --write-baseline
    PYTHONPATH=src python -m repro.analysis --format json

Exit codes: 0 = no non-baselined findings; 1 = new findings (this is
``--fail-on-new``, which is the default and only mode — the flag is
accepted for CI readability); 2 = usage error.

Stale baseline entries (fixed findings still listed) are reported so
debt gets deleted from the baseline, never hoarded; they do not fail
the run.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .checkers import CHECKERS
from .findings import Baseline, Finding
from .loader import Project


def _default_repo_root() -> Path:
    # src/repro/analysis/cli.py -> repo root is three levels above src/
    return Path(__file__).resolve().parents[3]


def run_checkers(project: Project, names: list[str] | None = None
                 ) -> list[Finding]:
    findings: list[Finding] = []
    for name, checker in CHECKERS.items():
        if names and name not in names:
            continue
        findings.extend(checker(project))
    return findings


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific static analysis: lock order, "
                    "event-loop blocking, write-ahead ordering, "
                    "wire-schema drift, thread hygiene")
    ap.add_argument("--root", default=None,
                    help="package to analyze "
                         "(default: <repo>/src/repro/core)")
    ap.add_argument("--baseline", default=None,
                    help="baseline file "
                         "(default: <repo>/repro-check.baseline.json)")
    ap.add_argument("--checker", action="append", default=None,
                    choices=sorted(CHECKERS),
                    help="run only this checker (repeatable)")
    ap.add_argument("--fail-on-new", action="store_true",
                    help="exit non-zero on non-baselined findings "
                         "(the default; flag kept for explicit CI steps)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline from current findings")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    args = ap.parse_args(argv)

    repo_root = _default_repo_root()
    root = Path(args.root) if args.root else repo_root / "src/repro/core"
    if not root.is_dir():
        print(f"repro-check: no such package root: {root}",
              file=sys.stderr)
        return 2
    baseline_path = (Path(args.baseline) if args.baseline
                     else repo_root / "repro-check.baseline.json")

    project = Project(root, repo_root=repo_root).load()
    findings = run_checkers(project, args.checker)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))

    if args.write_baseline:
        Baseline.from_findings(findings).save(baseline_path)
        print(f"repro-check: wrote {len(findings)} finding(s) to "
              f"{baseline_path}")
        return 0

    baseline = Baseline.load(baseline_path)
    new, known, stale = baseline.split(findings)

    if args.format == "json":
        print(json.dumps({
            "new": [f.__dict__ | {"fingerprint": f.fingerprint}
                    for f in new],
            "baselined": [f.fingerprint for f in known],
            "stale": stale,
        }, indent=1))
    else:
        for f in new:
            print(f.render())
        if known:
            print(f"repro-check: {len(known)} baselined finding(s) "
                  f"suppressed")
        for fp in stale:
            print(f"repro-check: stale baseline entry {fp} "
                  f"({baseline.entries[fp]}) — finding fixed, delete it "
                  f"from {baseline_path.name}")
        counts: dict[str, int] = {}
        for f in findings:
            counts[f.checker] = counts.get(f.checker, 0) + 1
        ran = args.checker or sorted(CHECKERS)
        summary = ", ".join(f"{c}: {counts.get(c, 0)}" for c in ran)
        print(f"repro-check: {summary}; {len(new)} new")
    return 1 if new else 0
