"""AST module loader for the repro-check analysis suite.

Parses a Python package (no imports are executed — analysis must work on
modules whose import-time side effects we do not want) into ``Module``
objects carrying the AST, the raw source lines, and the in-code
``repro-check`` annotations:

    # repro-check: allow(blocking) -- non-blocking socket, audited 2026-08

An annotation applies to

  * the code on its own line (trailing comment),
  * the next non-blank code line (standalone comment line), and
  * the whole function body when it sits on (or directly above) a
    ``def`` line.

Annotations are how audited exceptions are recorded *next to the code
they excuse* — the committed baseline is for findings that are still
open, never for permanent waivers.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Iterator

_ALLOW_RE = re.compile(
    r"#\s*repro-check:\s*allow\(\s*([\w\-, ]+?)\s*\)")


@dataclasses.dataclass
class Module:
    """One parsed source file."""

    name: str                 # dotted name relative to the scan root
    path: str                 # repo-relative path (stable in findings)
    tree: ast.Module
    lines: list[str]
    # line number (1-based) -> set of allow tags effective on that line
    allows: dict[int, set[str]]
    # function-def line -> tags that cover the whole function body
    func_allows: dict[int, set[str]]

    def is_allowed(self, line: int, tag: str) -> bool:
        return tag in self.allows.get(line, ())

    def function_allowed(self, func: ast.AST, tag: str) -> bool:
        return tag in self.func_allows.get(getattr(func, "lineno", -1), ())


def _parse_allows(lines: list[str]) -> tuple[dict[int, set[str]],
                                             dict[int, set[str]]]:
    """Map annotation comments to the lines they cover."""
    allows: dict[int, set[str]] = {}
    func_allows: dict[int, set[str]] = {}

    def add(lineno: int, tags: set[str]) -> None:
        allows.setdefault(lineno, set()).update(tags)

    for i, text in enumerate(lines, start=1):
        m = _ALLOW_RE.search(text)
        if not m:
            continue
        tags = {t.strip() for t in m.group(1).split(",") if t.strip()}
        code = text[: m.start()].strip()
        target = i
        if not code:
            # standalone comment: push down to the next code line
            for j in range(i, len(lines)):
                nxt = lines[j].strip()
                if nxt and not nxt.startswith("#"):
                    target = j + 1
                    break
        add(target, tags)
        target_code = (lines[target - 1].strip()
                       if target - 1 < len(lines) else "")
        if target_code.startswith(("def ", "async def ")):
            func_allows.setdefault(target, set()).update(tags)
    return allows, func_allows


@dataclasses.dataclass
class FunctionInfo:
    """A function or method with enough context to resolve calls."""

    qual: str                     # "module.Class.method" or "module.func"
    name: str
    module: "Module"
    node: ast.FunctionDef
    cls: str | None               # owning class qual ("module.Class")


@dataclasses.dataclass
class ClassInfo:
    qual: str                     # "module.Class"
    name: str
    module: "Module"
    node: ast.ClassDef
    bases: list[str]              # raw base-name text (resolved lazily)
    methods: dict[str, FunctionInfo] = dataclasses.field(default_factory=dict)


class Project:
    """All loaded modules plus symbol indexes used by the checkers."""

    def __init__(self, root: Path, repo_root: Path | None = None):
        self.root = Path(root)
        self.repo_root = Path(repo_root) if repo_root else self.root
        self.modules: dict[str, Module] = {}
        self.classes: dict[str, ClassInfo] = {}
        self.functions: dict[str, FunctionInfo] = {}
        # method name -> every FunctionInfo with that name (may-call sets)
        self.methods_by_name: dict[str, list[FunctionInfo]] = {}
        # module name -> {local alias -> dotted import target}
        self.imports: dict[str, dict[str, str]] = {}

    # ------------------------------------------------------------------ #
    # loading
    # ------------------------------------------------------------------ #
    def load(self) -> "Project":
        for path in sorted(self.root.rglob("*.py")):
            rel = path.relative_to(self.root)
            name = ".".join(rel.with_suffix("").parts)
            if name.endswith(".__init__"):
                name = name[: -len(".__init__")]
            elif name == "__init__":
                name = ""
            self._load_file(path, name or rel.stem)
        self._index()
        return self

    def load_file(self, path: Path, name: str | None = None) -> "Project":
        path = Path(path)
        self._load_file(path, name or path.stem)
        self._index()
        return self

    def _load_file(self, path: Path, name: str) -> None:
        source = path.read_text()
        try:
            rel_path = str(path.relative_to(self.repo_root))
        except ValueError:
            rel_path = str(path)
        allows, func_allows = _parse_allows(source.splitlines())
        self.modules[name] = Module(
            name=name, path=rel_path, tree=ast.parse(source),
            lines=source.splitlines(), allows=allows,
            func_allows=func_allows)

    def _index(self) -> None:
        self.classes.clear()
        self.functions.clear()
        self.methods_by_name.clear()
        self.imports.clear()
        for mod in self.modules.values():
            imports: dict[str, str] = {}
            for node in ast.walk(mod.tree):
                if isinstance(node, ast.Import):
                    for alias in node.names:
                        imports[alias.asname or alias.name.split(".")[0]] = \
                            alias.name
                elif isinstance(node, ast.ImportFrom):
                    base = node.module or ""
                    for alias in node.names:
                        imports[alias.asname or alias.name] = \
                            f"{base}.{alias.name}" if base else alias.name
            self.imports[mod.name] = imports
            for node in mod.tree.body:
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    self._add_function(mod, node, cls=None)
                elif isinstance(node, ast.ClassDef):
                    cls_qual = f"{mod.name}.{node.name}"
                    info = ClassInfo(
                        qual=cls_qual, name=node.name, module=mod,
                        node=node,
                        bases=[ast.unparse(b) for b in node.bases])
                    self.classes[cls_qual] = info
                    for item in node.body:
                        if isinstance(item, (ast.FunctionDef,
                                             ast.AsyncFunctionDef)):
                            fi = self._add_function(mod, item, cls=cls_qual)
                            info.methods[item.name] = fi

    def _add_function(self, mod: Module, node, cls: str | None
                      ) -> FunctionInfo:
        qual = (f"{cls}.{node.name}" if cls
                else f"{mod.name}.{node.name}")
        fi = FunctionInfo(qual=qual, name=node.name, module=mod,
                          node=node, cls=cls)
        self.functions[qual] = fi
        self.methods_by_name.setdefault(node.name, []).append(fi)
        return fi

    # ------------------------------------------------------------------ #
    # symbol resolution helpers
    # ------------------------------------------------------------------ #
    def class_by_name(self, name: str) -> list[ClassInfo]:
        return [c for c in self.classes.values() if c.name == name]

    def mro(self, cls_qual: str) -> Iterator[ClassInfo]:
        """The class and its loaded ancestors (best-effort linearization)."""
        seen: set[str] = set()
        stack = [cls_qual]
        while stack:
            qual = stack.pop(0)
            if qual in seen or qual not in self.classes:
                continue
            seen.add(qual)
            info = self.classes[qual]
            yield info
            for base in info.bases:
                base_name = base.split(".")[-1]
                for cand in self.class_by_name(base_name):
                    stack.append(cand.qual)

    def subclasses(self, cls_qual: str) -> Iterator[ClassInfo]:
        """Loaded classes that (transitively) derive from ``cls_qual``."""
        target = self.classes.get(cls_qual)
        if target is None:
            return
        for info in self.classes.values():
            if info.qual == cls_qual:
                continue
            if any(m.qual == cls_qual for m in self.mro(info.qual)):
                yield info


def load_core(repo_root: str | Path, rel: str = "src/repro/core"
              ) -> Project:
    """Load the core package rooted at ``repo_root``."""
    repo_root = Path(repo_root)
    return Project(repo_root / rel, repo_root=repo_root).load()
