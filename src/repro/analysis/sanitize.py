"""Runtime lock sanitizer (``REPRO_SANITIZE=1``).

Instruments ``threading.Lock`` / ``threading.RLock`` so every lock
*created from repro source* records the real acquisition order observed
while the test suite runs:

  * each lock instance is keyed to the same *lock class* the static
    checker uses (``storage._StudyShard.lock``) by matching its
    creation site against the AST lock model — the runtime edge set is
    directly comparable to the static acquisition graph;
  * a watchdog inside ``acquire`` dumps every held lock and all thread
    stacks to stderr when an acquisition stalls longer than
    ``REPRO_SANITIZE_STALL`` seconds (default 30) — a suspected
    deadlock becomes a readable report instead of a hung CI job;
  * at session end (see the repo-root ``conftest.py``),
    :func:`cross_check` compares the observed edges against the static
    graph: an observed order ``a -> b`` where the static graph can
    reach ``a`` from ``b`` is an *inversion* — the combined evidence is
    a cycle — and fails the run.

Only locks created from files under ``src/repro`` are wrapped; the
stdlib's own locks (``queue``, ``logging``, ``threading.Condition``
internals created from ``threading.py``) pass through untouched.

``REPRO_SANITIZE=race`` layers an Eraser-style shared-state sanitizer
on top (see :func:`install_race`): the concurrency-bearing core classes
get a ``__setattr__`` wrapper that records (thread, field, held
lockset) samples and runs the classic lockset state machine per
(instance, field) — exclusive while one thread owns the field, then a
candidate lockset seeded at the first access from a second thread and
intersected on every later cross-thread write.  An empty observed
intersection is a data race and fails the session.  Fields audited
with ``# repro-check: allow(shared-state)`` are exempt, read from the
same static model the ``shared-state`` checker uses, so the static and
runtime views validate each other.  Bare ``threading.Condition()``
objects created from repro source are given a tracked inner lock in
this mode, so ``with self._cv:`` sections count as locked.
"""
from __future__ import annotations

import importlib
import itertools
import linecache
import os
import sys
import threading
import traceback
from typing import Any

# originals, captured before install() rebinds the factories
_ORIG_LOCK = threading.Lock
_ORIG_RLOCK = threading.RLock
_ORIG_CONDITION = threading.Condition

_STALL_SECONDS = float(os.environ.get("REPRO_SANITIZE_STALL", "30"))

_installed = False
_state_lock = _ORIG_LOCK()          # guards the module-global records
_edges: dict[tuple[str, str], str] = {}   # (held, acquired) -> example
_self_edges: dict[str, int] = {}          # key -> times nested with itself
_keys_seen: dict[str, int] = {}           # key -> locks created
_stalls: list[dict[str, Any]] = []
_site_keys: dict[tuple[str, int], str] = {}
_tls = threading.local()
# one clock for creations and acquisitions: lets an edge recorder see
# that the acquired lock was born inside the held lock's critical
# section (the runtime image of the static fresh-instance rule)
_clock = itertools.count()
# thread ident -> (thread name, its held list) — readable cross-thread
# by the stall dump, unlike the threading.local itself
_held_by_thread: dict[int, tuple[str, list]] = {}


def _held() -> list[tuple["_TrackedLock", int]]:
    held = getattr(_tls, "held", None)
    if held is None:
        held = _tls.held = []
        t = threading.current_thread()
        with _state_lock:
            _held_by_thread[t.ident or 0] = (t.name, held)
    return held


class _TrackedLock:
    """Order-recording proxy around a real ``Lock``/``RLock``.

    Implements the context-manager and ``acquire``/``release`` surface
    plus (via delegation) the private RLock methods ``Condition``
    needs, so ``threading.Condition(tracked_rlock)`` keeps working.
    """

    def __init__(self, inner: Any, key: str):
        self._inner = inner
        self.key = key
        self.created_by = threading.get_ident()
        self.created_seq = next(_clock)

    # -- acquisition ---------------------------------------------------- #
    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        if not blocking or timeout != -1:
            got = self._inner.acquire(blocking, timeout)
            if got:
                self._note_acquired()
            return got
        waited = 0.0
        dumped = False
        while not self._inner.acquire(timeout=1.0):
            waited += 1.0
            if waited >= _STALL_SECONDS and not dumped:
                dumped = True
                _dump_stall(self, waited)
        self._note_acquired()
        return True

    def release(self) -> None:
        self._inner.release()
        held = _held()
        for i in range(len(held) - 1, -1, -1):
            if held[i][0] is self:
                del held[i]
                break

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc: Any) -> None:
        self.release()

    def locked(self) -> bool:
        return self._inner.locked()

    def __getattr__(self, name: str) -> Any:
        # Condition compatibility: _is_owned/_acquire_restore/... go to
        # the real lock (order bookkeeping is best-effort around waits)
        return getattr(self._inner, name)

    # -- bookkeeping ---------------------------------------------------- #
    def _note_acquired(self) -> None:
        held = _held()
        seq = next(_clock)
        if any(h is self for h, _ in held):  # RLock re-entry: no new edge
            held.append((self, seq))
            return
        if held:
            me = threading.get_ident()
            where = _caller_site()
            with _state_lock:
                for h, h_seq in held:
                    if (self.created_by == me
                            and self.created_seq > h_seq):
                        # this lock was born inside the held lock's
                        # critical section, on this thread: a private
                        # instance no other thread can contend
                        continue
                    if h.key == self.key:
                        _self_edges[self.key] = \
                            _self_edges.get(self.key, 0) + 1
                    elif (h.key, self.key) not in _edges:
                        _edges[(h.key, self.key)] = where
        held.append((self, seq))


def _caller_site() -> str:
    f: Any = sys._getframe(1)
    while f is not None and f.f_code.co_filename == __file__:
        f = f.f_back
    if f is None:
        return "?"
    return f"{f.f_code.co_filename}:{f.f_lineno}"


def _dump_stall(lock: _TrackedLock, waited: float) -> None:
    lines = [
        f"repro-sanitize: suspected deadlock — thread "
        f"{threading.current_thread().name!r} has waited {waited:.0f}s "
        f"for {lock.key}",
        "repro-sanitize: locks held per thread:",
    ]
    with _state_lock:
        _stalls.append({"key": lock.key, "waited": waited,
                        "thread": threading.current_thread().name})
        holders = {ident: (name, [h.key for h, _ in held])
                   for ident, (name, held) in _held_by_thread.items()}
    for ident, (name, keys) in sorted(holders.items()):
        if keys:
            lines.append(f"  {name} ({ident}): {keys}")
    lines.append("repro-sanitize: all thread stacks:")
    for tid, frame in sys._current_frames().items():
        lines.append(f"  -- thread {tid} --")
        lines.extend("  " + ln.rstrip()
                     for ln in traceback.format_stack(frame))
    print("\n".join(lines), file=sys.stderr, flush=True)


# ----------------------------------------------------------------------- #
# installation
# ----------------------------------------------------------------------- #
def _load_site_keys(repo_root: str) -> dict[tuple[str, int], str]:
    """(abs file, lineno of the ``threading.Lock()`` assignment) ->
    static lock-class key, from the same model the checker uses."""
    from .checkers.lock_order import LockModel
    from .loader import load_core

    project = load_core(repo_root)
    model = LockModel(project)
    out: dict[tuple[str, int], str] = {}
    for lc in model.classes.values():
        mod = project.modules.get(lc.module)
        if mod is None:
            continue
        abs_path = os.path.realpath(os.path.join(repo_root, mod.path))
        out[(abs_path, lc.line)] = lc.key
    return out


def _repo_root() -> str:
    # src/repro/analysis/sanitize.py -> repo root three levels above src/
    return os.path.realpath(
        os.path.join(os.path.dirname(__file__), "..", "..", ".."))


def _make_factory(orig: Any, src_prefix: str):
    def factory(*args: Any, **kwargs: Any) -> Any:
        inner = orig(*args, **kwargs)
        frame = sys._getframe(1)
        fname = os.path.realpath(frame.f_code.co_filename)
        if not fname.startswith(src_prefix):
            return inner
        # extension code (numpy's BitGenerator, etc.) can call the
        # factory with no Python frame of its own — the nearest repro
        # frame would be blamed for a lock it never created.  Only wrap
        # when the creating source line really constructs a lock.
        if "Lock(" not in linecache.getline(fname, frame.f_lineno):
            return inner
        key = _site_keys.get((fname, frame.f_lineno))
        if key is None:
            rel = os.path.relpath(fname, _repo_root())
            key = f"{rel}:{frame.f_lineno}"
        with _state_lock:
            _keys_seen[key] = _keys_seen.get(key, 0) + 1
        return _TrackedLock(inner, key)
    return factory


def install(repo_root: str | None = None,
            src_prefix: str | None = None) -> None:
    """Patch the ``threading`` lock factories.  Idempotent."""
    global _installed
    if _installed:
        return
    root = repo_root or _repo_root()
    prefix = src_prefix or os.path.join(root, "src", "repro")
    _site_keys.update(_load_site_keys(root))
    threading.Lock = _make_factory(_ORIG_LOCK, prefix)
    threading.RLock = _make_factory(_ORIG_RLOCK, prefix)
    _installed = True


def installed() -> bool:
    return _installed


# ----------------------------------------------------------------------- #
# race mode (REPRO_SANITIZE=race): Eraser lockset state machine
# ----------------------------------------------------------------------- #
_race_installed = False
_race_prefix = ""
_race_allowed: set[tuple[str, str]] = set()
# id(instance) -> field -> {"owner": ident, "owner_name": str,
#                           "lockset": None (exclusive) | set[str]}
_race_state: dict[int, dict[str, dict[str, Any]]] = {}
_race_seen: set[tuple[str, str]] = set()
_race_violations: list[dict[str, Any]] = []
_race_classes: list[str] = []
_race_fields_tracked: set[tuple[str, str]] = set()


def _condition_factory(lock: Any = None) -> Any:
    """Replacement ``threading.Condition``: a bare ``Condition()``
    created from repro source gets a tracked inner RLock keyed to its
    creation site, so critical sections entered through the condition
    count as locked in both the order and race bookkeeping.  Explicit
    locks and non-repro callers pass through untouched."""
    if lock is not None:
        return _ORIG_CONDITION(lock)
    frame: Any = sys._getframe(1)
    fname = os.path.realpath(frame.f_code.co_filename)
    if (not _race_prefix or not fname.startswith(_race_prefix)
            or "Condition(" not in linecache.getline(fname, frame.f_lineno)):
        return _ORIG_CONDITION()
    key = _site_keys.get((fname, frame.f_lineno))
    if key is None:
        rel = os.path.relpath(fname, _repo_root())
        key = f"{rel}:{frame.f_lineno}"
    with _state_lock:
        _keys_seen[key] = _keys_seen.get(key, 0) + 1
    return _ORIG_CONDITION(_TrackedLock(_ORIG_RLOCK(), key))


def _race_skip_value(value: Any) -> bool:
    # synchronization primitives and thread handles are not data fields
    return (isinstance(value, _TrackedLock)
            or type(value).__module__ in ("threading", "_thread"))


def _race_note(obj: Any, name: str, value: Any) -> None:
    if name.startswith("__") or name.startswith("_abc_"):
        return
    if _race_skip_value(value):
        return
    mro_names = [k.__name__ for k in type(obj).__mro__]
    if any((cn, name) in _race_allowed for cn in mro_names):
        return
    cname = mro_names[0]
    t = threading.get_ident()
    held = frozenset(h.key for h, _ in _held())
    with _state_lock:
        _race_fields_tracked.add((cname, name))
        fields = _race_state.setdefault(id(obj), {})
        st = fields.get(name)
        if st is None:
            fields[name] = {
                "owner": t,
                "owner_name": threading.current_thread().name,
                "lockset": None,
            }
            return
        if st["lockset"] is None:
            if st["owner"] == t:
                return                  # still thread-exclusive
            # first access from a second thread: seed the candidate set
            st["lockset"] = set(held)
        else:
            st["lockset"] &= held
        if not st["lockset"] and (cname, name) not in _race_seen:
            _race_seen.add((cname, name))
            _race_violations.append({
                "class": cname,
                "field": name,
                "site": _caller_site(),
                "threads": sorted({st["owner_name"],
                                   threading.current_thread().name}),
            })


def _instrument_class(cls: type) -> None:
    if cls.__dict__.get("__repro_race__"):
        return
    orig_setattr = cls.__setattr__
    orig_init = cls.__init__

    def __setattr__(self: Any, name: str, value: Any) -> None:
        orig_setattr(self, name, value)
        _race_note(self, name, value)

    def __init__(self: Any, *args: Any, **kwargs: Any) -> None:
        # ids are recycled: a new instance at a dead instance's address
        # must not inherit its lockset history
        with _state_lock:
            _race_state.pop(id(self), None)
        orig_init(self, *args, **kwargs)

    cls.__setattr__ = __setattr__      # type: ignore[method-assign]
    cls.__init__ = __init__            # type: ignore[method-assign]
    cls.__repro_race__ = True          # type: ignore[attr-defined]


def install_race(repo_root: str | None = None,
                 src_prefix: str | None = None) -> None:
    """Install the shared-state race sanitizer.  Idempotent; implies
    :func:`install` (lockset samples come from the tracked locks)."""
    global _race_installed, _race_prefix
    if _race_installed:
        return
    install(repo_root, src_prefix)
    root = repo_root or _repo_root()
    _race_prefix = src_prefix or os.path.join(root, "src", "repro")
    threading.Condition = _condition_factory  # type: ignore[misc,assignment]

    from .checkers import shared_state
    from .loader import load_core

    project = load_core(root)
    _race_allowed.update(shared_state.allowed_fields(project))
    for cname in shared_state.DEFAULT_CONFIG["classes"]:
        for ci in project.class_by_name(cname):
            try:
                mod = importlib.import_module(
                    "repro.core." + ci.module.name)
            except ImportError:
                continue
            cls = getattr(mod, cname, None)
            if isinstance(cls, type):
                _instrument_class(cls)
                _race_classes.append(cname)
                break
    _race_installed = True


def race_installed() -> bool:
    return _race_installed


def race_report() -> dict[str, Any]:
    with _state_lock:
        return {
            "violations": [dict(v) for v in _race_violations],
            "instrumented_classes": list(_race_classes),
            "fields_tracked": len(_race_fields_tracked),
            "fields_allowed": len(_race_allowed),
        }


# ----------------------------------------------------------------------- #
# reporting + static cross-check
# ----------------------------------------------------------------------- #
def report() -> dict[str, Any]:
    with _state_lock:
        return {
            "edges": {f"{a} -> {b}": site
                      for (a, b), site in sorted(_edges.items())},
            "self_edges": dict(_self_edges),
            "locks_created": dict(_keys_seen),
            "stalls": list(_stalls),
        }


def cross_check(runtime_edges: dict[tuple[str, str], str],
                static_edges: dict[tuple[str, str], str]
                ) -> dict[str, list]:
    """Compare observed order against the static acquisition graph.

    ``inversions``: observed ``a -> b`` where the static graph reaches
    ``a`` from ``b`` — combined, a cycle (potential deadlock).
    ``unknown``: observed edges the static graph has no opinion on
    (informational; usually locks below the model's resolution).
    """
    adj: dict[str, set[str]] = {}
    for (a, b) in static_edges:
        adj.setdefault(a, set()).add(b)

    reach_cache: dict[str, set[str]] = {}

    def reachable(src: str) -> set[str]:
        if src in reach_cache:
            return reach_cache[src]
        seen: set[str] = set()
        stack = [src]
        while stack:
            n = stack.pop()
            for m in adj.get(n, ()):
                if m not in seen:
                    seen.add(m)
                    stack.append(m)
        reach_cache[src] = seen
        return seen

    inversions, unknown = [], []
    for (a, b), site in sorted(runtime_edges.items()):
        if a in reachable(b):
            inversions.append({"edge": f"{a} -> {b}", "site": site,
                               "static_reverse_path": f"{b} ~> {a}"})
        elif (a, b) not in static_edges:
            unknown.append({"edge": f"{a} -> {b}", "site": site})
    return {"inversions": inversions, "unknown": unknown}


def cross_check_repo(repo_root: str | None = None) -> dict[str, Any]:
    """Full session-end check: observed edges vs the freshly built
    static graph of this repo.  Returns the merged report."""
    from .checkers.lock_order import build_lock_graph
    from .loader import load_core

    root = repo_root or _repo_root()
    graph = build_lock_graph(load_core(root))
    with _state_lock:
        runtime = dict(_edges)
    out = cross_check(runtime, graph["edges"])
    out.update(report())
    return out
