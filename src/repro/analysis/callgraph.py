"""Call-graph construction with best-effort method resolution.

The graph is *may-call*: an edge means the caller can plausibly reach the
callee.  Resolution handles the shapes the core package actually uses —

  * bare names (module functions, imported functions),
  * ``self.method()`` through the loaded MRO **and** loaded subclass
    overrides (virtual dispatch: ``InMemoryStorage.add_trial`` calling
    ``self._log`` must reach ``DurableStorage._log``),
  * ``Class.method()`` / ``obj.method()`` where ``obj`` was constructed
    from a loaded class in the same function,
  * a unique-method-name fallback for everything else (sound for
    may-block analysis; annotations cut the false edges that matter).

Also home to the blocking-primitive classifier shared by the lock-order
and event-loop checkers.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from typing import Callable, Iterable

from .loader import FunctionInfo, Module, Project


@dataclasses.dataclass(frozen=True)
class CallSite:
    caller: str                  # qual of the calling function
    path: str
    line: int
    text: str                    # unparsed call expression (truncated)
    # receiver is an instance constructed in the calling function
    # (``shadow = InMemoryStorage(); shadow.load_state(...)``) — its
    # locks are private and must not alias the live store's lock classes
    fresh: bool = False


@dataclasses.dataclass(frozen=True)
class BlockingCall:
    kind: str                    # "fsync" | "socket" | "sleep" | ...
    site: CallSite
    chain: tuple[str, ...]       # qualified call chain from the entry


# attribute names that mean a blocking syscall on the receiver
_SOCKET_ATTRS = {"sendall", "recv", "recv_into", "accept", "connect",
                 "getresponse", "send", "makefile", "sendfile"}
_PROC_ATTRS = {"communicate"}
_THREADISH = ("thread", "proc", "worker", "flusher", "compactor",
              "monitor", "_t", "child")

# method names whose unique-name fallback resolution is noise, not signal:
# they collide with builtin dict/list/set/str/file methods used everywhere
_FALLBACK_DENY = {
    "get", "pop", "update", "items", "keys", "values", "add", "remove",
    "clear", "append", "extend", "insert", "discard", "setdefault",
    "popitem", "copy", "count", "index", "sort", "split", "strip",
    "join", "read", "write", "encode", "decode", "format", "replace",
    "startswith", "endswith", "lower", "upper", "stop", "start",
    "submit", "put", "get_nowait", "put_nowait",
    # file-object methods: ``self._active_file.flush()`` must not alias
    # the storage classes' flush()/close() overrides
    "flush", "close",
}


def classify_blocking(call: ast.Call, module: Module,
                      imports: dict[str, str]) -> str | None:
    """Blocking-primitive kind of ``call``, or None."""
    fn = call.func
    if isinstance(fn, ast.Name):
        target = imports.get(fn.id, fn.id)
        if target in ("time.sleep", "sleep"):
            return "sleep"
        if target in ("os.fsync", "os.fdatasync", "fsync", "fdatasync"):
            return "fsync"
        return None
    if not isinstance(fn, ast.Attribute):
        return None
    attr = fn.attr
    recv = ast.unparse(fn.value)
    recv_root = recv.split(".")[0].split("[")[0]
    dotted = imports.get(recv_root, recv_root)
    if attr == "sleep" and dotted == "time":
        return "sleep"
    if attr in ("fsync", "fdatasync") and dotted == "os":
        return "fsync"
    if attr in ("flock", "lockf") and dotted == "fcntl":
        return "flock"
    if attr in _SOCKET_ATTRS:
        # str.startswith-style false positives are impossible for these
        # names; ``send`` on non-blocking sockets is excused by
        # annotation at the audited sites.
        return "socket"
    if attr in _PROC_ATTRS:
        return "subprocess"
    if attr == "wait":
        # Condition.wait under its *own* condition releases the lock —
        # the lock-order checker exempts that case by receiver; every
        # other .wait() (Popen, Event, foreign Condition) blocks.
        return "wait"
    if attr == "join":
        # distinguish Thread.join from str.join: a thread-ish receiver
        # name, or a no-arg / numeric-timeout call.
        low = recv.lower()
        if any(t in low for t in _THREADISH):
            return "join"
        if not call.args:
            return "join"
        if (len(call.args) == 1
                and isinstance(call.args[0], ast.Constant)
                and isinstance(call.args[0].value, (int, float))):
            return "join"
    return None


def _ann_class_name(text: str) -> str:
    """``HopaasServer`` / ``Optional[RouteTable]`` / ``x.Y | None`` ->
    the bare class name (best effort)."""
    text = text.strip().strip("'\"")
    m = re.fullmatch(r"Optional\[(.+)\]", text)
    if m:
        text = m.group(1)
    text = text.split("|")[0].strip()
    return text.split(".")[-1].strip("'\"")


class CallGraph:
    def __init__(self, project: Project):
        self.project = project
        # qual -> list[(callee FunctionInfo, CallSite)]
        self._edges: dict[str, list[tuple[FunctionInfo, CallSite]]] = {}
        # qual -> list[(blocking kind, CallSite)]
        self._direct_blocking: dict[str, list[tuple[str, CallSite]]] = {}
        # class qual -> {attr -> class qual}: ``self.server = server``
        # where the param is annotated, or ``self.x = SomeClass(...)``
        self._attr_types = self._class_attr_types()
        self._build()

    def _class_attr_types(self) -> dict[str, dict[str, str]]:
        out: dict[str, dict[str, str]] = {}
        for info in self.project.classes.values():
            types: dict[str, str] = {}
            ambiguous: set[str] = set()

            def note(attr: str, qual: str) -> None:
                if types.get(attr, qual) != qual:
                    ambiguous.add(attr)
                types[attr] = qual

            for m in info.methods.values():
                ann: dict[str, str] = {}
                args = list(m.node.args.args) + list(
                    m.node.args.kwonlyargs)
                for arg in args:
                    if arg.annotation is None:
                        continue
                    name = _ann_class_name(ast.unparse(arg.annotation))
                    for cand in self.project.class_by_name(name):
                        ann[arg.arg] = cand.qual
                        break
                for node in ast.walk(m.node):
                    target = value = None
                    if isinstance(node, ast.Assign) \
                            and len(node.targets) == 1:
                        target, value = node.targets[0], node.value
                    elif isinstance(node, ast.AnnAssign):
                        target, value = node.target, node.value
                    if not (isinstance(target, ast.Attribute)
                            and isinstance(target.value, ast.Name)
                            and target.value.id == "self"):
                        continue
                    if (isinstance(node, ast.AnnAssign)
                            and node.annotation is not None):
                        name = _ann_class_name(
                            ast.unparse(node.annotation))
                        for cand in self.project.class_by_name(name):
                            note(target.attr, cand.qual)
                            break
                        continue
                    if isinstance(value, ast.Call) and isinstance(
                            value.func, ast.Name):
                        for cand in self.project.class_by_name(
                                value.func.id):
                            note(target.attr, cand.qual)
                            break
                    elif isinstance(value, ast.Name) and value.id in ann:
                        note(target.attr, ann[value.id])
            for attr in ambiguous:
                types.pop(attr, None)
            out[info.qual] = types
        return out

    # ------------------------------------------------------------------ #
    def _build(self) -> None:
        for fi in self.project.functions.values():
            edges: list[tuple[FunctionInfo, CallSite]] = []
            blocking: list[tuple[str, CallSite]] = []
            imports = self.project.imports.get(fi.module.name, {})
            local_types = self._infer_local_types(fi)
            for node in ast.walk(fi.node):
                if not isinstance(node, ast.Call):
                    continue
                fresh = (isinstance(node.func, ast.Attribute)
                         and isinstance(node.func.value, ast.Name)
                         and node.func.value.id in local_types)
                site = CallSite(
                    caller=fi.qual, path=fi.module.path,
                    line=node.lineno,
                    text=ast.unparse(node)[:120],
                    fresh=fresh)
                kind = classify_blocking(node, fi.module, imports)
                if kind is not None:
                    blocking.append((kind, site))
                for callee in self._resolve(fi, node, imports, local_types):
                    edges.append((callee, site))
            self._edges[fi.qual] = edges
            self._direct_blocking[fi.qual] = blocking

    def _infer_local_types(self, fi: FunctionInfo) -> dict[str, str]:
        """name -> class qual for ``x = SomeLoadedClass(...)`` locals."""
        out: dict[str, str] = {}
        for node in ast.walk(fi.node):
            if (isinstance(node, ast.Assign)
                    and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and isinstance(node.value, ast.Call)
                    and isinstance(node.value.func, ast.Name)):
                for cand in self.project.class_by_name(node.value.func.id):
                    out[node.targets[0].id] = cand.qual
        return out

    def _resolve(self, fi: FunctionInfo, call: ast.Call,
                 imports: dict[str, str], local_types: dict[str, str]
                 ) -> list[FunctionInfo]:
        fn = call.func
        if isinstance(fn, ast.Name):
            # module function in the same module
            mod_qual = f"{fi.module.name}.{fn.id}"
            if mod_qual in self.project.functions:
                return [self.project.functions[mod_qual]]
            # imported function from a loaded module
            target = imports.get(fn.id)
            if target:
                tail = target.split(".")
                for k in range(1, len(tail)):
                    qual = ".".join(tail[-k - 1:])
                    if qual in self.project.functions:
                        return [self.project.functions[qual]]
            # constructor of a loaded class
            ctors = []
            for cand in self.project.class_by_name(fn.id):
                init = cand.methods.get("__init__")
                if init:
                    ctors.append(init)
            return ctors
        if not isinstance(fn, ast.Attribute):
            return []
        attr = fn.attr
        recv = fn.value
        # self.method() — MRO plus loaded subclass overrides
        if isinstance(recv, ast.Name) and recv.id == "self" and fi.cls:
            out: dict[str, FunctionInfo] = {}
            for cls in self.project.mro(fi.cls):
                if attr in cls.methods and attr not in out:
                    out[cls.qual] = cls.methods[attr]
            for sub in self.project.subclasses(fi.cls):
                if attr in sub.methods:
                    out[sub.qual] = sub.methods[attr]
            if out:
                return list(out.values())
        # Class.method() / obj.method() with an inferred local type
        if isinstance(recv, ast.Name):
            cls_qual = local_types.get(recv.id)
            if cls_qual is None:
                for cand in self.project.class_by_name(recv.id):
                    cls_qual = cand.qual
                    break
            if cls_qual:
                for cls in self.project.mro(cls_qual):
                    if attr in cls.methods:
                        return [cls.methods[attr]]
        # self.attr.method() with a typed instance attribute — resolve
        # through the attribute class's MRO plus loaded overrides
        # (virtual dispatch), never through the name-soup fallback
        if (isinstance(recv, ast.Attribute)
                and isinstance(recv.value, ast.Name)
                and recv.value.id == "self" and fi.cls):
            t = self._attr_types.get(fi.cls, {}).get(recv.attr)
            if t:
                out: dict[str, FunctionInfo] = {}
                for cls in self.project.mro(t):
                    if attr in cls.methods and attr not in out:
                        out[cls.qual] = cls.methods[attr]
                for sub in self.project.subclasses(t):
                    if attr in sub.methods:
                        out[sub.qual] = sub.methods[attr]
                if out:
                    return list(out.values())
        # fallback: every loaded method with this name (may-call) —
        # except names shared with builtin collections/strings, which
        # produce wildly false edges (a dict's .pop is not RouteTable.pop)
        if attr in _FALLBACK_DENY:
            return []
        cands = self.project.methods_by_name.get(attr, [])
        if 0 < len(cands) <= 6:
            return list(cands)
        return []

    # ------------------------------------------------------------------ #
    def calls_in(self, qual: str) -> list[tuple[FunctionInfo, CallSite]]:
        return self._edges.get(qual, [])

    def direct_blocking(self, qual: str) -> list[tuple[str, CallSite]]:
        return self._direct_blocking.get(qual, [])

    def reachable_blocking(
            self, entry: str, *, allow_tag: str,
            skip_call: Callable[[CallSite], bool] | None = None,
            max_depth: int = 12) -> list[BlockingCall]:
        """Blocking primitives reachable from ``entry``.

        Traversal stops at call sites (or whole functions) annotated with
        ``# repro-check: allow(<allow_tag>)`` and at sites where
        ``skip_call`` returns True.
        """
        out: list[BlockingCall] = []
        seen: set[str] = set()

        def visit(qual: str, chain: tuple[str, ...], depth: int) -> None:
            if qual in seen or depth > max_depth:
                return
            seen.add(qual)
            fi = self.project.functions.get(qual)
            if fi is not None and fi.module.function_allowed(
                    fi.node, allow_tag):
                return
            for kind, site in self.direct_blocking(qual):
                mod = self._module_of(qual)
                if mod is not None and mod.is_allowed(site.line, allow_tag):
                    continue
                if skip_call is not None and skip_call(site):
                    continue
                out.append(BlockingCall(kind=kind, site=site,
                                        chain=chain + (qual,)))
            for callee, site in self.calls_in(qual):
                mod = self._module_of(qual)
                if mod is not None and mod.is_allowed(site.line, allow_tag):
                    continue
                if skip_call is not None and skip_call(site):
                    continue
                visit(callee.qual, chain + (qual,), depth + 1)

        visit(entry, (), 0)
        return out

    def _module_of(self, qual: str) -> Module | None:
        fi = self.project.functions.get(qual)
        return fi.module if fi else None

    def transitive_callees(self, entry: str, max_depth: int = 12
                           ) -> Iterable[str]:
        seen: set[str] = set()
        stack = [(entry, 0)]
        while stack:
            qual, depth = stack.pop()
            if qual in seen or depth > max_depth:
                continue
            seen.add(qual)
            yield qual
            for callee, _ in self.calls_in(qual):
                stack.append((callee.qual, depth + 1))
