"""Checker registry for repro-check.

Each checker is a callable ``run(project, config=None) -> list[Finding]``.
``CHECKERS`` maps the CLI name to the callable; order is report order.
"""
from __future__ import annotations

from . import (evloop, lock_order, shared_state, thread_hygiene,
               wal_order, wire_schema)

CHECKERS = {
    "lock-order": lock_order.run,
    "evloop-blocking": evloop.run,
    "wal-order": wal_order.run,
    "wire-schema": wire_schema.run,
    "thread-hygiene": thread_hygiene.run,
    "shared-state": shared_state.run,
}

__all__ = ["CHECKERS"]
