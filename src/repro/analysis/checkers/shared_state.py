"""Eraser-style shared-state checker: thread roots -> escape -> lockset.

Three passes over the core package, all AST-only:

1. **Thread-root discovery** — every concurrent entry point:
   ``threading.Thread(target=...)`` spawns (lane pools, WAL flusher and
   compactor daemons, the fabric monitor, replication hub/client
   threads), ``threading.Timer``, ``multiprocessing.Process`` workers,
   and ``threading.Thread`` subclasses' ``run`` methods.  A synthetic
   ``<main>`` root covers everything reachable from external entry
   points (loaded functions with no loaded caller).  Dynamic dispatch
   the call graph cannot see (the router calling registered handler
   closures) is closed over by configured ``dispatch_edges``.

2. **Escape analysis** — which instance attributes of the configured
   core classes are accessed from >= 2 roots after construction.
   Receivers are typed from ``self``, annotated parameters and
   return-annotated helpers (``shard = self._shard(key)``); accesses on
   locally constructed instances are private to the constructing
   function, matching the call-graph's fresh-instance rule.  Functions
   reachable only from ``__init__`` methods are construction-phase:
   their accesses happen before the instance is published.

3. **Lockset pass** (Eraser's core idea) — reusing the lock-order
   checker's lock-class abstraction: every access gets the set of lock
   classes statically held there (enclosing ``with``/``acquire`` spans
   plus a meet-over-call-sites entry lockset), and an escaped field
   whose intersection across all post-init accesses is empty — no
   single lock consistently protects it — is flagged.

Audited lock-free fields (GIL-atomic monotonic counters, single-writer
stats, write-once flags) carry
``# repro-check: allow(shared-state) -- why`` on any line that touches
the field (conventionally the initialising assignment); that audits the
whole field.  The runtime race sanitizer (``REPRO_SANITIZE=race``)
derives its allowlist from the same annotations, so the static model
and observed behaviour stay cross-validated.
"""
from __future__ import annotations

import ast
import dataclasses

from ..callgraph import CallGraph, _ann_class_name
from ..findings import Finding
from ..loader import ClassInfo, FunctionInfo, Project
from .lock_order import DEFAULT_CONFIG as _LOCK_DEFAULTS
from .lock_order import Span, build_lock_graph

TAG = "shared-state"
MAIN_ROOT = "<main>"

DEFAULT_CONFIG = {
    # classes whose instances are shared across threads; a configured
    # name missing from the project is itself a finding (coverage pin)
    "classes": ("_StudyShard", "DurableStorage", "ReplicationHub",
                "ReplicationClient", "FabricDispatcher",
                "EventLoopFrontend", "SpeculativeQueue",
                "SpeculativeWorker"),
    # subsystems (top-level module names) that must contribute at least
    # one discovered thread root — used by the --stats coverage guard
    "root_subsystems": ("aio", "durable", "fabric", "replication",
                        "speculate"),
    # dynamic dispatch the AST cannot resolve: the router calls handler
    # closures registered at construction time, so handler bodies (which
    # live in the register_* functions) run on whatever thread dispatches
    "dispatch_edges": (
        ("api.router.Router.dispatch", "api.v2.register_v2"),
        ("api.router.Router.dispatch", "api.v1.register_v1"),
    ),
    # entry points spawned outside the loaded AST (the threaded frontend
    # hands _make_handler's nested class to ThreadingHTTPServer, which
    # runs it on per-connection threads)
    "extra_roots": ("transport._make_handler",),
    "aliases": _LOCK_DEFAULTS["aliases"],
}

_SPAWN_KINDS = {"Thread": "thread", "Timer": "timer", "Process": "process"}

# receiver-mutating method names: ``self.waiting.append(x)`` writes the
# field's value even though the reference is only read
_MUTATORS = {"append", "add", "update", "pop", "popitem", "remove",
             "discard", "clear", "extend", "insert", "setdefault",
             "appendleft", "popleft", "sort"}
_HEAP_FNS = {"heappush", "heappop", "heapify", "heapreplace",
             "heappushpop"}


@dataclasses.dataclass(frozen=True)
class ThreadRoot:
    qual: str        # entry function qual ("durable.DurableStorage._flush_loop")
    kind: str        # "thread" | "timer" | "process" | "thread-subclass" | "config"
    subsystem: str   # top-level module name of the spawn site
    path: str
    line: int


@dataclasses.dataclass
class Access:
    attr: str
    func: FunctionInfo
    line: int
    write: bool
    recv: str


@dataclasses.dataclass
class FieldReport:
    family: str              # configured class name
    cls_qual: str            # primary class qual
    class_names: tuple[str, ...]   # every class name in the family
    attr: str
    accesses: list[Access]
    post_init: list[Access]
    roots: set[str]
    lockset: frozenset[str] | None   # intersection over post-init accesses
    allowed: bool
    flagged: bool
    example: Access | None


@dataclasses.dataclass
class SharedStateReport:
    roots: list[ThreadRoot]
    fields: list[FieldReport]
    families: dict[str, list[str]]   # configured name -> class quals found
    missing: list[str]               # configured names not in the project


# --------------------------------------------------------------------------- #
# pass 1: thread roots
# --------------------------------------------------------------------------- #
def _target_functions(project: Project, fi: FunctionInfo,
                      expr: ast.expr) -> list[FunctionInfo]:
    """Resolve a ``target=`` expression to candidate entry functions."""
    if isinstance(expr, ast.Call):
        fn = expr.func
        name = (fn.attr if isinstance(fn, ast.Attribute)
                else getattr(fn, "id", ""))
        if name == "partial" and expr.args:
            expr = expr.args[0]
    if isinstance(expr, ast.Attribute) and isinstance(expr.value, ast.Name):
        recv = expr.value.id
        if recv == "self" and fi.cls:
            out: dict[str, FunctionInfo] = {}
            for cls in project.mro(fi.cls):
                if expr.attr in cls.methods and expr.attr not in out:
                    out[cls.qual] = cls.methods[expr.attr]
            for sub in project.subclasses(fi.cls):
                if expr.attr in sub.methods:
                    out[sub.qual] = sub.methods[expr.attr]
            return list(out.values())
        for cand in project.class_by_name(recv):
            for cls in project.mro(cand.qual):
                if expr.attr in cls.methods:
                    return [cls.methods[expr.attr]]
        # obj.method where obj is untyped: unique-name fallback
        cands = project.methods_by_name.get(expr.attr, [])
        if len(cands) == 1:
            return list(cands)
        return []
    if isinstance(expr, ast.Name):
        qual = f"{fi.module.name}.{expr.id}"
        if qual in project.functions:
            return [project.functions[qual]]
        target = project.imports.get(fi.module.name, {}).get(expr.id)
        if target:
            tail = target.split(".")
            for k in range(1, len(tail)):
                qual = ".".join(tail[-k - 1:])
                if qual in project.functions:
                    return [project.functions[qual]]
    return []


def discover_roots(project: Project, config: dict | None = None
                   ) -> list[ThreadRoot]:
    cfg = dict(DEFAULT_CONFIG)
    if config:
        cfg.update(config)
    roots: dict[str, ThreadRoot] = {}

    def add(qual: str, kind: str, subsystem: str, path: str,
            line: int) -> None:
        if qual not in roots:
            roots[qual] = ThreadRoot(qual=qual, kind=kind,
                                     subsystem=subsystem, path=path,
                                     line=line)

    # threading.Thread subclasses: run() is an entry once started
    for info in project.classes.values():
        if any(b.split(".")[-1] == "Thread" for b in info.bases):
            run = info.methods.get("run")
            if run is not None:
                add(run.qual, "thread-subclass",
                    info.module.name.split(".")[0], info.module.path,
                    info.node.lineno)

    for fi in project.functions.values():
        for node in ast.walk(fi.node):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            name = (fn.attr if isinstance(fn, ast.Attribute)
                    else fn.id if isinstance(fn, ast.Name) else "")
            kind = _SPAWN_KINDS.get(name)
            if kind is None:
                continue
            target_expr = None
            for kw in node.keywords:
                if kw.arg == "target":
                    target_expr = kw.value
            if target_expr is None and kind == "timer" \
                    and len(node.args) >= 2:
                target_expr = node.args[1]
            if target_expr is None:
                continue
            for tgt in _target_functions(project, fi, target_expr):
                add(tgt.qual, kind, fi.module.name.split(".")[0],
                    fi.module.path, node.lineno)

    for qual in cfg.get("extra_roots", ()):
        fi = project.functions.get(qual)
        if fi is not None:
            add(qual, "config", fi.module.name.split(".")[0],
                fi.module.path, fi.node.lineno)
    return sorted(roots.values(), key=lambda r: r.qual)


# --------------------------------------------------------------------------- #
# call-graph scaffolding shared by the escape and lockset passes
# --------------------------------------------------------------------------- #
def _call_edges(project: Project, cg: CallGraph,
                dispatch: tuple) -> dict[str, list[tuple[str, int, bool]]]:
    """caller qual -> [(callee qual, call line, receiver-is-fresh)]."""
    edges: dict[str, list[tuple[str, int, bool]]] = {
        q: [] for q in project.functions}
    for qual in project.functions:
        for callee, site in cg.calls_in(qual):
            edges[qual].append((callee.qual, site.line, site.fresh))
    for a, b in dispatch:
        if a in edges and b in project.functions:
            edges[a].append((b, 0, False))
    return edges


def _callers(edges: dict[str, list[tuple[str, int, bool]]]
             ) -> dict[str, set[str]]:
    out: dict[str, set[str]] = {}
    for caller, outs in edges.items():
        for callee, _, _ in outs:
            out.setdefault(callee, set()).add(caller)
    return out


def _reach_from(edges: dict[str, list[tuple[str, int, bool]]],
                entry: str) -> set[str]:
    seen: set[str] = set()
    stack = [entry]
    while stack:
        q = stack.pop()
        if q in seen:
            continue
        seen.add(q)
        for callee, _, fresh in edges.get(q, ()):
            if fresh:
                continue    # private instance: not the shared object
            stack.append(callee)
    return seen


def _init_only(project: Project, callers: dict[str, set[str]],
               root_quals: set[str]) -> set[str]:
    """Functions reachable *only* from ``__init__`` methods."""
    init = {q for q in project.functions
            if q.split(".")[-1] == "__init__" and q not in root_quals}
    changed = True
    while changed:
        changed = False
        for q in project.functions:
            if q in init or q in root_quals:
                continue
            cs = callers.get(q)
            if cs and all(c in init for c in cs):
                init.add(q)
                changed = True
    return init


def _spans_at(spans: dict[str, list[Span]], qual: str, line: int
              ) -> set[str]:
    return {s.key for s in spans.get(qual, ())
            if s.start <= line <= s.end}


def _entry_locksets(project: Project,
                    edges: dict[str, list[tuple[str, int, bool]]],
                    spans: dict[str, list[Span]],
                    forced_empty: set[str]) -> dict[str, set[str] | None]:
    """Meet-over-call-sites locks held when each function is entered.

    ``None`` is top (never reached from an entry: no opinion); thread
    roots and external entries are pinned to the empty set.
    """
    held: dict[str, set[str] | None] = {q: None for q in project.functions}
    for q in forced_empty:
        if q in held:
            held[q] = set()
    changed = True
    while changed:
        changed = False
        for caller, outs in edges.items():
            ch = held.get(caller)
            if ch is None:
                continue
            for callee, line, fresh in outs:
                if fresh or callee in forced_empty:
                    continue
                at = ch | _spans_at(spans, caller, line)
                cur = held.get(callee)
                if cur is None:
                    held[callee] = set(at)
                    changed = True
                else:
                    new = cur & at
                    if new != cur:
                        held[callee] = new
                        changed = True
    return held


# --------------------------------------------------------------------------- #
# pass 2: access collection over typed receivers
# --------------------------------------------------------------------------- #
def _families(project: Project, cfg: dict
              ) -> dict[str, dict[str, ClassInfo]]:
    out: dict[str, dict[str, ClassInfo]] = {}
    for name in cfg["classes"]:
        fam: dict[str, ClassInfo] = {}
        for ci in project.class_by_name(name):
            for m in project.mro(ci.qual):
                fam[m.qual] = m
            for s in project.subclasses(ci.qual):
                fam[s.qual] = s
        out[name] = fam
    return out


def _return_type(project: Project, fi: FunctionInfo,
                 call: ast.Call) -> str | None:
    """Class name of the callee's return annotation, best effort."""
    fn = call.func
    cands: list[FunctionInfo] = []
    if isinstance(fn, ast.Name):
        qual = f"{fi.module.name}.{fn.id}"
        if qual in project.functions:
            cands = [project.functions[qual]]
    elif isinstance(fn, ast.Attribute):
        if isinstance(fn.value, ast.Name) and fn.value.id == "self" \
                and fi.cls:
            for cls in project.mro(fi.cls):
                if fn.attr in cls.methods:
                    cands = [cls.methods[fn.attr]]
                    break
        if not cands:
            pool = project.methods_by_name.get(fn.attr, [])
            if len(pool) == 1:
                cands = list(pool)
    for cand in cands:
        if cand.node.returns is not None:
            return _ann_class_name(ast.unparse(cand.node.returns))
    return None


def _typed_receivers(project: Project, fi: FunctionInfo,
                     fam_names: set[str]) -> set[str]:
    """Local names statically typed as a family class in ``fi`` —
    excluding names bound by direct construction (fresh instances)."""
    recvs: set[str] = set()
    fresh: set[str] = set()
    args = (list(fi.node.args.args) + list(fi.node.args.kwonlyargs)
            + list(getattr(fi.node.args, "posonlyargs", [])))
    for arg in args:
        if arg.arg == "self" or arg.annotation is None:
            continue
        if _ann_class_name(ast.unparse(arg.annotation)) in fam_names:
            recvs.add(arg.arg)
    for node in ast.walk(fi.node):
        target = None
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            target = node.targets[0].id
        elif isinstance(node, ast.AnnAssign) \
                and isinstance(node.target, ast.Name):
            if _ann_class_name(ast.unparse(node.annotation)) in fam_names:
                recvs.add(node.target.id)
            continue
        if target is None or not isinstance(node.value, ast.Call):
            continue
        callee = node.value.func
        if isinstance(callee, ast.Name) and callee.id in fam_names:
            fresh.add(target)
            continue
        rt = _return_type(project, fi, node.value)
        if rt in fam_names:
            recvs.add(target)
    return recvs - fresh


def _collect_accesses(fi: FunctionInfo, recv: str, method_names: set[str],
                      skip_attrs: set[str],
                      out: dict[str, list[Access]]) -> None:
    parent: dict[ast.AST, ast.AST] = {}
    for node in ast.walk(fi.node):
        for ch in ast.iter_child_nodes(node):
            parent[ch] = node
    for node in ast.walk(fi.node):
        if not isinstance(node, ast.Attribute):
            continue
        if not (isinstance(node.value, ast.Name)
                and node.value.id == recv):
            continue
        attr = node.attr
        if attr.startswith("__") or attr in skip_attrs \
                or attr in method_names:
            continue
        write = isinstance(node.ctx, (ast.Store, ast.Del))
        if not write:
            p = parent.get(node)
            if isinstance(p, ast.Subscript) and p.value is node \
                    and isinstance(p.ctx, (ast.Store, ast.Del)):
                write = True
            elif isinstance(p, ast.Attribute) and p.value is node \
                    and p.attr in _MUTATORS:
                pp = parent.get(p)
                if isinstance(pp, ast.Call) and pp.func is p:
                    write = True
            elif isinstance(p, ast.Call) and p.args and p.args[0] is node:
                fn = p.func
                nm = (fn.attr if isinstance(fn, ast.Attribute)
                      else getattr(fn, "id", ""))
                if nm in _HEAP_FNS:
                    write = True
        out.setdefault(attr, []).append(Access(
            attr=attr, func=fi, line=node.lineno, write=write, recv=recv))


def _family_accesses(project: Project, fam: dict[str, ClassInfo],
                     lock_attrs: set[str]) -> dict[str, list[Access]]:
    method_names: set[str] = set()
    for ci in fam.values():
        method_names |= set(ci.methods)
    fam_names = {ci.name for ci in fam.values()}
    accesses: dict[str, list[Access]] = {}
    seen: set[str] = set()
    for ci in fam.values():
        for m in ci.methods.values():
            if m.qual in seen:
                continue
            seen.add(m.qual)
            _collect_accesses(m, "self", method_names, lock_attrs,
                              accesses)
    for fi in project.functions.values():
        for recv in _typed_receivers(project, fi, fam_names):
            _collect_accesses(fi, recv, method_names, lock_attrs,
                              accesses)
    return accesses


def _class_default_allowed(fam: dict[str, ClassInfo], attr: str) -> bool:
    """allow(shared-state) on a class-level default assignment line."""
    for ci in fam.values():
        for node in ci.node.body:
            target = None
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Name) and t.id == attr:
                        target = t
            elif isinstance(node, ast.AnnAssign) \
                    and isinstance(node.target, ast.Name) \
                    and node.target.id == attr:
                target = node.target
            if target is not None and ci.module.is_allowed(
                    node.lineno, TAG):
                return True
    return False


# --------------------------------------------------------------------------- #
# pass 3: lockset verdicts
# --------------------------------------------------------------------------- #
def analyze(project: Project, config: dict | None = None,
            graph: dict | None = None) -> SharedStateReport:
    cfg = dict(DEFAULT_CONFIG)
    if config:
        cfg.update(config)
    if graph is None:
        graph = build_lock_graph(project, {"aliases": cfg["aliases"]})
    model = graph["model"]
    cg: CallGraph = graph["callgraph"]
    spans: dict[str, list[Span]] = graph["spans"]

    roots = discover_roots(project, cfg)
    root_quals = {r.qual for r in roots}
    edges = _call_edges(project, cg, tuple(cfg.get("dispatch_edges", ())))
    callers = _callers(edges)
    externals = {q for q in project.functions
                 if q not in callers and q not in root_quals}
    init_only = _init_only(project, callers, root_quals)
    entry_held = _entry_locksets(project, edges, spans,
                                 root_quals | externals)

    reach = {q: _reach_from(edges, q) for q in root_quals}
    main_reach: set[str] = set()
    for q in externals:
        main_reach |= _reach_from(edges, q)
    roots_of: dict[str, set[str]] = {}
    for q in project.functions:
        rs = {rq for rq in root_quals if q in reach[rq]}
        if q in main_reach:
            rs.add(MAIN_ROOT)
        if not rs:
            # unreachable from any loaded entry (dynamic dispatch we do
            # not model): assume the main thread can run it
            rs = {MAIN_ROOT}
        roots_of[q] = rs

    lock_attrs = {lc.key.split(".")[-1] for lc in model.classes.values()}

    fields: list[FieldReport] = []
    families: dict[str, list[str]] = {}
    missing: list[str] = []
    for name, fam in _families(project, cfg).items():
        if not fam:
            missing.append(name)
            continue
        primary = next((ci for ci in fam.values() if ci.name == name),
                       next(iter(fam.values())))
        families[name] = sorted(fam)
        class_names = tuple(sorted({ci.name for ci in fam.values()}))
        accesses = _family_accesses(project, fam, lock_attrs)
        for attr, accs in sorted(accesses.items()):
            allowed = _class_default_allowed(fam, attr) or any(
                a.func.module.is_allowed(a.line, TAG)
                or a.func.module.function_allowed(a.func.node, TAG)
                for a in accs)
            post = [a for a in accs if a.func.qual not in init_only]
            writes = [a for a in post if a.write]
            acc_roots: set[str] = set()
            for a in post:
                acc_roots |= roots_of[a.func.qual]
            lockset: frozenset[str] | None = None
            flagged = False
            example: Access | None = None
            if not allowed and writes and len(acc_roots) >= 2:
                inter: set[str] | None = None
                empty_at: Access | None = None
                for a in post:
                    eh = entry_held.get(a.func.qual)
                    if eh is None:
                        continue    # unreached: no opinion
                    ls = eh | _spans_at(spans, a.func.qual, a.line)
                    inter = set(ls) if inter is None else inter & ls
                    if not ls and (empty_at is None or
                                   (a.write and not empty_at.write)):
                        empty_at = a
                if inter is not None:
                    lockset = frozenset(inter)
                    if not inter:
                        flagged = True
                        example = (empty_at
                                   or next(iter(writes), post[0]))
            fields.append(FieldReport(
                family=name, cls_qual=primary.qual,
                class_names=class_names, attr=attr, accesses=accs,
                post_init=post, roots=acc_roots, lockset=lockset,
                allowed=allowed, flagged=flagged, example=example))
    return SharedStateReport(roots=roots, fields=fields,
                             families=families, missing=missing)


def allowed_fields(project: Project, config: dict | None = None
                   ) -> set[tuple[str, str]]:
    """(class name, attr) pairs audited with allow(shared-state),
    expanded over every class in the owning family — the runtime race
    sanitizer matches by concrete ``type(obj).__name__``."""
    rep = analyze(project, config)
    out: set[tuple[str, str]] = set()
    for fr in rep.fields:
        if fr.allowed:
            for cls_name in fr.class_names:
                out.add((cls_name, fr.attr))
    return out


def stats(project: Project, config: dict | None = None,
          report: SharedStateReport | None = None) -> dict:
    cfg = dict(DEFAULT_CONFIG)
    if config:
        cfg.update(config)
    rep = report if report is not None else analyze(project, cfg)
    by_subsystem: dict[str, int] = {s: 0 for s in cfg["root_subsystems"]}
    for r in rep.roots:
        by_subsystem[r.subsystem] = by_subsystem.get(r.subsystem, 0) + 1
    return {
        "roots": len(rep.roots),
        "roots_by_subsystem": dict(sorted(by_subsystem.items())),
        "required_subsystems": list(cfg["root_subsystems"]),
        "classes_configured": len(cfg["classes"]),
        "classes_found": len(rep.families),
        "fields_examined": len(rep.fields),
        "fields_escaped": sum(1 for f in rep.fields
                              if len(f.roots) >= 2
                              and any(a.write for a in f.post_init)),
        "fields_allowed": sum(1 for f in rep.fields if f.allowed),
        "fields_flagged": sum(1 for f in rep.fields if f.flagged),
    }


# --------------------------------------------------------------------------- #
def run(project: Project, config: dict | None = None) -> list[Finding]:
    cfg = dict(DEFAULT_CONFIG)
    if config:
        cfg.update(config)
    rep = analyze(project, cfg)
    findings: list[Finding] = []

    for name in rep.missing:
        findings.append(Finding(
            checker="shared-state", rule="missing-class",
            path="", line=0, symbol=name,
            message=f"configured shared class {name!r} not found — "
                    f"renamed or dropped without updating the checker "
                    f"config (coverage would silently shrink)",
            detail=f"missing:{name}"))

    for fr in rep.fields:
        if not fr.flagged:
            continue
        ex = fr.example
        shown = sorted(fr.roots)
        if len(shown) > 4:
            shown = shown[:4] + [f"+{len(fr.roots) - 4} more"]
        where = (f"{ex.func.module.path}:{ex.line} in {ex.func.qual}"
                 if ex else "?")
        what = "write" if ex is not None and ex.write else "access"
        findings.append(Finding(
            checker="shared-state", rule="unlocked-shared-field",
            path=ex.func.module.path if ex else "",
            line=ex.line if ex else 0,
            symbol=f"{fr.cls_qual}.{fr.attr}",
            message=f"field {fr.cls_qual}.{fr.attr} is shared across "
                    f"roots {{{', '.join(shown)}}} with empty lockset "
                    f"intersection; e.g. unlocked {what} at {where}",
            detail=f"{fr.cls_qual}|{fr.attr}"))

    seen: set[str] = set()
    out: list[Finding] = []
    for f in findings:
        if f.fingerprint not in seen:
            seen.add(f.fingerprint)
            out.append(f)
    return out
