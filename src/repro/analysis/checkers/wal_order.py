"""Write-ahead ordering checker.

The storage invariant (PR 4): every mutator serializes its operation to
the journal/WAL (``self._log({...})``) *before* touching in-memory
state, so crash recovery replays to a digest-identical state.  A mutation
that lands before the log call is unrecoverable — the journal would
miss it (or record it after a partially applied state).

The checker walks every method of the storage classes that calls the
journal serializer and flags in-memory mutations (assignments or
mutating calls rooted at ``self`` or a shard) that can execute on a path
where the log call has not happened yet.  Branches are analyzed
independently; a path counts as "logged" only once every branch through
it has logged.

Exemptions: counters/telemetry attributes (configured), and
``# repro-check: allow(wal-order)`` for audited sites (e.g. rebuilding
derived indexes during replay, which by definition must not re-journal).
"""
from __future__ import annotations

import ast

from ..findings import Finding
from ..loader import FunctionInfo, Project

DEFAULT_CONFIG = {
    "module": "storage",
    # classes whose mutators must write ahead; subclasses are included
    "classes": ("InMemoryStorage",),
    "log_method": "_log",
    # receivers whose mutation is state (self plus the shard parameter)
    "roots": ("self", "shard"),
    # attributes that are telemetry/bookkeeping, not recovered state
    "exempt_attrs": ("_stats", "_metrics", "_last_flush", "_dirty",
                     "_pending_ack"),
}

_MUTATING_ATTRS = {"append", "appendleft", "add", "insert", "update",
                   "setdefault", "pop", "popitem", "remove", "discard",
                   "clear", "extend", "__setitem__"}


def _root_of(expr: ast.expr) -> str | None:
    while isinstance(expr, (ast.Attribute, ast.Subscript)):
        expr = expr.value
    return expr.id if isinstance(expr, ast.Name) else None


def _attr_chain(expr: ast.expr) -> list[str]:
    out: list[str] = []
    while isinstance(expr, (ast.Attribute, ast.Subscript)):
        if isinstance(expr, ast.Attribute):
            out.append(expr.attr)
        expr = expr.value
    return list(reversed(out))


class _PathWalker:
    """Linearized walk tracking whether the log call has happened yet."""

    def __init__(self, fi: FunctionInfo, cfg: dict,
                 findings: list[Finding]):
        self.fi = fi
        self.cfg = cfg
        self.findings = findings
        self.exempt = set(cfg["exempt_attrs"])
        self.roots = set(cfg["roots"])

    # -> True when the statement list is guaranteed to have logged
    def walk(self, body: list[ast.stmt], logged: bool) -> bool:
        for stmt in body:
            logged = self._stmt(stmt, logged)
        return logged

    def _stmt(self, stmt: ast.stmt, logged: bool) -> bool:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return logged
        if not logged:
            self._check_mutations(stmt)
        if isinstance(stmt, ast.If):
            a = self.walk(stmt.body, logged)
            b = self.walk(stmt.orelse, logged)
            return a and b
        if isinstance(stmt, (ast.For, ast.While)):
            self.walk(stmt.body, logged)
            self.walk(stmt.orelse, logged)
            return logged
        if isinstance(stmt, ast.With):
            return self.walk(stmt.body, logged)
        if isinstance(stmt, ast.Try):
            a = self.walk(stmt.body, logged)
            for handler in stmt.handlers:
                a = self.walk(handler.body, logged) and a
            a = self.walk(stmt.orelse, a) and a
            return self.walk(stmt.finalbody, a)
        return logged or self._logs(stmt)

    def _logs(self, stmt: ast.stmt) -> bool:
        log_method = self.cfg["log_method"]
        for node in ast.walk(stmt):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == log_method
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id == "self"):
                return True
        return False

    def logs_anywhere(self) -> bool:
        return self._logs(self.fi.node)

    def _check_mutations(self, stmt: ast.stmt) -> None:
        # only the statement itself, not nested blocks (handled above)
        if isinstance(stmt, (ast.If, ast.For, ast.While, ast.With,
                             ast.Try)):
            nodes: list[ast.AST] = [stmt.test] if isinstance(
                stmt, (ast.If, ast.While)) else []
            if isinstance(stmt, ast.For):
                nodes = [stmt.iter]
            if isinstance(stmt, ast.With):
                nodes = [i.context_expr for i in stmt.items]
        else:
            nodes = [stmt]
        for top in nodes:
            if top is None:
                continue
            for node in ast.walk(top):
                self._check_node(node)

    def _check_node(self, node: ast.AST) -> None:
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                if isinstance(t, ast.Tuple):
                    sub = list(t.elts)
                else:
                    sub = [t]
                for target in sub:
                    if not isinstance(target, (ast.Attribute,
                                               ast.Subscript)):
                        continue
                    self._flag_if_state(target, node)
        elif isinstance(node, ast.Call) and isinstance(
                node.func, ast.Attribute) and \
                node.func.attr in _MUTATING_ATTRS:
            self._flag_if_state(node.func.value, node)

    def _flag_if_state(self, expr: ast.expr, node: ast.AST) -> None:
        root = _root_of(expr)
        if root not in self.roots:
            return
        chain = _attr_chain(expr)
        if chain and chain[0] in self.exempt:
            return
        mod = self.fi.module
        line = getattr(node, "lineno", self.fi.node.lineno)
        if mod.is_allowed(line, "wal-order") or \
                mod.function_allowed(self.fi.node, "wal-order"):
            return
        text = ast.unparse(node)[:80]
        self.findings.append(Finding(
            checker="wal-order", rule="mutate-before-journal",
            path=mod.path, line=line, symbol=self.fi.qual,
            message=f"in-memory mutation `{text}` can execute before "
                    f"the write-ahead `self.{self.cfg['log_method']}(...)` "
                    f"call — recovery would diverge",
            detail=f"{self.fi.qual}|{text}"))


def run(project: Project, config: dict | None = None) -> list[Finding]:
    cfg = dict(DEFAULT_CONFIG)
    if config:
        cfg.update(config)
    findings: list[Finding] = []
    targets: list[str] = []
    for name in cfg["classes"]:
        for cls in project.class_by_name(name):
            targets.append(cls.qual)
            targets.extend(s.qual for s in project.subclasses(cls.qual))

    seen_methods: set[str] = set()
    for cls_qual in targets:
        cls = project.classes.get(cls_qual)
        if cls is None:
            continue
        for method in cls.methods.values():
            if method.qual in seen_methods:
                continue
            seen_methods.add(method.qual)
            if method.name == cfg["log_method"]:
                continue
            walker = _PathWalker(method, cfg, findings)
            if not walker.logs_anywhere():
                continue
            walker.walk(method.node.body, logged=False)

    seen: set[str] = set()
    out = []
    for f in findings:
        if f.fingerprint not in seen:
            seen.add(f.fingerprint)
            out.append(f)
    return out
