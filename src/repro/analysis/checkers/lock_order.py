"""Lock-order checker: static acquisition graph + blocking-under-lock.

Two rules over the concurrency modules (``storage``, ``durable``,
``aio``, ``fabric``, ``replication``, ``server`` by default):

``lock-cycle``
    Every ``with <lock>:`` / ``<lock>.acquire()`` /
    ``stack.enter_context(<lock>)`` span contributes edges *held-lock ->
    newly-acquired-lock* (including acquisitions made by transitively
    called functions).  Locks are abstracted to *lock classes* —
    ``storage._StudyShard.lock`` is one node no matter how many shards
    exist, the standard static deadlock abstraction.  Any strongly
    connected component with more than one node is a potential deadlock.

``blocking-under-lock``
    A blocking primitive (``os.fsync``, socket send/recv, ``sleep``,
    thread ``join``, subprocess waits, foreign ``Condition.wait``)
    reached while a *shard or WAL* lock class is held.  ``cv.wait()``
    under its own condition is exempt (it releases the lock).  Audited
    exceptions carry ``# repro-check: allow(blocking-under-lock)``.

The graph this builds is also exported (``build_lock_graph``) for the
runtime sanitizer, which validates real acquisition order against it.
"""
from __future__ import annotations

import ast
import dataclasses

from ..callgraph import CallGraph, classify_blocking
from ..findings import Finding
from ..loader import FunctionInfo, Project

_LOCK_FACTORIES = {"Lock", "RLock", "Condition", "BoundedSemaphore",
                   "Semaphore"}

DEFAULT_CONFIG = {
    # modules whose lock spans are analyzed (project-relative names)
    "modules": ("storage", "durable", "aio", "fabric", "replication",
                "server", "speculate"),
    # lock classes defined in these modules are "shard or WAL" locks:
    # blocking while holding one is a finding
    "critical_modules": ("storage", "durable"),
    # attribute expressions the resolver cannot type, mapped by hand —
    # server keeps the per-study shard lock on its context object
    "aliases": {
        ("server", "ctx.lock"): "storage._StudyShard.lock",
        ("server", "self.lock"): "storage._StudyShard.lock",
    },
}


@dataclasses.dataclass(frozen=True)
class LockClass:
    key: str        # "storage._StudyShard.lock" / "aio._switch_lock"
    module: str
    attr: str
    line: int


@dataclasses.dataclass
class Span:
    key: str
    func: FunctionInfo
    start: int
    end: int
    ref_text: str   # source expression of the acquisition ("self._lock")
    line: int


class LockModel:
    """Discovered lock classes + resolution of lock reference exprs."""

    def __init__(self, project: Project, aliases: dict | None = None):
        self.project = project
        self.aliases = dict(aliases or {})
        self.classes: dict[str, LockClass] = {}
        # attr name -> lock classes carrying it
        self.by_attr: dict[str, list[LockClass]] = {}
        # provider function name -> lock key (e.g. study_lock)
        self.providers: dict[str, str] = {}
        self._discover()
        self._discover_providers()

    def _add(self, key: str, module: str, attr: str, line: int) -> None:
        lc = LockClass(key=key, module=module, attr=attr, line=line)
        self.classes[key] = lc
        self.by_attr.setdefault(attr, []).append(lc)

    def _discover(self) -> None:
        for mod in self.project.modules.values():
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Assign) or not isinstance(
                        node.value, ast.Call):
                    continue
                fn = node.value.func
                name = (fn.attr if isinstance(fn, ast.Attribute)
                        else fn.id if isinstance(fn, ast.Name) else "")
                if name not in _LOCK_FACTORIES:
                    continue
                for target in node.targets:
                    if (isinstance(target, ast.Attribute)
                            and isinstance(target.value, ast.Name)
                            and target.value.id == "self"):
                        cls = self._enclosing_class(mod, node)
                        owner = cls or mod.name
                        self._add(f"{mod.name}.{owner.split('.')[-1]}."
                                  f"{target.attr}"
                                  if cls else f"{mod.name}.{target.attr}",
                                  mod.name, target.attr, node.lineno)
                    elif isinstance(target, ast.Name):
                        # module-level or long-lived local lock
                        self._add(f"{mod.name}.{target.id}", mod.name,
                                  target.id, node.lineno)

    def _enclosing_class(self, mod, node) -> str | None:
        for cls in mod.tree.body:
            if isinstance(cls, ast.ClassDef) and \
                    cls.lineno <= node.lineno <= (cls.end_lineno or 1 << 30):
                return cls.name
        return None

    def _discover_providers(self) -> None:
        """Functions that *return* a lock (``storage.study_lock``)."""
        for fi in self.project.functions.values():
            for node in ast.walk(fi.node):
                if isinstance(node, ast.Return) and node.value is not None:
                    key = self._resolve_expr(node.value, fi, {})
                    if key is not None:
                        self.providers[fi.name] = key

    # ------------------------------------------------------------------ #
    def resolve(self, expr: ast.expr, fi: FunctionInfo,
                local_binds: dict[str, str]) -> str | None:
        return self._resolve_expr(expr, fi, local_binds)

    def _resolve_expr(self, expr: ast.expr, fi: FunctionInfo,
                      local_binds: dict[str, str]) -> str | None:
        text = ast.unparse(expr)
        alias = self.aliases.get((fi.module.name, text))
        if alias is not None:
            return alias
        if isinstance(expr, ast.Call):
            # provider call: self.storage.study_lock(key)
            fn = expr.func
            name = (fn.attr if isinstance(fn, ast.Attribute)
                    else fn.id if isinstance(fn, ast.Name) else "")
            return self.providers.get(name)
        if isinstance(expr, ast.Name):
            if expr.id in local_binds:
                return local_binds[expr.id]
            key = f"{fi.module.name}.{expr.id}"
            return key if key in self.classes else None
        if isinstance(expr, ast.Attribute):
            cands = self.by_attr.get(expr.attr, [])
            if not cands:
                return None
            recv = ast.unparse(expr.value)
            if recv == "self" and fi.cls:
                # own (or inherited/overriding) class first
                names = {c.name for c in self.project.mro(fi.cls)}
                names |= {c.name
                          for c in self.project.subclasses(fi.cls)}
                own = [c for c in cands
                       if c.key.split(".")[-2] in names]
                if own:
                    return own[0].key
            same_mod = [c for c in cands if c.module == fi.module.name]
            if len(same_mod) == 1:
                return same_mod[0].key
            pool = same_mod or cands
            # name hint: "shard".lock -> _StudyShard.lock
            hint = recv.split(".")[-1].split("[")[0].lstrip("_").lower()
            hinted = [c for c in pool
                      if hint and hint in c.key.split(".")[-2]
                      .lstrip("_").lower()]
            if len(hinted) == 1:
                return hinted[0].key
            if len(pool) == 1:
                return pool[0].key
            return None
        return None


def _local_lock_binds(fi: FunctionInfo, model: LockModel) -> dict[str, str]:
    """``lock = self.storage.study_lock(k)``-style local name bindings."""
    binds: dict[str, str] = {}
    for node in ast.walk(fi.node):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            key = model.resolve(node.value, fi, binds)
            if key is not None:
                binds[node.targets[0].id] = key
    return binds


def _spans_in(fi: FunctionInfo, model: LockModel) -> list[Span]:
    binds = _local_lock_binds(fi, model)
    spans: list[Span] = []
    end_of_func = fi.node.end_lineno or fi.node.lineno

    for node in ast.walk(fi.node):
        if isinstance(node, ast.With):
            for item in node.items:
                key = model.resolve(item.context_expr, fi, binds)
                if key is not None:
                    spans.append(Span(
                        key=key, func=fi, start=node.lineno,
                        end=node.end_lineno or node.lineno,
                        ref_text=ast.unparse(item.context_expr),
                        line=node.lineno))
        elif isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Attribute) and fn.attr == "acquire":
                key = model.resolve(fn.value, fi, binds)
                if key is not None:
                    spans.append(Span(
                        key=key, func=fi, start=node.lineno,
                        end=_release_line(fi, fn.value, node.lineno)
                        or end_of_func,
                        ref_text=ast.unparse(fn.value), line=node.lineno))
            elif (isinstance(fn, ast.Attribute)
                  and fn.attr == "enter_context" and node.args):
                key = model.resolve(node.args[0], fi, binds)
                if key is not None:
                    # held until the ExitStack unwinds — treat as the
                    # rest of the function (conservative)
                    spans.append(Span(
                        key=key, func=fi, start=node.lineno,
                        end=end_of_func,
                        ref_text=ast.unparse(node.args[0]),
                        line=node.lineno))
    return spans


def _release_line(fi: FunctionInfo, ref: ast.expr, after: int
                  ) -> int | None:
    want = ast.unparse(ref)
    best: int | None = None
    for node in ast.walk(fi.node):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "release"
                and ast.unparse(node.func.value) == want
                and node.lineno >= after):
            if best is None or node.lineno < best:
                best = node.lineno
    return best


# --------------------------------------------------------------------------- #
def build_lock_graph(project: Project, config: dict | None = None) -> dict:
    """-> {"keys": [...], "edges": {(a, b): example-site}, "spans": ...}

    Shared by the checker and the runtime sanitizer cross-check.
    """
    cfg = dict(DEFAULT_CONFIG)
    if config:
        cfg.update(config)
    model = LockModel(project, aliases=cfg.get("aliases"))
    cg = CallGraph(project)

    all_spans: dict[str, list[Span]] = {}
    for fi in project.functions.values():
        spans = _spans_in(fi, model)
        if spans:
            all_spans[fi.qual] = spans

    # transitive lock acquisition per function (memoized, cycle-tolerant)
    closure_cache: dict[str, set[tuple[str, str]]] = {}

    def closure(qual: str, stack: tuple = ()) -> set[tuple[str, str]]:
        if qual in closure_cache:
            return closure_cache[qual]
        if qual in stack or len(stack) > 12:
            return set()
        acc = {(s.key, f"{s.func.module.path}:{s.line}")
               for s in all_spans.get(qual, [])}
        for callee, site in cg.calls_in(qual):
            if site.fresh:
                continue    # private instance: its locks are unaliased
            acc |= closure(callee.qual, stack + (qual,))
        closure_cache[qual] = acc
        return acc

    edges: dict[tuple[str, str], str] = {}

    def add_edge(a: str, b: str, where: str) -> None:
        if a != b and (a, b) not in edges:
            edges[(a, b)] = where

    for qual, spans in all_spans.items():
        fi = project.functions[qual]
        for span in spans:
            where = f"{fi.module.path}:{span.line} in {qual}"
            # nested spans in the same function
            for other in spans:
                if other is not span and span.start <= other.start \
                        and other.end <= span.end:
                    add_edge(span.key, other.key, where)
            # acquisitions made by calls inside the span
            for callee, site in cg.calls_in(qual):
                if not (span.start <= site.line <= span.end):
                    continue
                if site.fresh:
                    continue    # private instance: locks unaliased
                if fi.module.is_allowed(site.line, "lock-order"):
                    continue
                for key, where2 in closure(callee.qual):
                    add_edge(span.key, key,
                             f"{where} -> {callee.qual} ({where2})")

    return {"keys": sorted(model.classes),
            "edges": edges,
            "spans": all_spans,
            "model": model,
            "callgraph": cg,
            "config": cfg}


def _sccs(nodes: list[str], edges: dict[tuple[str, str], str]
          ) -> list[list[str]]:
    """Tarjan strongly connected components."""
    adj: dict[str, list[str]] = {n: [] for n in nodes}
    for (a, b) in edges:
        adj.setdefault(a, []).append(b)
        adj.setdefault(b, [])
    index: dict[str, int] = {}
    low: dict[str, int] = {}
    on_stack: set[str] = set()
    stack: list[str] = []
    out: list[list[str]] = []
    counter = [0]

    def strong(v: str) -> None:
        index[v] = low[v] = counter[0]
        counter[0] += 1
        stack.append(v)
        on_stack.add(v)
        for w in adj.get(v, ()):
            if w not in index:
                strong(w)
                low[v] = min(low[v], low[w])
            elif w in on_stack:
                low[v] = min(low[v], index[w])
        if low[v] == index[v]:
            comp = []
            while True:
                w = stack.pop()
                on_stack.discard(w)
                comp.append(w)
                if w == v:
                    break
            out.append(comp)

    for v in list(adj):
        if v not in index:
            strong(v)
    return out


# --------------------------------------------------------------------------- #
def run(project: Project, config: dict | None = None) -> list[Finding]:
    cfg = dict(DEFAULT_CONFIG)
    if config:
        cfg.update(config)
    graph = build_lock_graph(project, cfg)
    model: LockModel = graph["model"]
    cg: CallGraph = graph["callgraph"]
    all_spans: dict[str, list[Span]] = graph["spans"]
    findings: list[Finding] = []

    # rule 1: cycles in the acquisition graph
    for comp in _sccs(graph["keys"], graph["edges"]):
        if len(comp) < 2:
            continue
        comp = sorted(comp)
        sites = [graph["edges"][(a, b)]
                 for (a, b) in graph["edges"] if a in comp and b in comp]
        first = min(sites) if sites else ""
        findings.append(Finding(
            checker="lock-order", rule="lock-cycle",
            path=first.split(":")[0] if first else "",
            line=int(first.split(":")[1].split(" ")[0]) if first else 0,
            symbol="",
            message=f"potential deadlock: lock classes acquired in a "
                    f"cycle: {' <-> '.join(comp)}"
                    + (f"; e.g. {sites[0]}" if sites else ""),
            detail="cycle:" + ",".join(comp)))

    # rule 2: blocking calls while a shard/WAL lock class is held
    critical_mods = set(cfg["critical_modules"])
    analyzed = set(cfg["modules"])
    tag = "blocking-under-lock"

    def is_critical(key: str) -> bool:
        lc = model.classes.get(key)
        return (lc.module if lc else key.split(".")[0]) in critical_mods

    for qual, spans in all_spans.items():
        fi = project.functions[qual]
        if fi.module.name.split(".")[0] not in analyzed:
            continue
        if fi.module.function_allowed(fi.node, tag):
            continue
        for span in spans:
            if not is_critical(span.key):
                continue
            held_refs = {s.ref_text for s in spans
                         if s.start <= span.start and span.end <= s.end}
            # direct blocking calls inside the span
            imports = project.imports.get(fi.module.name, {})
            for node in ast.walk(fi.node):
                if not isinstance(node, ast.Call) or not (
                        span.start <= node.lineno <= span.end):
                    continue
                kind = classify_blocking(node, fi.module, imports)
                if kind is None:
                    continue
                if kind == "wait" and isinstance(node.func, ast.Attribute) \
                        and ast.unparse(node.func.value) in held_refs:
                    continue  # cv.wait under its own condition releases it
                if fi.module.is_allowed(node.lineno, tag):
                    continue
                findings.append(Finding(
                    checker="lock-order", rule="blocking-under-lock",
                    path=fi.module.path, line=node.lineno, symbol=qual,
                    message=f"{kind} call "
                            f"`{ast.unparse(node)[:80]}` while holding "
                            f"{span.key}",
                    detail=f"{span.key}|{kind}|"
                           f"{ast.unparse(node)[:80]}"))
            # blocking reached through calls made inside the span
            for callee, site in cg.calls_in(qual):
                if not (span.start <= site.line <= span.end):
                    continue
                if fi.module.is_allowed(site.line, tag):
                    continue
                for bc in cg.reachable_blocking(callee.qual,
                                                allow_tag=tag):
                    if bc.kind == "wait" and any(
                            bc.site.text.startswith(r + ".wait")
                            for r in held_refs):
                        continue
                    findings.append(Finding(
                        checker="lock-order", rule="blocking-under-lock",
                        path=fi.module.path, line=site.line, symbol=qual,
                        message=f"{bc.kind} at {bc.site.path}:"
                                f"{bc.site.line} reachable while holding "
                                f"{span.key} via "
                                f"{' -> '.join(bc.chain[-3:])}",
                        detail=f"{span.key}|{bc.kind}|{bc.site.path}|"
                               f"{bc.site.caller}"))

    # dedupe (same fingerprint can arise via several chains)
    seen: set[str] = set()
    out = []
    for f in findings:
        if f.fingerprint not in seen:
            seen.add(f.fingerprint)
            out.append(f)
    return out
