"""Thread-hygiene checker: silently swallowed exceptions.

A ``except Exception: pass`` (or bare ``except:``) inside the
concurrency modules hides real failures — a background flusher or
monitor loop that dies silently looks exactly like a healthy idle one.
This rule flags any handler that catches ``Exception``/``BaseException``
(or everything) and whose body neither logs, re-raises, records, nor
returns a value — it just ``pass``es or ``continue``s.

Deliberate swallows (e.g. best-effort cleanup on shutdown) are audited
in-code:

    except Exception:   # repro-check: allow(swallow) -- shutdown path
        pass

``contextlib.suppress(...)`` is not flagged: writing it is already an
explicit, reviewable statement of intent.
"""
from __future__ import annotations

import ast

from ..findings import Finding
from ..loader import Project

DEFAULT_CONFIG = {
    "modules": ("storage", "durable", "aio", "fabric", "replication",
                "server", "faults"),
}

_BROAD = {"Exception", "BaseException"}


def _is_broad(handler: ast.ExceptHandler) -> bool:
    if handler.type is None:
        return True
    t = handler.type
    if isinstance(t, ast.Name):
        return t.id in _BROAD
    if isinstance(t, ast.Tuple):
        return any(isinstance(el, ast.Name) and el.id in _BROAD
                   for el in t.elts)
    return False


def _swallows(handler: ast.ExceptHandler) -> bool:
    """True when the handler body does nothing observable."""
    for stmt in handler.body:
        if isinstance(stmt, (ast.Pass, ast.Continue, ast.Break)):
            continue
        if isinstance(stmt, ast.Expr) and isinstance(
                stmt.value, ast.Constant):
            continue    # docstring-style no-op
        return False
    return True


def run(project: Project, config: dict | None = None) -> list[Finding]:
    cfg = dict(DEFAULT_CONFIG)
    if config:
        cfg.update(config)
    findings: list[Finding] = []
    tag = "swallow"
    for name in cfg["modules"]:
        mod = project.modules.get(name)
        if mod is None:
            continue
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if not (_is_broad(node) and _swallows(node)):
                continue
            if mod.is_allowed(node.lineno, tag):
                continue
            caught = (ast.unparse(node.type) if node.type is not None
                      else "<bare>")
            # locate the enclosing function for a stable fingerprint
            symbol = ""
            for fi in project.functions.values():
                if fi.module is mod and fi.node.lineno <= node.lineno <= (
                        fi.node.end_lineno or 0):
                    symbol = fi.qual
            findings.append(Finding(
                checker="thread-hygiene", rule="swallowed-exception",
                path=mod.path, line=node.lineno, symbol=symbol,
                message=f"`except {caught}` silently swallowed — log it, "
                        f"narrow it, or annotate "
                        f"`# repro-check: allow(swallow)`",
                detail=f"{symbol}|{caught}"))
    return findings
