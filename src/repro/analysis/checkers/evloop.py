"""Event-loop blocking checker.

The event-loop frontend (``repro.core.aio``) runs exactly one IO thread;
everything that thread executes must be non-blocking or the whole
frontend stalls.  This checker walks the call graph from the IO-thread
entry points of ``EventLoopFrontend`` to any blocking primitive.

Audited exceptions are annotated in-code:

    # repro-check: allow(blocking) -- <why this cannot actually block>

e.g. the memory-backend inline dispatch (``_execute`` from ``_on_read``,
which by construction cannot touch a WAL or a socket) and sends on
sockets already in non-blocking mode.

The entry-point list is configuration, not discovery: selector callbacks
are registered as data (``key.data``), which a static call graph cannot
follow, so the contract is stated explicitly here and pinned by the
``missing-entry`` rule — if a configured entry disappears from the
class, the checker fails rather than silently analyzing nothing.
"""
from __future__ import annotations

from ..callgraph import CallGraph
from ..findings import Finding
from ..loader import Project

DEFAULT_CONFIG = {
    "module": "aio",
    "cls": "EventLoopFrontend",
    # everything the selector loop runs on the IO thread
    "entries": ("_loop", "_accept", "_on_read", "_on_write", "_flush_ready",
                "_write_some", "_drain_done", "_close_conn", "_wake"),
    # the loop's own selector poll is the one sanctioned blocking point
    "allowed_kinds": (),
}


def run(project: Project, config: dict | None = None) -> list[Finding]:
    cfg = dict(DEFAULT_CONFIG)
    if config:
        cfg.update(config)
    cg = CallGraph(project)
    findings: list[Finding] = []

    cls_qual = f"{cfg['module']}.{cfg['cls']}"
    cls = project.classes.get(cls_qual)
    if cls is None:
        findings.append(Finding(
            checker="evloop-blocking", rule="missing-entry",
            path="", line=0, symbol=cls_qual,
            message=f"configured IO-thread class {cls_qual} not found",
            detail=f"class:{cls_qual}"))
        return findings

    for entry in cfg["entries"]:
        if entry not in cls.methods:
            findings.append(Finding(
                checker="evloop-blocking", rule="missing-entry",
                path=cls.module.path, line=cls.node.lineno,
                symbol=cls_qual,
                message=f"configured IO-thread entry point "
                        f"{cls_qual}.{entry} no longer exists — update "
                        f"the checker config to match the frontend",
                detail=f"entry:{cls_qual}.{entry}"))
            continue
        qual = cls.methods[entry].qual
        for bc in cg.reachable_blocking(qual, allow_tag="blocking"):
            if bc.kind in cfg["allowed_kinds"]:
                continue
            findings.append(Finding(
                checker="evloop-blocking", rule="io-thread-blocks",
                path=bc.site.path, line=bc.site.line,
                symbol=bc.site.caller,
                message=f"{bc.kind} call `{bc.site.text[:80]}` reachable "
                        f"on the IO thread via "
                        f"{' -> '.join(bc.chain[:4])}",
                detail=f"{entry}|{bc.kind}|{bc.site.path}|"
                       f"{bc.site.caller}|{bc.site.text[:60]}"))

    seen: set[str] = set()
    out = []
    for f in findings:
        if f.fingerprint not in seen:
            seen.add(f.fingerprint)
            out.append(f)
    return out
