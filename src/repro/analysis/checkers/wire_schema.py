"""Wire-schema drift checker.

The client (``client.py``/``transport.py``) and the server surface
(``api/schemas.py`` + ``api/v2.py`` routes + server-raised error codes)
are maintained by hand on both sides of the wire.  This checker parses
both and cross-checks them statically, so a server-side change the
client cannot handle fails `repro-check` instead of a production call:

``client-route-mismatch``
    a client ``_call``/``_request`` path that matches no registered
    route (method + template);

``client-field-unknown``
    a literal body key the route's request schema does not declare
    (the server ignores unknown keys — silently dropping client intent);

``client-missing-required``
    a required schema field (no default) absent from the client's
    literal body;

``error-code-drift``
    an error code the client branches on (retry policy, equality
    checks) that no server-side code path raises.

``probe-route-mismatch``
    a literal ``/api/...`` path used by an *internal* probe (the fabric
    router's fast-path classifiers, the health scatter-gather, the
    service launcher) that matches no registered route — the fabric
    would 404 its own monitoring;

``health-field-drift``
    a payload key a scatter-gather consumer reads (``x.get("k")`` /
    ``x["k"]``) that no producer function on that surface ever emits —
    renaming a health field silently turns a consumer read into
    ``None``.

All parsing is AST-level; nothing is imported.
"""
from __future__ import annotations

import ast
import re

from ..findings import Finding
from ..loader import Module, Project

DEFAULT_CONFIG = {
    "client_module": "client",
    "schemas_module": "api.schemas",
    "routes_modules": ("api.v2", "api.v1"),
    # modules scanned for server-raised codes: ApiError(status, code, ...),
    # error_payload(code, ...), HopaasError(code=...)
    "code_modules": None,        # None = every loaded module
    # codes produced outside the scanned sources (none today)
    "extra_codes": (),
    # modules whose literal "/api/..." strings are internal probes that
    # must match a registered route (trailing-slash prefixes exempt)
    "probe_modules": ("fabric", "aio", "service"),
    # scatter-gather surfaces: consumer key reads ⊆ producer key emits
    "health_surfaces": (
        {"name": "replication-status",
         "producers": ("replication.ReplicationHub.status",
                       "replication.ReplicationClient.status",
                       "fabric.FabricWorkerServer._replication_status",
                       "fabric.FabricWorkerServer._op_promote"),
         "consumers": ("fabric.ShardFabric._failover",)},
        {"name": "health-endpoint",
         "producers": ("server.HopaasServer.op_health",
                       "fabric.FabricWorkerServer.health_extra",
                       "fabric.FabricWorkerServer._replication_status"),
         "consumers": ("fabric.ShardFabric.health",)},
    ),
}


# ----------------------------------------------------------------------- #
# schema model
# ----------------------------------------------------------------------- #
def _schema_fields(mod: Module) -> dict[str, dict[str, dict]]:
    """class name -> {field name -> {"required": bool, "has_default": bool}}.

    Understands the repo idiom: ``FIELDS = (Field(...), ...)`` tuples,
    optionally concatenated with ``Other.FIELDS``.
    """
    classes: dict[str, dict[str, dict]] = {}
    pending: dict[str, ast.expr] = {}
    for node in mod.tree.body:
        if not isinstance(node, ast.ClassDef):
            continue
        fields_expr = None
        for item in node.body:
            if (isinstance(item, ast.Assign)
                    and any(isinstance(t, ast.Name) and t.id == "FIELDS"
                            for t in item.targets)):
                fields_expr = item.value
            elif (isinstance(item, ast.AnnAssign)
                  and isinstance(item.target, ast.Name)
                  and item.target.id == "FIELDS" and item.value):
                fields_expr = item.value
        base_names = [ast.unparse(b).split(".")[-1] for b in node.bases]
        if fields_expr is None:
            # inherits FIELDS unchanged
            for base in base_names:
                if base in classes:
                    classes[node.name] = dict(classes[base])
                    break
            else:
                classes[node.name] = {}
            continue
        pending[node.name] = fields_expr
        classes[node.name] = _eval_fields(fields_expr, classes)
    return classes


def _eval_fields(expr: ast.expr, classes: dict) -> dict[str, dict]:
    out: dict[str, dict] = {}
    if isinstance(expr, ast.BinOp) and isinstance(expr.op, ast.Add):
        out.update(_eval_fields(expr.left, classes))
        out.update(_eval_fields(expr.right, classes))
        return out
    if isinstance(expr, ast.Attribute) and expr.attr == "FIELDS":
        owner = ast.unparse(expr.value).split(".")[-1]
        return dict(classes.get(owner, {}))
    if isinstance(expr, (ast.Tuple, ast.List)):
        for el in expr.elts:
            out.update(_eval_fields(el, classes))
        return out
    if isinstance(expr, ast.Call):
        fn = expr.func
        name = (fn.attr if isinstance(fn, ast.Attribute)
                else fn.id if isinstance(fn, ast.Name) else "")
        if name == "Field" and expr.args and isinstance(
                expr.args[0], ast.Constant):
            kw = {k.arg: k.value for k in expr.keywords}
            required = (isinstance(kw.get("required"), ast.Constant)
                        and kw["required"].value is True)
            has_default = "default" in kw
            out[expr.args[0].value] = {"required": required,
                                       "has_default": has_default}
    return out


# ----------------------------------------------------------------------- #
# route model
# ----------------------------------------------------------------------- #
def _routes(mod: Module) -> list[dict]:
    """Every ``Route(...)`` literal: method, template, schema name."""
    out = []
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id == "Route"
                and len(node.args) >= 2
                and isinstance(node.args[0], ast.Constant)
                and isinstance(node.args[1], ast.Constant)):
            continue
        schema = None
        for kw in node.keywords:
            if kw.arg == "request_schema":
                schema = ast.unparse(kw.value).split(".")[-1]
        out.append({"method": node.args[0].value.upper(),
                    "template": node.args[1].value,
                    "schema": schema,
                    "line": node.lineno,
                    "path": mod.path})
    return out


def _seg_match(client_seg: str, tmpl_seg: str) -> bool:
    """One path segment: client ``{x}`` holes (f-string interpolations)
    and template ``{param}`` holes both match anything; the literal
    fragments around the holes must line up.  ``trials{x}`` matches the
    literal ``trials`` — the hole is a prebuilt query string."""
    c_re = ".*".join(re.escape(p) for p in client_seg.split("{x}"))
    t_concrete = re.sub(r"\{\w+\}", "\x00", tmpl_seg)
    if re.fullmatch(c_re, t_concrete):
        return True
    t_re = ".*".join(re.escape(p)
                     for p in re.split(r"\{\w+\}", tmpl_seg))
    c_concrete = client_seg.replace("{x}", "\x00")
    return re.fullmatch(t_re, c_concrete) is not None


def _path_match(client_path: str, template: str) -> bool:
    """Client path (with ``{x}`` interpolation holes, possibly a glued
    ``?query``) vs a route template, segment by segment."""
    c = client_path.partition("?")[0]
    c_segs = c.strip("/").split("/")
    t_segs = template.strip("/").split("/")
    if len(c_segs) != len(t_segs):
        return False
    return all(_seg_match(cs, ts) for cs, ts in zip(c_segs, t_segs))


# ----------------------------------------------------------------------- #
# client model
# ----------------------------------------------------------------------- #
def _client_calls(mod: Module) -> list[dict]:
    """Every ``self._call(method, path, body?)`` in the client."""
    out = []
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in ("_call", "_request")
                and len(node.args) >= 2):
            continue
        method_node, path_node = node.args[0], node.args[1]
        if not isinstance(method_node, ast.Constant):
            continue
        path = _path_text(path_node)
        if path is None:
            continue
        body_keys: list[str] | None = None
        if len(node.args) >= 3 and isinstance(node.args[2], ast.Dict):
            body_keys = [k.value for k in node.args[2].keys
                         if isinstance(k, ast.Constant)]
        elif len(node.args) >= 3 and isinstance(node.args[2],
                                                ast.Constant) \
                and node.args[2].value is None:
            body_keys = []
        out.append({"method": method_node.value.upper(), "path": path,
                    "body_keys": body_keys, "line": node.lineno})
    return out


def _path_text(node: ast.expr) -> str | None:
    """Constant or f-string path -> template-ish text with {x} holes."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    if isinstance(node, ast.JoinedStr):
        parts = []
        for v in node.values:
            if isinstance(v, ast.Constant):
                parts.append(str(v.value))
            else:
                parts.append("{x}")
        return "".join(parts)
    return None


def _client_codes(mod: Module) -> list[tuple[str, int]]:
    """Error-code strings the client logic branches on."""
    out: list[tuple[str, int]] = []
    for node in ast.walk(mod.tree):
        # e.code ==/!=/in "..." comparisons
        if isinstance(node, ast.Compare):
            sides = [node.left] + list(node.comparators)
            involves_code = any(
                isinstance(s, ast.Attribute) and s.attr == "code"
                for s in sides)
            if involves_code:
                for s in sides:
                    if isinstance(s, ast.Constant) and isinstance(
                            s.value, str):
                        out.append((s.value, node.lineno))
                    elif isinstance(s, (ast.Tuple, ast.List)):
                        out.extend((el.value, node.lineno)
                                   for el in s.elts
                                   if isinstance(el, ast.Constant)
                                   and isinstance(el.value, str))
        # RetryPolicy retry_codes defaults / assignments
        if isinstance(node, (ast.Assign, ast.AnnAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            names = {t.id for t in targets if isinstance(t, ast.Name)}
            names |= {t.attr for t in targets
                      if isinstance(t, ast.Attribute)}
            if "retry_codes" in names and node.value is not None:
                for el in ast.walk(node.value):
                    if isinstance(el, ast.Constant) and isinstance(
                            el.value, str):
                        out.append((el.value, node.lineno))
    return out


def _probe_paths(mod: Module) -> list[tuple[str, int]]:
    """Literal ``/api/...`` strings used as internal probe paths.
    Trailing-slash values are prefix constants (``startswith`` guards,
    URL builders), not full paths — those are exempt.  Fragments inside
    an f-string are judged as the whole joined text, not per part."""
    joined_parts: set[int] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.JoinedStr):
            joined_parts.update(id(v) for v in node.values)
    out: list[tuple[str, int]] = []
    for node in ast.walk(mod.tree):
        if id(node) in joined_parts:
            continue
        if not isinstance(node, (ast.Constant, ast.JoinedStr)):
            continue
        text = _path_text(node)
        if (text and text.startswith("/api/")
                and not text.partition("?")[0].endswith("/")):
            out.append((text, node.lineno))
    return out


def _produced_keys(project: Project, quals: tuple) -> set[str]:
    """String keys a producer function can emit: dict-literal keys plus
    ``out["key"] = ...`` subscript stores.  ``update(other.status())``
    composition is covered by listing every producer on the surface."""
    keys: set[str] = set()
    for qual in quals:
        fn = project.functions.get(qual)
        if fn is None:
            continue
        for node in ast.walk(fn.node):
            if isinstance(node, ast.Dict):
                keys.update(k.value for k in node.keys
                            if isinstance(k, ast.Constant)
                            and isinstance(k.value, str))
            elif (isinstance(node, ast.Subscript)
                  and isinstance(node.ctx, (ast.Store, ast.Del))
                  and isinstance(node.slice, ast.Constant)
                  and isinstance(node.slice.value, str)):
                keys.add(node.slice.value)
    return keys


def _consumed_keys(project: Project, quals: tuple
                   ) -> list[tuple[str, int, str, Module]]:
    """(key, line, consumer qual, module) for every constant-string
    ``x.get("k")`` call or ``x["k"]`` load in the consumer functions."""
    out: list[tuple[str, int, str, Module]] = []
    for qual in quals:
        fn = project.functions.get(qual)
        if fn is None:
            continue
        for node in ast.walk(fn.node):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "get" and node.args
                    and isinstance(node.args[0], ast.Constant)
                    and isinstance(node.args[0].value, str)):
                out.append((node.args[0].value, node.lineno, qual,
                            fn.module))
            elif (isinstance(node, ast.Subscript)
                  and isinstance(node.ctx, ast.Load)
                  and isinstance(node.slice, ast.Constant)
                  and isinstance(node.slice.value, str)):
                out.append((node.slice.value, node.lineno, qual,
                            fn.module))
    return out


def _server_codes(project: Project, modules: tuple | None) -> set[str]:
    codes: set[str] = set()
    for mod in project.modules.values():
        if modules is not None and mod.name not in modules:
            continue
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            name = (fn.attr if isinstance(fn, ast.Attribute)
                    else fn.id if isinstance(fn, ast.Name) else "")
            if name == "ApiError" and len(node.args) >= 2 and isinstance(
                    node.args[1], ast.Constant):
                codes.add(node.args[1].value)
            elif name == "error_payload" and node.args and isinstance(
                    node.args[0], ast.Constant):
                codes.add(node.args[0].value)
            elif name in ("HopaasError",):
                for kw in node.keywords:
                    if kw.arg == "code" and isinstance(
                            kw.value, ast.Constant) and isinstance(
                            kw.value.value, str):
                        codes.add(kw.value.value)
    return codes


# ----------------------------------------------------------------------- #
def run(project: Project, config: dict | None = None) -> list[Finding]:
    cfg = dict(DEFAULT_CONFIG)
    if config:
        cfg.update(config)
    findings: list[Finding] = []

    client = project.modules.get(cfg["client_module"])
    schemas_mod = project.modules.get(cfg["schemas_module"])
    if client is None or schemas_mod is None:
        findings.append(Finding(
            checker="wire-schema", rule="missing-module", path="", line=0,
            symbol="",
            message=f"client/schemas modules not found "
                    f"({cfg['client_module']!r}, "
                    f"{cfg['schemas_module']!r})",
            detail="missing-module"))
        return findings

    schemas = _schema_fields(schemas_mod)
    routes: list[dict] = []
    for name in cfg["routes_modules"]:
        mod = project.modules.get(name)
        if mod is not None:
            routes.extend(_routes(mod))
    for call in _client_calls(client):
        matches = [r for r in routes
                   if r["method"] == call["method"]
                   and _path_match(call["path"], r["template"])]
        if not matches:
            if client.is_allowed(call["line"], "wire"):
                continue
            findings.append(Finding(
                checker="wire-schema", rule="client-route-mismatch",
                path=client.path, line=call["line"], symbol="",
                message=f"client calls {call['method']} "
                        f"{call['path']!r} but no route matches",
                detail=f"{call['method']}|{call['path']}"))
            continue
        route = matches[0]
        schema_name = route["schema"]
        if schema_name is None or call["body_keys"] is None:
            continue
        fields = schemas.get(schema_name)
        if fields is None:
            continue
        for key in call["body_keys"]:
            if key not in fields:
                if client.is_allowed(call["line"], "wire"):
                    continue
                findings.append(Finding(
                    checker="wire-schema", rule="client-field-unknown",
                    path=client.path, line=call["line"], symbol="",
                    message=f"client sends field {key!r} to "
                            f"{route['method']} {route['template']} but "
                            f"schema {schema_name} does not declare it "
                            f"(server silently drops it)",
                    detail=f"{route['template']}|{key}"))
        for name, spec in fields.items():
            if spec["required"] and not spec["has_default"] \
                    and name not in call["body_keys"]:
                if client.is_allowed(call["line"], "wire"):
                    continue
                findings.append(Finding(
                    checker="wire-schema", rule="client-missing-required",
                    path=client.path, line=call["line"], symbol="",
                    message=f"client body for {route['method']} "
                            f"{route['template']} omits required field "
                            f"{name!r} of schema {schema_name}",
                    detail=f"{route['template']}|missing|{name}"))

    for mod_name in cfg["probe_modules"]:
        mod = project.modules.get(mod_name)
        if mod is None:
            continue
        for path, line in _probe_paths(mod):
            if any(_path_match(path, r["template"]) for r in routes):
                continue
            if mod.is_allowed(line, "wire"):
                continue
            findings.append(Finding(
                checker="wire-schema", rule="probe-route-mismatch",
                path=mod.path, line=line, symbol="",
                message=f"internal probe uses path {path!r} but no "
                        f"registered route matches it",
                detail=f"probe|{mod_name}|{path}"))

    for surface in cfg["health_surfaces"]:
        produced = _produced_keys(project, surface["producers"])
        if not produced:
            # every producer renamed/moved: the surface silently reads
            # as fully drifted — report the coverage loss, not N keys
            findings.append(Finding(
                checker="wire-schema", rule="health-field-drift",
                path="", line=0, symbol=surface["name"],
                message=f"health surface {surface['name']!r}: no "
                        f"producer function found "
                        f"({', '.join(surface['producers'])})",
                detail=f"surface-empty|{surface['name']}"))
            continue
        for key, line, qual, mod in _consumed_keys(
                project, surface["consumers"]):
            if key in produced:
                continue
            if mod.is_allowed(line, "wire"):
                continue
            findings.append(Finding(
                checker="wire-schema", rule="health-field-drift",
                path=mod.path, line=line, symbol=qual,
                message=f"{qual} reads payload key {key!r} but no "
                        f"producer on the {surface['name']!r} surface "
                        f"emits it",
                detail=f"{surface['name']}|{qual}|{key}"))

    server_codes = _server_codes(project, cfg["code_modules"])
    server_codes.update(cfg["extra_codes"])
    for code, line in _client_codes(client):
        if code not in server_codes:
            if client.is_allowed(line, "wire"):
                continue
            findings.append(Finding(
                checker="wire-schema", rule="error-code-drift",
                path=client.path, line=line, symbol="",
                message=f"client handles error code {code!r} but no "
                        f"server path raises it",
                detail=f"code|{code}"))

    seen: set[str] = set()
    out = []
    for f in findings:
        if f.fingerprint not in seen:
            seen.add(f.fingerprint)
            out.append(f)
    return out
