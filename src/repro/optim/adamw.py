"""AdamW with global-norm clipping, built here (no optax in the image).

Optimizer state (m, v) inherits the parameter's logical axes, so ZeRO-1/3
sharding falls out of the same ``repro.dist.sharding`` rulebook: with
``embed -> data``, the fp32 master moments are FSDP-sharded exactly like
the weights and no replica ever materializes the full optimizer state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float | Callable[[jax.Array], jax.Array] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # moments dtype — fp32 masters by default; bf16 halves opt-state HBM
    moment_dtype: Any = jnp.float32


def adamw_init(params: Any, cfg: AdamWConfig, abstract: bool = False) -> dict:
    """-> {"m": tree, "v": tree, "step": scalar}."""
    def zeros_like(p):
        if abstract:
            return jax.ShapeDtypeStruct(p.shape, cfg.moment_dtype)
        return jnp.zeros(p.shape, cfg.moment_dtype)

    step = (jax.ShapeDtypeStruct((), jnp.int32) if abstract
            else jnp.zeros((), jnp.int32))
    return {"m": jax.tree.map(zeros_like, params),
            "v": jax.tree.map(zeros_like, params),
            "step": step}


def opt_state_specs(param_specs: Any) -> dict:
    """Logical axes for the optimizer state tree (mirrors the params)."""
    return {"m": param_specs, "v": param_specs, "step": ()}


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads: Any, opt_state: dict, params: Any,
                 cfg: AdamWConfig) -> tuple[Any, dict, dict]:
    """-> (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = cfg.lr(step) if callable(cfg.lr) else jnp.float32(cfg.lr)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip else jnp.float32(1.0)

    b1, b2 = jnp.float32(cfg.b1), jnp.float32(cfg.b2)
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat, vhat = m_new / c1, v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                       # no decay on norms/biases/scalars
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(cfg.moment_dtype),
                v_new.astype(cfg.moment_dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
