from .adamw import (AdamWConfig, adamw_init, adamw_update, global_norm,
                    opt_state_specs)
from .schedules import constant, cosine_warmup, linear_warmup
from .compression import compress_int8, decompress_int8

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "opt_state_specs", "cosine_warmup", "linear_warmup", "constant",
           "compress_int8", "decompress_int8"]
