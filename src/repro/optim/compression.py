"""Gradient compression for the cross-pod (DCN) all-reduce.

int8 block-quantization: per-block max-abs scale (block = trailing dim),
~4x fewer bytes on the slow inter-pod links.  Error feedback (residual
carried to the next step) keeps the quantization noise unbiased over time.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def compress_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """-> (int8 payload, fp32 per-row scale). x: any shape."""
    xf = x.astype(jnp.float32)
    flat = xf.reshape(-1, x.shape[-1]) if x.ndim > 1 else xf.reshape(1, -1)
    scale = jnp.max(jnp.abs(flat), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    if x.ndim > 1:
        return q.reshape(x.shape), scale.reshape(*x.shape[:-1], 1)
    return q.reshape(x.shape), scale.reshape(())


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads: Any) -> Any:
    return jax.tree.map(lambda g: compress_int8(g)
                        if g.ndim >= 2 else (g, None), grads,
                        is_leaf=lambda x: isinstance(x, jax.Array))


def decompress_tree(ctree: Any) -> Any:
    def dec(pair):
        q, s = pair
        return decompress_int8(q, s) if s is not None else q
    return jax.tree.map(dec, ctree, is_leaf=lambda x: isinstance(x, tuple))


def error_feedback_compress(g: jax.Array, residual: jax.Array
                            ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Compress (g + residual); return (q, scale, new_residual)."""
    target = g.astype(jnp.float32) + residual
    q, scale = compress_int8(target)
    recon = decompress_int8(q, scale)
    return q, scale, target - recon
