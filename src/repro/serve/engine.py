"""Serving: prefill + decode steps and a batched greedy engine.

``make_decode_step`` is what the ``decode_32k`` / ``long_500k`` dry-run
cells lower: one new token against a KV/SSM cache of ``seq_len``.  For
attention archs the cache is a ring of ``max_len`` (window-bounded for
SWA archs — mixtral's long_500k cache is min(seq, window)); for SSM /
hybrid archs the state is O(1) and ``long_500k`` costs the same HBM as
``decode_32k`` — the reason those archs keep the long cells.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.models.config import ModelConfig


def cache_max_len(cfg: ModelConfig, seq_len: int) -> int:
    """Physical KV length: window-bounded for SWA archs."""
    if cfg.sliding_window is not None:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def make_prefill_step(cfg: ModelConfig) -> Callable:
    """prefill(params, batch) -> logits — full-sequence forward (the
    prefill_32k dry-run cell; cache writes are folded into decode here)."""
    def prefill(params: dict, batch: dict) -> jax.Array:
        logits, _ = transformer.forward(params, cfg, batch)
        return logits
    return prefill


def make_decode_step(cfg: ModelConfig) -> Callable:
    """decode(params, cache, tokens, cache_len) -> (logits, new_cache)."""
    def decode(params: dict, cache: dict, tokens: jax.Array,
               cache_len: jax.Array):
        return transformer.decode_step(params, cfg, cache, tokens, cache_len)
    return decode


@dataclasses.dataclass
class ServeEngine:
    """Batched greedy decoding for the end-to-end serving example."""
    cfg: ModelConfig
    params: Any
    max_len: int = 256

    def __post_init__(self):
        assert self.cfg.supports_decode, f"{self.cfg.name} is encoder-only"
        self._decode = jax.jit(make_decode_step(self.cfg))

    def generate(self, prompts: np.ndarray, n_new: int) -> np.ndarray:
        """prompts: (B, P) int32 -> (B, n_new) greedy continuations.
        Prefill is runs through the decode path token-by-token (exact,
        cache-consistent); production prefill uses the fused forward."""
        B, P = prompts.shape
        cache, _ = transformer.init_cache_arrays(
            self.cfg, B, cache_max_len(self.cfg, self.max_len))
        logits = None
        for t in range(P):
            logits, cache = self._decode(
                self.params, cache, jnp.asarray(prompts[:, t: t + 1]),
                jnp.int32(t))
        out = []
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        for t in range(P, P + n_new):
            out.append(np.asarray(tok)[:, 0])
            if len(out) == n_new:
                break
            logits, cache = self._decode(self.params, cache, tok, jnp.int32(t))
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return np.stack(out, axis=1)
