"""pixtral-12b [vlm] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072.  Pixtral-ViT frontend is a STUB (precomputed patch
embeddings, 1024-dim as in the Pixtral vision encoder) + a trainable
adapter; backbone is the mistral-nemo transformer.
[hf:mistralai/Pixtral-12B-2409]"""
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.registry import register

N_PATCHES = 256          # stub image: 16x16 patch grid per image


def full() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", family="vlm",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=131072, head_dim=128,
        frontend="vision", frontend_dim=1024, rope_theta=1_000_000_000.0)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16,
        frontend="vision", frontend_dim=32, dtype=jnp.float32)


register("pixtral-12b", full, smoke)
