"""Assigned-architecture configs.  Importing this package registers every
arch (full + smoke variants) into ``repro.models.registry``."""
from . import (deepseek_67b, deepseek_7b, hubert_xlarge, mixtral_8x7b,
               pixtral_12b, qwen15_32b, qwen2_moe_a27b, qwen3_32b, rwkv6_7b,
               zamba2_12b)  # noqa: F401

ARCHS = ["qwen1.5-32b", "deepseek-67b", "deepseek-7b", "qwen3-32b",
         "zamba2-1.2b", "pixtral-12b", "qwen2-moe-a2.7b", "mixtral-8x7b",
         "rwkv6-7b", "hubert-xlarge"]
