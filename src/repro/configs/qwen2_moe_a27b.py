"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (kv=16) d_ff=1408
(per-expert) vocab=151936, MoE 60 routed top-4 + 4 shared experts
(shared width 4x1408 = 5632).  [hf:Qwen/Qwen1.5-MoE-A2.7B]"""
import jax.numpy as jnp

from repro.models.config import ModelConfig, MoEConfig
from repro.models.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b", family="moe",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab_size=151936, head_dim=128,
        qkv_bias=True, rope_theta=1_000_000.0,
        moe=MoEConfig(n_experts=60, top_k=4, d_expert=1408, n_shared=4,
                      router_norm_topk=True))


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=96, vocab_size=256, head_dim=16, qkv_bias=True,
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=96, n_shared=2,
                      router_norm_topk=True, dense_dispatch=True),
        dtype=jnp.float32)


register("qwen2-moe-a2.7b", full, smoke)
