"""deepseek-7b [dense] — 30L d_model=4096 32H (GQA kv=32, i.e. MHA)
d_ff=11008 vocab=102400, llama-arch.  [arXiv:2401.02954]"""
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b", family="dense",
        n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32,
        d_ff=11008, vocab_size=102400, head_dim=128,
        rope_theta=10_000.0)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256, head_dim=16, dtype=jnp.float32)


register("deepseek-7b", full, smoke)
