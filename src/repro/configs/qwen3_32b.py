"""qwen3-32b [dense] — 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936, qk_norm.  [hf:Qwen/Qwen3-8B family]

Qwen3 decouples head_dim (128) from d_model/n_heads and RMS-normalizes
q and k per head before RoPE."""
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8,
        d_ff=25600, vocab_size=151936, head_dim=128,
        qk_norm=True, rope_theta=1_000_000.0)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16,
        qk_norm=True, dtype=jnp.float32)


register("qwen3-32b", full, smoke)
