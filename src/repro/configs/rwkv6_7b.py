"""rwkv6-7b "Finch" [ssm] — 32L d_model=4096 (attention-free) d_ff=14336
vocab=65536, data-dependent per-channel decay.  [arXiv:2404.05892]

Attention-free linear recurrence -> O(1) decode state -> runs long_500k."""
import jax.numpy as jnp

from repro.models.config import ModelConfig, RWKVConfig
from repro.models.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b", family="ssm",
        n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64,
        d_ff=14336, vocab_size=65536, head_dim=64,
        block="rwkv6", rwkv=RWKVConfig(head_dim=64, decay_lora=64))


def smoke() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256, head_dim=16,
        block="rwkv6", rwkv=RWKVConfig(head_dim=16, decay_lora=8),
        dtype=jnp.float32)


register("rwkv6-7b", full, smoke)
