"""zamba2-1.2b [hybrid] — 38L d_model=2048 32H (kv=32) d_ff=8192
vocab=32000, ssm_state=64.  Mamba2 backbone + weight-tied shared attention
block every ``shared_attn_period`` layers.  [arXiv:2411.15242]

Runs long_500k: the Mamba2 state is O(1) per layer and the shared
attention blocks' KV caches shard over the model axis."""
import jax.numpy as jnp

from repro.models.config import ModelConfig, SSMConfig
from repro.models.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=32000, head_dim=64,
        block="zamba2", shared_attn_period=6,
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=64))


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b-smoke", family="hybrid",
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256, head_dim=16,
        block="zamba2", shared_attn_period=2,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=8),
        dtype=jnp.float32)


register("zamba2-1.2b", full, smoke)
