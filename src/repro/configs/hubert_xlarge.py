"""hubert-xlarge [audio] — 48L d_model=1280 16H (kv=16) d_ff=5120
vocab=504 (k-means cluster targets), encoder-only, same arch as
wav2vec2.  [arXiv:2106.07447]

Frontend is a STUB: precomputed conv-feature frames (512-dim) enter a
trainable projection.  Encoder-only -> no decode shapes; objective is
masked-frame cluster prediction (CE over 504 targets on masked frames).
vocab=504 % 16 != 0 -> LM head replicates (divisibility fallback)."""
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", family="audio",
        n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
        d_ff=5120, vocab_size=504, head_dim=80,
        encoder_only=True, frontend="audio", frontend_dim=512,
        glu=False, act="gelu")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=32, head_dim=16,
        encoder_only=True, frontend="audio", frontend_dim=24,
        glu=False, act="gelu", dtype=jnp.float32)


register("hubert-xlarge", full, smoke)
