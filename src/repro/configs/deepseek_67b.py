"""deepseek-67b [dense] — 95L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=102400, llama-arch.  [arXiv:2401.02954]"""
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b", family="dense",
        n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=22016, vocab_size=102400, head_dim=128,
        rope_theta=10_000.0)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b-smoke", family="dense",
        n_layers=3, d_model=64, n_heads=8, n_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16, dtype=jnp.float32)


register("deepseek-67b", full, smoke)
