"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
(per-expert) vocab=32000, 8 experts top-2, sliding-window attention
(4096).  [arXiv:2401.04088]

SWA makes attention sub-quadratic -> runs long_500k with a window-bounded
KV cache."""
import jax.numpy as jnp

from repro.models.config import ModelConfig, MoEConfig
from repro.models.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=32000, head_dim=128,
        sliding_window=4096, rope_theta=1_000_000.0,
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=14336,
                      router_norm_topk=True))


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16, sliding_window=16,
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=128,
                      router_norm_topk=True, dense_dispatch=True),
        dtype=jnp.float32)


register("mixtral-8x7b", full, smoke)
