"""PR 2 claim — ask latency is independent of history length.

Measures sampler ``suggest`` / ``suggest_batch`` latency against trial
histories of increasing length, in three modes:

  * ``legacy``  — the pre-PR ask path: the observation matrix is rebuilt
                  from scratch with per-trial scalar featurization
                  (``Param.to_unit`` in a Python loop, per-dim math.log);
  * ``scratch`` — from-scratch rebuild through the vectorized codec
                  (what direct sampler users get today);
  * ``cached``  — the service ask path: the incremental
                  ``ObservationCache`` (O(1) sync, pre-padded buffers).

Emits ``BENCH_ask_latency.json``.  Acceptance: TPE cached at the longest
history >= 5x faster than legacy, and cached latency near-flat (within
2x) from 1k to 5k trials.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core.obs_cache import ObservationCache
from repro.core.samplers.base import Sampler
from repro.core.samplers.gp import GPSampler
from repro.core.samplers.tpe import TPESampler
from repro.core.space import SearchSpace
from repro.core.storage import InMemoryStorage
from repro.core.types import Direction, StudyConfig, TrialState

PROPS = {"lr": {"type": "loguniform", "low": 1e-5, "high": 1e-1},
         "wd": {"type": "loguniform", "low": 1e-6, "high": 1e-2},
         "width": {"type": "int", "low": 32, "high": 1024},
         "act": {"type": "categorical", "choices": ["relu", "gelu", "silu"]},
         "dropout": {"type": "uniform", "low": 0.0, "high": 0.5}}


def _legacy_observations(space, trials, direction, cache=None):
    """The seed implementation of ``Sampler.observations``: one Python
    featurization call per trial, one scalar ``to_unit`` per dim."""
    done = [t for t in trials
            if t.state == TrialState.COMPLETED and t.value is not None]
    if not done:
        return np.zeros((0, space.dim)), np.zeros((0,))
    X = np.stack([
        np.array([p.to_unit(t.params[p.name]) for p in space.searchable],
                 dtype=np.float64)
        for t in done])
    sign = 1.0 if direction == Direction.MINIMIZE else -1.0
    y = np.array([sign * t.value for t in done], dtype=np.float64)
    return X, y


class _LegacyTPE(TPESampler):
    observations = staticmethod(_legacy_observations)


class _LegacyGP(GPSampler):
    observations = staticmethod(_legacy_observations)


def _build_history(space, n, seed=0):
    cfg = StudyConfig(name=f"bench-{n}-{seed}", properties=PROPS)
    storage = InMemoryStorage()
    study, _ = storage.get_or_create_study(cfg)
    rng = np.random.default_rng(seed)
    for i in range(n):
        t = storage.add_trial(study.key, space.sample_uniform(rng), None, None)
        storage.update_trial(t.uid, value=float(rng.uniform(0, 10)),
                             state=TrialState.COMPLETED, lease_deadline=None)
    cache = ObservationCache(space, cfg.direction)
    cache.sync(storage, study.key)
    return study, cache


def _time_ask(sampler, space, trials, rng, batch, cache, repeats=7):
    def ask():
        if batch == 1:
            sampler.suggest(space, trials, Direction.MINIMIZE, rng,
                            cache=cache)
        else:
            sampler.suggest_batch(space, trials, Direction.MINIMIZE, rng,
                                  batch, cache=cache)
    ask()                                   # warm-up (jit compile)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        ask()
        times.append(time.perf_counter() - t0)
    return float(np.median(times)) * 1e3    # ms


def run(smoke: bool = False) -> list[dict]:
    histories = (100, 500) if smoke else (100, 1000, 5000)
    space = SearchSpace.from_properties(PROPS)
    # liar="none" keeps the historical single-fused-batch ask path; the
    # constant-liar chunked batch is bench_parallel_ask's subject
    variants = {
        "tpe": (TPESampler, _LegacyTPE,
                {"n_startup_trials": 10, "liar": "none"}, (1, 16)),
        "gp": (GPSampler, _LegacyGP,
               {"n_startup_trials": 8, "liar": "none"}, (1,)),
    }
    rows = []
    for name, (cls, legacy_cls, kw, batches) in variants.items():
        for n in histories:
            study, cache = _build_history(space, n)
            for batch in batches:
                timings = {}
                for mode in ("legacy", "scratch", "cached"):
                    sampler = (legacy_cls if mode == "legacy" else cls)(**kw)
                    timings[mode] = _time_ask(
                        sampler, space, study.trials,
                        np.random.default_rng(1), batch,
                        cache if mode == "cached" else None)
                rows.append({
                    "sampler": name, "history": n, "batch": batch,
                    "legacy_ms": round(timings["legacy"], 3),
                    "scratch_ms": round(timings["scratch"], 3),
                    "cached_ms": round(timings["cached"], 3),
                    "speedup_vs_legacy": round(
                        timings["legacy"] / max(timings["cached"], 1e-9), 2),
                })
    out_dir = "experiments/benchmarks"
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "BENCH_ask_latency.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows
