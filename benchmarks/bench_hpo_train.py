"""End-to-end: HOPAAS driving real JAX training (the paper's actual use).

A small TPE study over (lr, weight_decay) of a reduced deepseek-7b,
with median pruning via the trainer's ``should_prune`` hook.  Shows the
best-found loss beats the median trial — the service is steering.

Columns: trials, pruned, median_loss, best_loss, best_lr.
"""
from __future__ import annotations

import numpy as np

from repro.core.auth import TokenManager
from repro.core.client import Client, Study, suggestions
from repro.core.server import HopaasServer
from repro.core.transport import DirectTransport
from repro.models import registry
from repro.train.trainer import hopaas_objective


def run(n_trials: int = 10, steps: int = 40) -> list[dict]:
    mcfg = registry.get_config("deepseek-7b", smoke=True)
    objective = hopaas_objective(mcfg, total_steps=steps, global_batch=8,
                                 seq_len=32, report_every=10)
    server = HopaasServer(tokens=TokenManager(), seed=3)
    tok = server.tokens.issue("bench")
    client = Client(DirectTransport(server), tok)
    study = Study(name="hpo-train",
                  properties={"lr": suggestions.loguniform(1e-5, 3e-2),
                              "weight_decay": suggestions.loguniform(1e-4, 0.3)},
                  sampler={"name": "tpe"},
                  pruner={"name": "median", "n_warmup_steps": 10},
                  client=client)
    losses, n_pruned, best, best_lr = [], 0, float("inf"), None
    for _ in range(n_trials):
        trial = study.ask()
        value = objective(trial.params, trial.should_prune)
        if trial.pruned:
            n_pruned += 1
            study.tell(trial, value=value, state="pruned")
            continue
        study.tell(trial, value=value)
        losses.append(value)
        if value < best:
            best, best_lr = value, trial.params["lr"]
    return [{"trials": n_trials, "pruned": n_pruned,
             "median_loss": round(float(np.median(losses)), 4),
             "best_loss": round(best, 4),
             "best_lr": None if best_lr is None else round(best_lr, 6)}]
