"""PR 6 — multi-process shard fabric: worker-count scaling.

PR 5 made one process fast; the GIL caps it there.  The fabric spreads
study shards over N worker processes behind the consistent-hash router
(``repro.core.fabric``), so ask/tell throughput should scale with
cores.  Two scenarios, emitted together as ``BENCH_fabric.json``:

* ``fabric-router`` — N concurrent keep-alive clients hammering
  ask/tell pairs through the router's byte-level proxy, for 1/2/4
  worker processes.  ``workers=1`` runs the fabric's inline mode (no
  children, no proxy hop) — it must match PR 5's evloop numbers in
  ``BENCH_transport``.
* ``fabric-direct`` — the same load sent straight to the per-worker
  data ports (``_transport_loadgen --targets``), with every client
  pinned to the worker that owns its study: the router hop removed,
  the upper bound for proxy overhead.

Acceptance (ISSUE 6): on a >= 4-core box, 4-worker router throughput
>= 2.5x 1-worker.  Every row records ``cores`` — on smaller hosts the
workers time-share the same cores and the ratio compresses toward 1x;
the honest signal there is that the fabric adds little overhead, not
that it scales.

Columns: scenario, workers, clients, requests, wall_s, pairs_per_s,
p50_ms, p99_ms, cores.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from repro.core.client import Client, Study, suggestions
from repro.core.fabric import ShardFabric
from repro.core.transport import HttpTransport

_SPACE = {"x": suggestions.uniform(0.0, 1.0)}
_LOADGEN = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "_transport_loadgen.py")


def _row(scenario: str, workers: int, clients: int, requests: int,
         wall: float, pairs: int, lats_ms: list[float]) -> dict:
    lats = sorted(lats_ms)
    return {"scenario": scenario, "workers": workers, "clients": clients,
            "requests": requests, "wall_s": round(wall, 3),
            "pairs_per_s": round(pairs / wall, 1),
            "p50_ms": round(lats[len(lats) // 2], 2),
            "p99_ms": round(lats[min(len(lats) - 1,
                                     int(len(lats) * 0.99))], 2),
            "cores": os.cpu_count()}


def _load(token: str, keys: list[str], *, n_clients: int,
          pairs_per_client: int, host: str | None = None,
          port: int | None = None,
          targets: list[tuple[str, int]] | None = None
          ) -> tuple[float, list[float]]:
    """Drive the out-of-process load generators (see bench_transport) at
    either one frontend (host/port) or the per-worker ports (targets)."""
    n_procs = 2 if n_clients > 1 else 1
    split = [n_clients // n_procs + (1 if i < n_clients % n_procs else 0)
             for i in range(n_procs)]
    offsets = [sum(split[:i]) for i in range(n_procs)]
    base = [sys.executable, _LOADGEN, "--token", token,
            "--keys", ",".join(keys)]
    if targets is not None:
        base += ["--targets", ",".join(f"{h}:{p}" for h, p in targets)]
    else:
        base += ["--host", str(host), "--port", str(port)]
    procs = []
    for count, offset in zip(split, offsets):
        procs.append(subprocess.Popen(
            base + ["--clients", str(count),
                    "--pairs", str(pairs_per_client),
                    "--offset", str(offset)],
            stdin=subprocess.PIPE, stdout=subprocess.PIPE, text=True))
    try:
        for p in procs:                      # connection-setup barrier
            line = p.stdout.readline().strip()
            if line != "READY":
                raise RuntimeError(f"load generator failed: {line!r}")
        t0 = time.time()
        for p in procs:
            p.stdin.write("GO\n")
            p.stdin.flush()
        results = []
        for p in procs:
            out = json.loads(p.stdout.readline())
            if "errors" in out:
                raise RuntimeError(f"load generator errors: {out['errors']}")
            results.append(out)
        wall = time.time() - t0
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
            p.wait()
    return wall, [x for r in results for x in r["lat_ms"]]


def _aligned_keys(fab: ShardFabric, client: Client,
                  per_worker: int) -> list[str]:
    """Create studies until every worker owns ``per_worker`` of them,
    then interleave so ``keys[j]`` is owned by worker ``j % N`` — the
    alignment ``--targets`` needs to pin each load client to the worker
    that owns its study."""
    n = fab.n_workers
    wids = sorted(fab.locations()) if not fab.inline else [0]
    buckets: dict[int, list[str]] = {w: [] for w in wids}
    i = 0
    while any(len(b) < per_worker for b in buckets.values()):
        study = Study(name=f"bench-fabric-{i}", properties=dict(_SPACE),
                      sampler={"name": "random"}, client=client)
        key = study._ensure_key()
        owner = fab.owner_of(key)
        if len(buckets[owner]) < per_worker:
            buckets[owner].append(key)
        i += 1
        if i > 200 * n:                      # pragma: no cover - paranoia
            raise RuntimeError("could not balance studies over workers")
    return [buckets[wids[j % n]][j // n] for j in range(per_worker * n)]


def run(smoke: bool = False) -> list[dict]:
    worker_counts = (1, 2, 4)
    n_clients = 16
    total_pairs = 384 if smoke else 768
    reps = 1 if smoke else 3
    pairs_per_client = max(2, total_pairs // n_clients)
    pairs = pairs_per_client * n_clients
    rows: list[dict] = []
    by_workers: dict[tuple[str, int], dict] = {}

    for n_workers in worker_counts:
        attempts_router: list[dict] = []
        attempts_direct: list[dict] = []
        for _rep in range(reps):
            fab = ShardFabric(workers=n_workers, storage="memory",
                              respawn=False).start()
            try:
                tok = fab.issue_token("bench")
                setup = Client(HttpTransport(fab.host, fab.port), tok)
                keys = _aligned_keys(fab, setup,
                                     per_worker=max(1, 8 // n_workers))
                wall, lats = _load(tok, keys, n_clients=n_clients,
                                   pairs_per_client=pairs_per_client,
                                   host=fab.host, port=fab.port)
                attempts_router.append(_row("fabric-router", n_workers,
                                            n_clients, 2 * pairs, wall,
                                            pairs, lats))
                if not fab.inline:
                    wall, lats = _load(tok, keys, n_clients=n_clients,
                                       pairs_per_client=pairs_per_client,
                                       targets=fab.endpoints)
                    attempts_direct.append(_row("fabric-direct", n_workers,
                                                n_clients, 2 * pairs, wall,
                                                pairs, lats))
            finally:
                fab.stop()
        for attempts in (attempts_router, attempts_direct):
            if not attempts:
                continue
            attempts.sort(key=lambda r: r["pairs_per_s"])
            row = dict(attempts[len(attempts) // 2], reps=reps)
            by_workers[(row["scenario"], row["workers"])] = row
            rows.append(row)

    # -- acceptance summary: N-worker router throughput vs 1 worker ------
    base = by_workers[("fabric-router", 1)]["pairs_per_s"]
    for n_workers in worker_counts[1:]:
        row = by_workers.get(("fabric-router", n_workers))
        if row is None:
            continue
        rows.append({"scenario": f"scaling-{n_workers}w",
                     "workers": n_workers, "clients": n_clients,
                     "requests": None, "wall_s": None,
                     "pairs_per_s": round(row["pairs_per_s"] / base, 2),
                     "p50_ms": None, "p99_ms": None,
                     "cores": os.cpu_count()})

    out_dir = "experiments/benchmarks"
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "BENCH_fabric.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    print(json.dumps(run(smoke="--smoke" in sys.argv), indent=1))
