"""Paper sec. 2 — ``should_prune`` "aborts non-promising trials without
wasting computing power": total training steps spent (the compute bill)
and best final loss, with and without pruning.

Objective: simulated training curves loss(step) = plateau + span*exp(-r t)
where the plateau depends on the hyperparameters — a stand-in with the
same structure as the GAN campaigns in sec. 4.

Columns: pruner, trials, total_steps, steps_vs_nopruner, best_loss.
"""
from __future__ import annotations

import math

from repro.core.auth import TokenManager
from repro.core.client import Client, Study, suggestions
from repro.core.server import HopaasServer
from repro.core.transport import DirectTransport

MAX_STEPS = 50

PRUNERS = [
    {"name": "none"},
    {"name": "median", "n_warmup_steps": 5},
    {"name": "percentile", "percentile": 25.0, "n_warmup_steps": 5},
    {"name": "sha", "min_resource": 5, "reduction_factor": 3},
    {"name": "hyperband", "min_resource": 5, "max_resource": MAX_STEPS},
]


def _objective(params: dict) -> "list[float]":
    """Deterministic loss curve for a hyperparameter point."""
    lr, width = params["lr"], params["width"]
    plateau = (math.log10(lr) + 3.0) ** 2 * 0.3 + (width - 256) ** 2 / 3e5
    rate = 0.05 + 0.15 * min(1.0, lr / 1e-3)
    return [plateau + 2.0 * math.exp(-rate * t) for t in range(MAX_STEPS)]


def run(n_trials: int = 40) -> list[dict]:
    rows = []
    base_steps = None
    for pruner in PRUNERS:
        server = HopaasServer(tokens=TokenManager(), seed=17)
        tok = server.tokens.issue("bench")
        client = Client(DirectTransport(server), tok)
        study = Study(name=f"prune-{pruner['name']}",
                      properties={"lr": suggestions.loguniform(1e-5, 1e-1),
                                  "width": suggestions.int(32, 1024)},
                      sampler={"name": "tpe"}, pruner=pruner, client=client)
        total_steps, best = 0, float("inf")
        for _ in range(n_trials):
            trial = study.ask()
            curve = _objective(trial.params)
            pruned = False
            for step, value in enumerate(curve):
                total_steps += 1
                if trial.should_prune(step, value):
                    pruned = True
                    break
            if pruned:
                study.tell(trial, value=value, state="pruned")
            else:
                best = min(best, curve[-1])
                study.tell(trial, value=curve[-1])
        if pruner["name"] == "none":
            base_steps = total_steps
        rows.append({"pruner": pruner["name"], "trials": n_trials,
                     "total_steps": total_steps,
                     "steps_vs_nopruner": round(total_steps / base_steps, 3),
                     "best_loss": round(best, 4)})
    return rows
