"""Roofline table — reads the dry-run JSON records (deliverable g).

Produces the per-(arch x shape x mesh) table of the three roofline terms,
the dominant bottleneck, the MODEL_FLOPS/HLO_FLOPs useful ratio, and the
per-kind score.  Run ``repro.launch.dryrun`` first; columns are read from
``experiments/dryrun/*.json``.
"""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def run(mesh: str | None = "16x16") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        d = json.load(open(path))
        if mesh is not None and d["mesh"] != mesh:
            continue
        r, m = d["roofline"], d["memory"]
        rows.append({
            "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
            "compute_ms": round(r["compute_s"] * 1e3, 2),
            "memory_ms": round(r["memory_s"] * 1e3, 2),
            "mem_flash_ms": round(
                r.get("memory_s_with_flash_kernel", r["memory_s"]) * 1e3, 2),
            "collective_ms": round(r["collective_s"] * 1e3, 2),
            "dominant": r["dominant"],
            "hbm_util": round(m.get("hbm_utilization", 0.0), 3),
            "useful_flops_ratio": round(r["useful_flops_ratio"], 3),
            "score": round(r["bytes_efficiency"] if d["kind"] == "decode"
                           else r["roofline_fraction"], 4),
        })
    if not rows:
        rows.append({"note": f"no dry-run records in {DRYRUN_DIR}; "
                     "run `python -m repro.launch.dryrun --all` first"})
    return rows


def render_markdown(out_path: str = "experiments/roofline_table.md") -> str:
    lines = ["# Roofline table (generated from the dry-run records)", "",
             "Terms in ms/step per device; `mem_flash` = memory term with "
             "attention-score traffic removed (the Pallas flash kernel's "
             "effect); score = roofline_fraction (train/prefill) or "
             "bytes_efficiency (decode).", ""]
    for mesh in ("16x16", "2x16x16"):
        rows = run(mesh)
        if rows and "note" in rows[0]:
            continue
        cols = list(rows[0].keys())
        lines += [f"## mesh {mesh}", "",
                  "| " + " | ".join(cols) + " |",
                  "|" + "---|" * len(cols)]
        lines += ["| " + " | ".join(str(r[c]) for c in cols) + " |"
                  for r in rows]
        lines.append("")
    text = "\n".join(lines)
    with open(out_path, "w") as f:
        f.write(text)
    return text


if __name__ == "__main__":
    render_markdown()
    print("wrote experiments/roofline_table.md")
