"""Benchmark harness — one table per paper claim (+ the roofline table).

  PYTHONPATH=src python -m benchmarks.run            # all tables
  PYTHONPATH=src python -m benchmarks.run --only api,samplers
  PYTHONPATH=src python -m benchmarks.run --smoke    # fast CI subset
"""
from __future__ import annotations

import argparse
import importlib
import inspect
import json
import os
import sys
import time

# support `python benchmarks/run.py` as well as `python -m benchmarks.run`
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

# tables fast enough (and dependency-light enough) for the CI smoke run
SMOKE_TABLES = ("api", "campaign", "ask_latency", "parallel_ask", "storage",
                "transport", "fabric", "replication")

TABLES = {
    "api": ("bench_api", "paper sec.3: transports + horizontal scaling"),
    "transport": ("bench_transport",
                  "PR 5: event-loop vs threaded frontend under "
                  "contended keep-alive load"),
    "fabric": ("bench_fabric",
               "PR 6: multi-process shard fabric — worker-count scaling "
               "through the consistent-hash router"),
    "replication": ("bench_replication",
                    "PR 7: WAL-shipping replication — throughput vs "
                    "replication mode + measured failover gap"),
    "convergence": ("bench_convergence", "paper sec.1/2: BO beats random"),
    "ask_latency": ("bench_ask_latency",
                    "PR 2: ask latency vs history (obs cache + fused kernels)"),
    "parallel_ask": ("bench_parallel_ask",
                     "PR 10: speculative ask pipeline — contended ask/tell "
                     "throughput + constant-liar batch quality"),
    "storage": ("bench_storage",
                "PR 4: fsync-mode throughput + snapshot/segment recovery"),
    "pruners": ("bench_pruners", "paper sec.2: pruning saves compute"),
    "campaign": ("bench_campaign", "paper sec.4: elastic multi-worker campaign"),
    "hpo_train": ("bench_hpo_train", "end-to-end: HOPAAS steering JAX training"),
    "roofline": ("bench_roofline", "dry-run roofline terms (deliverable g)"),
}

# the bench_sampler/bench_samplers near-twin pair was consolidated into
# names that say what each table measures; keep the old spellings as
# hard errors (not aliases) so stale scripts fail loudly, not silently
RENAMED = {
    "samplers": "convergence",
    "bench_samplers": "convergence",
    "bench_sampler": "ask_latency",
}


def _fmt_table(rows: list[dict]) -> str:
    if not rows:
        return "(empty)"
    cols = list(rows[0].keys())
    widths = {c: max(len(str(c)), *(len(str(r.get(c, ""))) for r in rows))
              for c in cols}
    lines = ["  ".join(str(c).ljust(widths[c]) for c in cols)]
    lines.append("  ".join("-" * widths[c] for c in cols))
    for r in rows:
        lines.append("  ".join(str(r.get(c, "")).ljust(widths[c])
                               for c in cols))
    return "\n".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated table names")
    ap.add_argument("--smoke", action="store_true",
                    help="fast subset with reduced sizes (CI)")
    ap.add_argument("--out", default="experiments/benchmarks")
    args = ap.parse_args()
    if args.only:
        only = {n for n in (s.strip() for s in args.only.split(","))
                if n}
        renamed = only & set(RENAMED)
        if renamed:
            for old in sorted(renamed):
                print(f"benchmark table '{old}' was renamed to "
                      f"'{RENAMED[old]}'; use --only {RENAMED[old]}",
                      file=sys.stderr)
            return 2
        unknown = only - set(TABLES)
        if unknown or not only:
            # a misspelled --only must not look like a green run
            print(f"unknown table name(s): {sorted(unknown)}; "
                  f"choose from {sorted(TABLES)}", file=sys.stderr)
            return 2
    elif args.smoke:
        only = set(SMOKE_TABLES)
    else:
        only = set(TABLES)

    os.makedirs(args.out, exist_ok=True)
    failures = []
    ran = 0
    for name, (module, caption) in TABLES.items():
        if name not in only:
            continue
        ran += 1
        print(f"\n=== {name}: {caption} ===", flush=True)
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{module}")
            kwargs = {}
            if args.smoke and "smoke" in inspect.signature(mod.run).parameters:
                kwargs["smoke"] = True
            rows = mod.run(**kwargs)
        except Exception as e:   # keep the harness going
            failures.append((name, repr(e)))
            print(f"  FAILED: {e!r}")
            continue
        print(_fmt_table(rows))
        print(f"  ({time.time() - t0:.1f}s)")
        with open(os.path.join(args.out, f"{name}.json"), "w") as f:
            json.dump(rows, f, indent=1)
    if failures:
        print("\nFAILURES:", failures)
        return 1
    if not ran:
        # selection matched nothing: vacuous success is a silent CI hole
        print("no benchmark tables selected", file=sys.stderr)
        return 2
    print("\nall benchmark tables written to", args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
